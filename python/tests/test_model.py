"""L2 correctness: entry points implement the column-major bridge —
f(bt, at) = bt @ at reproduces BLAS column-major dgemm — and lower to
single fused HLO modules."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402


def _colmajor_dgemm_via_entry(entry, a_cm, b_cm, m, n, k):
    """Emulate the Rust runtime: reinterpret column-major buffers as
    row-major transposes, call the entry, get back C column-major."""
    at = a_cm.reshape((k, m))  # A is m×k col-major ⇒ (k,m) row-major
    bt = b_cm.reshape((n, k))
    (ct,) = entry(jnp.asarray(bt), jnp.asarray(at))
    return np.asarray(ct).reshape(-1)  # C col-major flat


def test_gemm_entry_matches_blas_semantics():
    m, n, k = 5, 4, 3
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, k))  # logical A
    b = rng.standard_normal((k, n))
    a_cm = np.asfortranarray(a).ravel(order="F")
    b_cm = np.asfortranarray(b).ravel(order="F")
    c_cm = _colmajor_dgemm_via_entry(model.gemm_jnp, a_cm, b_cm, m, n, k)
    c = np.asarray(c_cm).reshape((m, n), order="F")
    np.testing.assert_allclose(c, a @ b, rtol=1e-12)


def test_gemm_pallas_entry_agrees_with_jnp_entry():
    n = 16
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.standard_normal((n, n)))
    at = jnp.asarray(rng.standard_normal((n, n)))
    (c1,) = model.gemm_jnp(bt, at)
    (c2,) = model.gemm_pallas(bt, at)
    np.testing.assert_allclose(c1, c2, rtol=1e-11)


def test_syrk_entry_symmetric():
    at = jnp.asarray(np.random.default_rng(2).standard_normal((6, 4)))
    (c,) = model.syrk_jnp(at)
    np.testing.assert_allclose(c, c.T, rtol=1e-12)


def test_lower_entry_produces_hlo():
    lowered = model.lower_entry("gemm_jnp", [(8, 8), (8, 8)])
    txt = lowered.as_text()
    assert "dot" in txt or "stablehlo" in txt


def test_lowered_module_is_single_fused_computation():
    # §Perf L2 target: one dot, no redundant transposes in the module
    lowered = model.lower_entry("gemm_jnp", [(16, 8), (8, 12)])
    txt = lowered.as_text()
    assert txt.count("stablehlo.dot_general") == 1
    assert "stablehlo.transpose" not in txt
