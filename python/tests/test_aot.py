"""AOT pipeline: HLO-text artifacts + manifest are produced, are
parseable by the XLA text format (smoke: header shape), and the
manifest schema matches what the Rust runtime expects."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot  # noqa: E402


def test_build_small_artifacts(tmp_path):
    out = str(tmp_path)
    manifest = aot.build(out, only_small=True)
    assert manifest["version"] == 1
    assert manifest["artifacts"], "no artifacts built"
    for art in manifest["artifacts"]:
        assert set(art) >= {"kernel", "impl", "m", "n", "k", "file", "dtype"}
        assert max(art["m"], art["n"], art["k"]) <= 128
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), art
        text = open(path).read()
        assert text.startswith("HloModule"), text[:80]
        assert "f64" in text
    # manifest file itself
    with open(os.path.join(out, "manifest.json")) as f:
        ondisk = json.load(f)
    assert ondisk == manifest


def test_build_is_incremental(tmp_path):
    out = str(tmp_path)
    aot.build(out, only_small=True)
    # second build must not rewrite artifact files (no-op semantics)
    path = os.path.join(out, aot.build(out, only_small=True)["artifacts"][0]["file"])
    mtime1 = os.path.getmtime(path)
    aot.build(out, only_small=True)
    assert os.path.getmtime(path) == mtime1


def test_artifact_list_covers_tensor_contraction_sweep():
    arts = aot.artifact_list()
    # ∀c algorithm needs each swept n
    for n in aot.TC_N_SWEEP:
        assert ("dgemm", "jnp", aot.TC_M, n, aot.TC_K) in arts
    # Pallas impl present
    assert any(impl == "pallas" for (_, impl, *_rest) in arts)
