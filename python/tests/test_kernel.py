"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle —
the CORE correctness signal of the compile path. Hypothesis sweeps
shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import matmul as pk  # noqa: E402
from compile.kernels import ref  # noqa: E402


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape), dtype=dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("shape", [(8, 8, 8), (128, 128, 128), (128, 64, 32)])
def test_matmul_padded_exact_blocks(dtype, shape):
    m, n, k = shape
    x = _rand((m, k), dtype, 1)
    y = _rand((k, n), dtype, 2)
    got = pk.matmul_padded(x, y, bm=min(128, m), bn=min(128, n), bk=min(128, k))
    want = ref.matmul_ref(x, y)
    rtol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 97),
    n=st.integers(1, 97),
    k=st.integers(1, 97),
    dtype=st.sampled_from(["float32", "float64"]),
)
def test_matmul_arbitrary_shapes_hypothesis(m, n, k, dtype):
    dt = jnp.float32 if dtype == "float32" else jnp.float64
    x = _rand((m, k), dt, m * 13 + k)
    y = _rand((k, n), dt, n * 7 + k)
    got = pk.matmul(x, y, bm=32, bn=32, bk=32)
    want = ref.matmul_ref(x, y)
    rtol = 2e-4 if dtype == "float32" else 1e-11
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


def test_matmul_nondivisible_padding_is_masked():
    # padding must not leak into the result
    x = jnp.ones((33, 17), jnp.float64)
    y = jnp.ones((17, 9), jnp.float64)
    got = pk.matmul(x, y, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, 17.0 * np.ones((33, 9)))


def test_matmul_rejects_mismatched_inner():
    with pytest.raises(AssertionError):
        pk.matmul_padded(jnp.ones((8, 8)), jnp.ones((16, 8)), bm=8, bn=8, bk=8)


def test_vmem_footprint_within_budget():
    # DESIGN.md §Perf: default BlockSpec ≤ 4 MiB of VMEM
    assert pk.vmem_footprint_bytes(128, 128, 128, dtype_bytes=4) <= 4 * 2**20


def test_mxu_utilization_estimate():
    assert pk.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert pk.mxu_utilization_estimate(64, 128, 128) == 0.5
