"""L1: tiled Pallas matmul kernel — the compute hot-spot of ELAPS-RS's
``xla`` "vendor library" backend.

TPU-style structure (DESIGN.md §Hardware-Adaptation): the grid tiles
C into (bm × bn) VMEM-resident blocks (MXU-shaped, default 128×128);
the innermost grid dimension walks the K panels, accumulating into the
revisited output block — the BlockSpec expresses the HBM↔VMEM schedule
that a CUDA kernel would express with threadblocks and shared memory.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel is lowered to plain HLO (see
/opt/xla-example/README.md). Real-TPU efficiency is *estimated* from
the BlockSpec in EXPERIMENTS.md §Perf, never measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps: int):
    """One (bm × bn) output block; grid dim 2 walks the K panels."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )
    del nsteps  # structure kept for the TPU double-buffered variant


def matmul_padded(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Pallas matmul requiring dims divisible by the block sizes."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    )
    nsteps = k // bk
    grid = (m // bm, n // bn, nsteps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """General matmul: pads to block multiples, runs the Pallas kernel,
    slices the result back. Equal to ``ref.matmul_ref`` on any shape."""
    m, k = x.shape
    _, n = y.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = matmul_padded(xp, yp, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


def _round_up(v: int, to: int) -> int:
    return ((v + to - 1) // to) * to


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step: X block + Y block +
    O block (double-buffered inputs). Used by EXPERIMENTS.md §Perf."""
    return dtype_bytes * (2 * (bm * bk + bk * bn) + bm * bn)


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issue slots doing useful work for one block step,
    assuming a 128×128 systolic MXU: full tiles ⇒ 1.0, partial ⇒ the
    fill ratio."""
    fill = lambda b: min(b, 128) / 128.0  # noqa: E731
    return fill(bm) * fill(bn) * fill(bk)
