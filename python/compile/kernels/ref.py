"""Pure-jnp correctness oracles for the Pallas kernels and the L2
model entry points. These ARE the semantics; pytest asserts the Pallas
and AOT paths against them."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """C = X @ Y with accumulation in the output dtype."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def gemm_ref(alpha, x, y, beta, c):
    """Full BLAS dgemm semantics: alpha*X@Y + beta*C."""
    return alpha * matmul_ref(x, y) + beta * c


def syrk_ref(x):
    """C = Xᵀ @ X (full matrix, both triangles)."""
    return jnp.dot(x.T, x, preferred_element_type=x.dtype)
