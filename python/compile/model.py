"""L2: the JAX compute graph of the ``xla`` vendor-library backend.

Each entry point is a pure jax function lowered once by ``aot.py`` to
HLO text and executed from the Rust runtime via PJRT — Python never
runs on the request path.

Column-major bridge: Rust stores BLAS operands column-major; jax
arrays are logically row-major. A column-major (m×k) buffer
reinterpreted row-major is the (k×m) transpose, and ``(A·B)ᵀ =
Bᵀ·Aᵀ``, so the Rust runtime passes (Bᵀ, Aᵀ) — i.e. the raw B and A
buffers with swapped logical shapes — and receives Cᵀ, which is
exactly the column-major C buffer. The gemm entry points are therefore
``f(bt, at) = bt @ at`` with bt: (n, k), at: (k, m).
"""

import jax
import jax.numpy as jnp

from .kernels import matmul as pk


def gemm_jnp(bt, at):
    """dgemm core via XLA's native dot (the 'vendor gemm')."""
    return (jnp.dot(bt, at, preferred_element_type=bt.dtype),)


def gemm_pallas(bt, at):
    """dgemm core via the L1 Pallas kernel."""
    return (pk.matmul(bt, at),)


def syrk_jnp(at):
    """dsyrk core. ``at`` is the raw column-major (n×k) A buffer seen
    as (k, n) row-major; AᵀA is symmetric so Cᵀ = C and the result maps
    straight back into the column-major C buffer: atᵀ·at? — careful:
    C = A·Aᵀ (trans='N') in column-major is (k,n)-row-major ``at``
    contracted over its first axis."""
    c = jnp.dot(at.T, at, preferred_element_type=at.dtype)
    return (c,)


ENTRY_POINTS = {
    "gemm_jnp": gemm_jnp,
    "gemm_pallas": gemm_pallas,
    "syrk_jnp": syrk_jnp,
}


def lower_entry(name: str, shapes, dtype=jnp.float64):
    """Lower an entry point at concrete shapes; returns the jax
    ``Lowered`` object."""
    fn = ENTRY_POINTS[name]
    args = [jax.ShapeDtypeStruct(s, dtype) for s in shapes]
    return jax.jit(fn).lower(*args)
