"""AOT driver: lower every (entry point, shape) pair the Rust runtime
needs to **HLO text** plus a ``manifest.json`` the runtime indexes.

HLO text — NOT ``lowered.compiler_ir('hlo')``/``.serialize()`` — is the
interchange format: the image's xla_extension 0.5.1 rejects jax≥0.5's
serialized protos (64-bit instruction ids); the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md and
gen_hlo.py there.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# The artifact set: every (kernel, impl, m, n, k) the figures use on
# the `xla` backend. gemm entries take (bt:(n,k), at:(k,m)) — see
# model.py's column-major bridge.
#
# Tensor-contraction study (Fig. 11, sizes scaled /4 per DESIGN.md
# §Substitutions 7): A ∈ R^{312×188}, B ∈ R^{188×125×n}.
TC_M, TC_K, TC_B = 312, 188, 125
TC_N_SWEEP = [25, 50, 75, 100, 150, 200, 300, 400, 500, 625]


def artifact_list():
    arts = []
    # square vendor gemms for quickstart / e2e / locality studies
    for n in [100, 128, 256, 500, 1000]:
        arts.append(("dgemm", "jnp", n, n, n))
    # Pallas-kernel gemms (block-divisible shapes)
    for n in [128, 256]:
        arts.append(("dgemm", "pallas", n, n, n))
    # tensor contraction ∀b: C[:,:,c] slices — fixed (m,n,k)
    arts.append(("dgemm", "jnp", TC_M, TC_B, TC_K))
    # tensor contraction ∀c: C[:,b,:] slices — n sweeps
    for n in TC_N_SWEEP:
        arts.append(("dgemm", "jnp", TC_M, n, TC_K))
    return arts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, *, only_small: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    for kernel, impl, m, n, k in artifact_list():
        if only_small and max(m, n, k) > 128:
            continue  # excluded from the manifest too: lookups must miss
        entry = "gemm_pallas" if impl == "pallas" else "gemm_jnp"
        fname = f"{kernel}_{impl}_{m}x{n}x{k}.hlo.txt"
        path = os.path.join(out_dir, fname)
        meta = {
            "kernel": kernel,
            "impl": impl,
            "m": m,
            "n": n,
            "k": k,
            "file": fname,
            "dtype": "f64",
        }
        if not os.path.exists(path):
            lowered = model.lower_entry(entry, [(n, k), (k, m)])
            text = to_hlo_text(lowered)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        manifest["artifacts"].append(meta)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only-small",
        action="store_true",
        help="only artifacts ≤128 (fast smoke builds in tests)",
    )
    args = ap.parse_args()
    manifest = build(args.out_dir, only_small=args.only_small)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
