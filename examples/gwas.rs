//! Algorithmic optimization (paper §4.4): genome-wide association
//! studies solve millions of small generalized-least-squares problems.
//! ELAPS-RS reproduces the paper's two-step optimization story:
//!
//! 1. the timing breakdown exposes dposv (M-sized Cholesky solve) as
//!    the bottleneck of the straightforward per-i loop,
//! 2. hoisting the i-independent solve and batching the right-hand
//!    sides into one dpotrs gains an order of magnitude.
//!
//! Run: `cargo run --release --example gwas`

use anyhow::Result;

fn main() -> Result<()> {
    let out = elaps::figures::f14_gwas(&elaps::figures::LocalRunner, false)?;
    for row in &out.rows {
        println!("{row}");
    }
    if let Some(fig) = &out.figure {
        println!("\n{}", fig.to_ascii(70, 18));
    }
    println!("{}", out.notes);
    Ok(())
}
