//! End-to-end validation: proves all layers compose on a real workload
//! (recorded in EXPERIMENTS.md §End-to-end).
//!
//!  L1/L2 — Pallas/JAX kernels, AOT-compiled to HLO text by
//!          `make artifacts` (build time, Python);
//!  runtime — the Rust PJRT client loads + compiles the artifacts;
//!  L3  — the coordinator unrolls a parameter-range Experiment into
//!        sampler scripts, the sampler executes the calls on the `xla`
//!        backend (PJRT) AND the rust libraries, reports flow back
//!        through the batch spooler, metrics/statistics/plots come out.
//!
//! The workload is the paper's core study: dgemm performance across
//! libraries, plus a numerical cross-check that the PJRT path computes
//! the same C as the rust substrate.
//!
//! Run: `make artifacts && cargo run --release --example e2e_validation`

use anyhow::{bail, Result};
use elaps::coordinator::{run_local, Metric, Spooler, Stat};
use elaps::figures::call;
use elaps::linalg::Matrix;
use elaps::util::rng::Xoshiro256;

fn main() -> Result<()> {
    // ---- stage 1: artifacts + PJRT runtime --------------------------
    let dir = elaps::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        bail!("artifacts/manifest.json missing — run `make artifacts` first");
    }
    let registry = elaps::runtime::register_xla_library(&dir)?;
    println!(
        "[1/4] PJRT runtime up: {} artifacts in {:?}",
        registry.artifact_count(),
        dir
    );

    // ---- stage 2: numerical cross-check rust ⇄ PJRT ⇄ Pallas --------
    let n = 128;
    let mut rng = Xoshiro256::seeded(2026);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let expect = a.matmul(&b);
    for impl_name in ["jnp", "pallas"] {
        let meta = registry
            .find("dgemm", n, n, n, impl_name)
            .filter(|m| m.key.impl_name == impl_name)
            .ok_or_else(|| anyhow::anyhow!("no {impl_name} artifact for {n}³"))?
            .clone();
        let mut c = vec![0.0f64; n * n];
        registry.run_gemm(&meta, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0)?;
        let diff = Matrix { m: n, n, data: c }.max_abs_diff(&expect);
        if diff > 1e-9 {
            bail!("{impl_name} artifact disagrees with rust substrate: {diff}");
        }
        println!("[2/4] {impl_name:>6} artifact ✓ max|Δ| = {diff:.2e} vs rust gemm");
    }

    // ---- stage 3: full experiment across all backends ---------------
    // dgemm n = 100..500 on every library, submitted through the batch
    // spooler (the paper's LoadLeveler/LSF workflow substitute).
    let spool_dir = std::env::temp_dir().join(format!("elaps-e2e-{}", std::process::id()));
    let spool = Spooler::new(&spool_dir)?;
    let sizes: Vec<i64> = vec![100, 128, 256, 500];
    println!("[3/4] dgemm study over {sizes:?} via the batch spooler:");
    println!(
        "      {:>6} {:>12} {:>12} {:>12} {:>12}",
        "n", "rustref", "rustblocked", "rustrec", "xla(PJRT)"
    );
    let mut per_lib: Vec<Vec<(i64, f64)>> = Vec::new();
    for lib in ["rustref", "rustblocked", "rustrecursive", "xla"] {
        let mut exp = elaps::coordinator::Experiment {
            name: format!("e2e-dgemm-{lib}"),
            library: lib.into(),
            nreps: 4,
            discard_first: true,
            range: Some(elaps::coordinator::RangeDef::new("n", sizes.clone())),
            calls: vec![call(
                "dgemm",
                &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
            )?],
            ..Default::default()
        };
        exp.counters = vec!["PAPI_L1_TCM".into()];
        let report = spool.run_through_queue(&exp)?;
        per_lib.push(report.series(Metric::Gflops, Stat::Median));
    }
    for (i, &n) in sizes.iter().enumerate() {
        println!(
            "      {:>6} {:>12.3} {:>12.3} {:>12.3} {:>12.3}",
            n, per_lib[0][i].1, per_lib[1][i].1, per_lib[2][i].1, per_lib[3][i].1
        );
    }
    let _ = std::fs::remove_dir_all(&spool_dir);

    // ---- stage 4: metrics/statistics/plot from a local run ----------
    let mut exp = elaps::coordinator::Experiment {
        name: "e2e-summary".into(),
        library: "xla".into(),
        nreps: 5,
        discard_first: true,
        calls: vec![call(
            "dgemm",
            &[
                "N", "N", "1000", "1000", "1000", "1.0", "$A", "1000", "$B", "1000",
                "0.0", "$C", "1000",
            ],
        )?],
        ..Default::default()
    };
    exp.counters = vec![];
    let report = run_local(&exp)?;
    println!("[4/4] headline (paper §2 metrics table, dgemm 1000³ via PJRT):");
    for (name, v) in report.metrics_table() {
        println!("      {name:<18} {v:>16.2}");
    }
    let mut fig = elaps::coordinator::Figure::new("e2e dgemm across libraries", "n", "Gflops/s");
    for (lib, series) in ["rustref", "rustblocked", "rustrecursive", "xla"]
        .iter()
        .zip(&per_lib)
    {
        fig.add_iseries(lib, series);
    }
    std::fs::create_dir_all("figures_out")?;
    std::fs::write("figures_out/e2e_validation.svg", fig.to_svg(720, 440))?;
    println!("\n{}", fig.to_ascii(70, 16));
    println!("e2e validation PASSED — plot at figures_out/e2e_validation.svg");
    Ok(())
}
