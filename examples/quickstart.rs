//! Quickstart: the paper's §2 walk-through on the public API.
//!
//! Builds Experiment 1 (one dgemm), derives the metrics table, then
//! Experiment 2 (10 repetitions) and prints the statistics of Fig. 1 —
//! showing the first-execution outlier and why ELAPS drops it.
//!
//! Run: `cargo run --release --example quickstart`

use elaps::coordinator::{run_local, Call, CallArg, Experiment, Metric, Stat};
use elaps::coordinator::stats::ALL_STATS;
use anyhow::Result;

fn dgemm_call(n: i64) -> Result<Call> {
    let e = |v: i64| CallArg::n(v);
    Call::new(
        "dgemm",
        vec![
            CallArg::Flag('N'),
            CallArg::Flag('N'),
            e(n),
            e(n),
            e(n),
            CallArg::Scalar(1.0),
            CallArg::Data("A".into()),
            e(n),
            CallArg::Data("B".into()),
            e(n),
            CallArg::Scalar(0.0),
            CallArg::Data("C".into()),
            e(n),
        ],
    )
}

fn main() -> Result<()> {
    let n = 300;
    // ------------------------------------------------ Experiment 1
    let mut exp = Experiment {
        name: "experiment-1".into(),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 1,
        calls: vec![dgemm_call(n)?],
        counters: vec!["PAPI_L1_TCM".into(), "PAPI_BR_MSP".into()],
        ..Default::default()
    };
    let report = run_local(&exp)?;
    println!("Experiment 1 — dgemm n={n}, 1 repetition:");
    println!("  {:<18} {:>16}", "metric", "value");
    for (name, v) in report.metrics_table() {
        println!("  {name:<18} {v:>16.1}");
    }
    for (i, c) in exp.counters.iter().enumerate() {
        let v = report.series(Metric::Counter(i), Stat::Median)[0].1;
        println!("  {c:<18} {v:>16.0}   (simulated)");
    }

    // ------------------------------------------------ Experiment 2
    exp.name = "experiment-2".into();
    exp.nreps = 10;
    let report = run_local(&exp)?;
    let vals = report.rep_values(&report.points[0], Metric::TimeMs);
    println!("\nExperiment 2 — same dgemm, 10 repetitions (time [ms]):");
    println!(
        "  per-rep: {}",
        vals.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>().join(" ")
    );
    println!("  {:<8} {:>12} {:>16}", "stat", "all reps", "without first");
    for &stat in ALL_STATS {
        println!(
            "  {:<8} {:>12.3} {:>16.3}",
            stat.name(),
            stat.apply(&vals),
            stat.apply(&vals[1..])
        );
    }
    println!(
        "\nThe first repetition is {}the slowest — ELAPS discards it by default\n\
         (experiment.discard_first) exactly as the paper's §2.1 recommends.",
        if vals[0] >= vals[1..].iter().cloned().fold(0.0, f64::max) { "" } else { "not always " }
    );
    Ok(())
}
