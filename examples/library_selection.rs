//! Library selection (paper §4.2): which "library" solves the
//! triangular Sylvester equation fastest?
//!
//! The vendor libraries of the paper (LAPACK, RECSY, libFLAME, MKL) are
//! substituted by the from-scratch algorithmic variants — unblocked,
//! blocked, recursive (DESIGN.md §Substitutions 1). The study runs one
//! parameter-range experiment per library and compares the series,
//! exactly the Fig. 12 workflow.
//!
//! Run: `cargo run --release --example library_selection`

use anyhow::Result;
use elaps::coordinator::{run_local, DataGen, Expr, Figure, Metric, RangeDef, Stat};
use elaps::figures::call;

fn main() -> Result<()> {
    let mut fig = Figure::new("triangular Sylvester equation", "n", "Gflops/s");
    println!("dtrsyl A·X + X·B = C across libraries (n = 64:64:448):\n");
    println!("{:>6} {:>14} {:>14} {:>14}", "n", "rustref", "rustblocked", "rustrecursive");
    let mut table: Vec<Vec<f64>> = Vec::new();
    let mut xs: Vec<i64> = Vec::new();
    for lib in ["rustref", "rustblocked", "rustrecursive"] {
        let mut exp = elaps::coordinator::Experiment {
            name: format!("sylvester-{lib}"),
            library: lib.into(),
            nreps: 4,
            discard_first: true,
            range: Some(RangeDef::span("n", 64, 64, 448)),
            calls: vec![call(
                "dtrsyl",
                &["N", "N", "1", "n", "n", "$A", "n", "$B", "n", "$C", "n"],
            )?],
            ..Default::default()
        };
        exp.datagen.insert("A".into(), DataGen::Tri(Expr::sym("n"), 'U'));
        exp.datagen.insert("B".into(), DataGen::Tri(Expr::sym("n"), 'U'));
        let report = run_local(&exp)?;
        let series = report.series(Metric::Gflops, Stat::Median);
        if xs.is_empty() {
            xs = series.iter().map(|&(x, _)| x).collect();
            table = vec![Vec::new(); xs.len()];
        }
        for (i, &(_, g)) in series.iter().enumerate() {
            table[i].push(g);
        }
        fig.add_iseries(lib, &series);
    }
    for (i, &x) in xs.iter().enumerate() {
        println!(
            "{x:>6} {:>14.3} {:>14.3} {:>14.3}",
            table[i][0], table[i][1], table[i][2]
        );
    }
    println!("\n{}", fig.to_ascii(70, 18));
    let last = table.last().unwrap();
    println!(
        "decision: at large n pick `{}` — the paper reaches the analogous\n\
         conclusion for RECSY over LAPACK/libFLAME/MKL (Fig. 12).",
        ["rustref", "rustblocked", "rustrecursive"]
            [last.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0]
    );
    Ok(())
}
