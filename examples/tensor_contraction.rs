//! Algorithm selection (paper §4.1): cast the tensor contraction
//! C_abc := A_ak B_kcb as a series of dgemm's — loop over b (∀b) or
//! over c (∀c)? The answer depends on the free dimension n, with a
//! crossover the experiment locates (Fig. 11).
//!
//! Uses the `xla` backend (JAX-AOT artifacts via PJRT) when built,
//! falling back to the rust blocked library.
//!
//! Run: `make artifacts && cargo run --release --example tensor_contraction`

use anyhow::Result;
use elaps::figures;

fn main() -> Result<()> {
    // register the PJRT-backed library if artifacts are present
    let dir = elaps::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let reg = elaps::runtime::register_xla_library(&dir)?;
        println!(
            "xla backend registered: {} AOT artifacts (gemm via PJRT)\n",
            reg.artifact_count()
        );
    } else {
        println!("artifacts/ missing — run `make artifacts`; using rustblocked\n");
    }
    let out = figures::f11_tensor_contraction(&figures::LocalRunner, false)?;
    for row in &out.rows {
        println!("{row}");
    }
    if let Some(fig) = &out.figure {
        println!("\n{}", fig.to_ascii(70, 18));
    }
    println!("{}", out.notes);
    Ok(())
}
