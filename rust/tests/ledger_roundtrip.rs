//! Differential ledger suite: the append-only campaign ledger and its
//! index snapshot against the file-per-fact spool they replace, driven
//! through the real CLI binary. Invariants:
//!
//! * **differential byte-identity** — a ledger-backed campaign and a
//!   `--no-ledger` file-backed campaign drained the same way produce
//!   byte-identical reports (after the report-JSON normalization),
//!   identical `wait` output modulo job ids, and byte-identical
//!   `spool status --json` — including between the ledger status path
//!   and the directory-scan path on the same spool;
//! * **archival is not amnesia** — `spool compact --archive` moves the
//!   log away but the index snapshot keeps answering `wait`/`fetch`/
//!   `status` queries unchanged;
//! * **retry exactly-once** — `elaps retry` resubmits each
//!   error-stamped job exactly once (durably: a second invocation is a
//!   no-op), dead-letters a chain at its attempt budget, and the whole
//!   chain passes the `elaps analyze` exactly-once publish audit;
//! * **cross-process `--max-leases`** — two worker *processes* sharing
//!   a host never exceed the per-host cap at any observation point
//!   (the regression for the lease-estimate over-cap window);
//! * **locked campaign reads** — readers racing `record_jobs` merges
//!   only ever see whole-batch, order-consistent snapshots (the
//!   regression for the unlocked `wait --campaign`/`fetch` reads).
//!
//! Like `campaign_roundtrip.rs`, timing margins are generous and waits
//! poll real state, so the suite stays flake-free under
//! `--test-threads=1` with `ELAPS_LEASE_TTL=1s` in the tier-2 CI leg.

use elaps::coordinator::campaign::{self, StampOutcome};
use elaps::coordinator::lease;
use elaps::coordinator::ledger;
use elaps::coordinator::{io, Experiment, Spooler};
use elaps::engine::{set_default_config, EngineConfig};
use elaps::figures::call;
use elaps::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// Pin the process-default engine config to serial, fixed-seed
/// execution (modeled timings): every report becomes a pure function
/// of its experiment, which is what turns the ledger-vs-file spool
/// comparison into a byte-equality check.
fn det_config() {
    set_default_config(EngineConfig::default().with_seed(7));
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps_ledger_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Equal-width sizes keep queue order (lexicographic by job file name)
/// aligned with submission order — see `campaign_roundtrip.rs`.
fn small_exp(n: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: format!("camp{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

fn normalize(r: &elaps::Report) -> String {
    io::report_to_json(r).to_string_pretty()
}

fn count_json(dir: &Path, sub: &str) -> usize {
    std::fs::read_dir(dir.join(sub))
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                .count()
        })
        .unwrap_or(0)
}

fn elaps_cmd(args: &[&str]) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_elaps"));
    cmd.args(args);
    for var in [
        "ELAPS_JOBS",
        "ELAPS_CACHE",
        "ELAPS_WARM",
        "ELAPS_SEED",
        "ELAPS_TRUSTED_ONLY",
        "ELAPS_HOST",
        "ELAPS_EVENTS",
    ] {
        cmd.env_remove(var);
    }
    cmd
}

fn stdout_lines(out: &std::process::Output) -> Vec<String> {
    String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(String::from)
        .collect()
}

/// Strip the leading job id from each `wait` outcome line (`{id}  ok
/// (host …)`) so outputs of two spools with different ids compare.
fn after_id(text: &str) -> Vec<String> {
    text.lines()
        .map(|l| match l.split_once("  ") {
            Some((_, rest)) => rest.to_string(),
            None => l.to_string(),
        })
        .collect()
}

// ------------------------------------------ the differential roundtrip

#[test]
fn ledger_and_file_spools_are_differential() {
    det_config();
    let dir = tmpdir("diff");
    std::fs::create_dir_all(&dir).unwrap();
    let exps: Vec<Experiment> = (0..4).map(|i| small_exp(10 + 2 * i)).collect();
    let mut mj = Json::obj();
    mj.set("campaign", "camp")
        .set("experiments", Json::Arr(exps.iter().map(io::experiment_to_json).collect()));
    let manifest = dir.join("manifest.json");
    std::fs::write(&manifest, mj.to_string_pretty()).unwrap();

    // the same manifest submitted twice: ledger-backed (the default)
    // and file-backed (`--no-ledger`)
    let spools = [dir.join("ledger-spool"), dir.join("file-spool")];
    let mut ids: Vec<Vec<String>> = Vec::new();
    for (i, spool_dir) in spools.iter().enumerate() {
        let mut argv =
            vec!["submit", manifest.to_str().unwrap(), "--spool", spool_dir.to_str().unwrap()];
        if i == 1 {
            argv.push("--no-ledger");
        }
        let out = elaps_cmd(&argv).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        ids.push(stdout_lines(&out));
        assert_eq!(ids[i].len(), 4, "{:?}", ids[i]);
    }
    // the discriminator: a ledger on one side, a record file on the
    // other — and both resolve the same job list
    assert!(ledger::has_ledger(&spools[0], "camp"));
    assert!(!ledger::has_ledger(&spools[1], "camp"));
    assert!(campaign::campaign_jobs(&spools[0], "camp").is_err(), "no record file written");
    assert_eq!(campaign::campaign_jobs(&spools[1], "camp").unwrap(), ids[1]);
    assert_eq!(ledger::campaign_jobs_resolved(&spools[0], "camp", true).unwrap(), ids[0]);

    // drain both spools identically: hostA serves the first two jobs,
    // hostB the last two, with pinned worker identities
    for (i, spool_dir) in spools.iter().enumerate() {
        let a = Spooler::new(spool_dir).unwrap().with_host("hostA").with_worker("wA#0");
        let b = Spooler::new(spool_dir).unwrap().with_host("hostB").with_worker("wB#0");
        assert_eq!(a.serve_one().unwrap().as_deref(), Some(ids[i][0].as_str()));
        assert_eq!(a.serve_one().unwrap().as_deref(), Some(ids[i][1].as_str()));
        assert_eq!(b.serve_one().unwrap().as_deref(), Some(ids[i][2].as_str()));
        assert_eq!(b.serve_one().unwrap().as_deref(), Some(ids[i][3].as_str()));
    }

    // `wait` output: identical modulo the job ids themselves
    let mut waits = Vec::new();
    for spool_dir in &spools {
        let out = elaps_cmd(&[
            "wait", "--campaign", "camp", "--spool", spool_dir.to_str().unwrap(), "--timeout",
            "60s",
        ])
        .output()
        .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        waits.push(String::from_utf8_lossy(&out.stdout).into_owned());
    }
    assert_eq!(after_id(&waits[0]), after_id(&waits[1]), "wait output must match");
    assert!(waits[0].contains("4 ok, 0 error"), "{}", waits[0]);

    // `spool status --json`: byte-identical between the ledger path
    // and the directory-scan path, on either spool — and stable across
    // repeat calls (the status cache must not drift)
    let status_json = |spool_dir: &Path, extra: &[&str]| -> String {
        let mut argv = vec!["spool", "status", "--spool", spool_dir.to_str().unwrap(), "--json"];
        argv.extend_from_slice(extra);
        let out = elaps_cmd(&argv).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let reference = status_json(&spools[0], &[]);
    assert_eq!(reference, status_json(&spools[0], &["--no-ledger"]));
    assert_eq!(reference, status_json(&spools[1], &[]));
    assert_eq!(reference, status_json(&spools[1], &["--no-ledger"]));
    assert_eq!(reference, status_json(&spools[0], &[]), "cached status must not drift");
    assert!(reference.contains("hostA"), "{reference}");

    // the reports themselves: byte-identical (normalized) to a serial
    // run_local of the same experiments, in both spools
    for (which, spool_dir) in spools.iter().enumerate() {
        for (id, exp) in ids[which].iter().zip(&exps) {
            let raw = std::fs::read_to_string(
                spool_dir.join("done").join(format!("{id}.report.json")),
            )
            .unwrap();
            let report = io::report_from_json(&Json::parse(&raw).unwrap()).unwrap();
            let reference = normalize(&elaps::coordinator::run_local(exp).unwrap());
            assert_eq!(normalize(&report), reference, "{id}");
        }
        assert_eq!(count_json(spool_dir, "leases"), 0);
        assert_eq!(count_json(spool_dir, "done"), 4);
    }

    // compaction folds the ledger into its snapshot; archival moves
    // the log away without orphaning the campaign
    let compact = |extra: &[&str]| -> String {
        let mut argv =
            vec!["spool", "compact", "--campaign", "camp", "--spool", spools[0].to_str().unwrap()];
        argv.extend_from_slice(extra);
        let out = elaps_cmd(&argv).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    assert!(compact(&[]).contains("folded"));
    assert!(compact(&["--archive"]).contains("archived"));
    assert!(!ledger::ledger_path(&spools[0], "camp").is_file());
    assert!(spools[0].join("ledger").join("archive").join("camp.log").is_file());
    assert!(ledger::has_ledger(&spools[0], "camp"), "the snapshot outlives the log");
    assert_eq!(ledger::campaign_jobs_resolved(&spools[0], "camp", true).unwrap(), ids[0]);
    let out = elaps_cmd(&[
        "wait", "--campaign", "camp", "--spool", spools[0].to_str().unwrap(), "--timeout", "10s",
    ])
    .output()
    .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        after_id(&waits[0]),
        after_id(&String::from_utf8_lossy(&out.stdout)),
        "archived campaign answers wait unchanged"
    );
    // archiving again is a refusal, not an error
    assert!(compact(&["--archive"]).contains("kept"));
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- retry exactly-once

#[test]
fn retry_resubmits_each_error_exactly_once_then_dead_letters() {
    det_config();
    let dir = tmpdir("retry");
    let spool =
        Spooler::new(&dir).unwrap().with_host("hostR").with_worker("wR#0").with_events(true);
    let spool_s = dir.to_str().unwrap().to_string();
    // the poison experiment parses fine but fails at run time (unknown
    // library), publishing an error report + error stamp
    let mut poison = small_exp(12);
    poison.library = "essl".into();
    let ids = ledger::submit_experiments(&spool, "cr", &[small_exp(10), poison]).unwrap();
    assert_eq!(ids.len(), 2);
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(ids[0].as_str()));
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(ids[1].as_str()));
    assert_eq!(campaign::read_stamp(&dir, &ids[0]).unwrap().outcome, StampOutcome::Ok);
    assert_eq!(campaign::read_stamp(&dir, &ids[1]).unwrap().outcome, StampOutcome::Error);

    // first retry: exactly one resubmission, new id printed on stdout
    let out = elaps_cmd(&["retry", "--campaign", "cr", "--spool", &spool_s]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let new_ids = stdout_lines(&out);
    assert_eq!(new_ids.len(), 1, "{new_ids:?}");
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("1 resubmitted, 0 dead-lettered, 0 unrecoverable"), "{err}");
    assert_eq!(count_json(&dir, "queue"), 1);

    // durable exactly-once: an immediate second retry is a no-op (the
    // `retried` fact marks the failure as replaced)
    let out = elaps_cmd(&["retry", "--campaign", "cr", "--spool", &spool_s]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout_lines(&out).is_empty());
    assert!(String::from_utf8_lossy(&out.stderr).contains("0 resubmitted"), "no double retry");

    // the retry job joined the campaign and fails the same way
    assert_eq!(
        ledger::campaign_jobs_resolved(&dir, "cr", true).unwrap(),
        vec![ids[0].clone(), ids[1].clone(), new_ids[0].clone()]
    );
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(new_ids[0].as_str()));
    assert_eq!(campaign::read_stamp(&dir, &new_ids[0]).unwrap().outcome, StampOutcome::Error);

    // at --max-attempts 2 the chain is out of budget: dead-letter
    let out = elaps_cmd(&[
        "retry", "--campaign", "cr", "--max-attempts", "2", "--spool", &spool_s,
    ])
    .output()
    .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout_lines(&out).is_empty());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("0 resubmitted, 1 dead-lettered"), "{err}");
    assert_eq!(count_json(&dir, "queue"), 0, "a dead-lettered job is not resubmitted");

    // the dead-letter listing, text and JSON
    let out = elaps_cmd(&["spool", "dead-letter", "--campaign", "cr", "--spool", &spool_s])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains(new_ids[0].as_str()), "{text}");
    assert!(text.contains("attempt 2"), "{text}");
    let out = elaps_cmd(&[
        "spool", "dead-letter", "--campaign", "cr", "--spool", &spool_s, "--json",
    ])
    .output()
    .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let arr = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let arr = arr.as_arr().unwrap().to_vec();
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("job_id").as_str(), Some(new_ids[0].as_str()));
    assert_eq!(arr[0].get("retry_of").as_str(), Some(ids[1].as_str()));
    assert_eq!(arr[0].get("dead").as_bool(), Some(true));

    // the whole chain passes the exactly-once publish audit
    let out = elaps_cmd(&["analyze", "--campaign", "cr", "--spool", &spool_s, "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("audit").get("ok").as_bool(), Some(true), "{j:?}");
    assert_eq!(j.get("audit").get("done").as_u64(), Some(3), "{j:?}");

    // wait surfaces the campaign's error outcomes and exits nonzero
    let out = elaps_cmd(&["wait", "--campaign", "cr", "--spool", &spool_s, "--timeout", "10s"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("error (host hostR"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- cross-process lease cap

#[test]
fn max_leases_cap_holds_across_two_worker_processes() {
    det_config();
    let dir = tmpdir("cap2p");
    let submitter = Spooler::new(&dir).unwrap();
    let total = 12usize;
    for i in 0..total {
        submitter.submit(&small_exp(10 + 2 * (i as i64 % 4))).unwrap();
    }
    let spool_s = dir.to_str().unwrap().to_string();
    // two worker *processes* share one simulated host and one cap: the
    // regression is the window where each process's private estimate
    // let the pair momentarily exceed the cap together
    let spawn = || {
        let mut cmd = elaps_cmd(&[
            "worker", "--spool", &spool_s, "--once", "--workers", "2", "--max-leases", "2",
            "--seed", "7",
        ]);
        cmd.env("ELAPS_HOST", "capH");
        cmd.spawn().unwrap()
    };
    let stop = AtomicBool::new(false);
    let max_seen = std::thread::scope(|s| {
        let observer = s.spawn(|| {
            let mut worst = 0;
            while !stop.load(Ordering::Relaxed) {
                worst = worst.max(lease::live_leases_for_host(&dir, "capH").unwrap());
                std::thread::sleep(Duration::from_millis(1));
            }
            worst
        });
        let mut p1 = spawn();
        let mut p2 = spawn();
        assert!(p1.wait().unwrap().success());
        assert!(p2.wait().unwrap().success());
        stop.store(true, Ordering::Relaxed);
        observer.join().unwrap()
    });
    // the cap held at every observation point, across both processes
    assert!(max_seen <= 2, "host capH held {max_seen} live leases");
    // no deadlock, no starvation, exactly once
    assert_eq!(count_json(&dir, "done"), total);
    assert_eq!(count_json(&dir, "queue"), 0);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0);
    assert_eq!(lease::live_leases_for_host(&dir, "capH").unwrap(), 0);
    let scan = campaign::read_stamps(&dir);
    assert_eq!(scan.stamps.len(), total);
    assert_eq!(scan.skipped, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------ locked campaign reads

#[test]
fn campaign_readers_see_only_whole_batch_consistent_snapshots() {
    det_config();
    let dir = tmpdir("rw");
    let w1 = Spooler::new(&dir).unwrap().with_events(false);
    let w2 = Spooler::new(&dir).unwrap().with_events(false);
    let done = AtomicBool::new(false);
    // two submitters race whole-batch merges on one tag while a reader
    // polls the record the way `elaps wait --campaign` does — the
    // regression is the unlocked read racing the read-merge-write
    let (mut all_a, mut all_b, reads) = std::thread::scope(|s| {
        let wa = s.spawn(|| {
            let mut out = Vec::new();
            for _ in 0..8 {
                let batch = [small_exp(10), small_exp(12)];
                out.extend(campaign::submit_experiments(&w1, Some("rw"), &batch).unwrap());
            }
            out
        });
        let wb = s.spawn(|| {
            let mut out = Vec::new();
            for _ in 0..8 {
                let batch = [small_exp(14), small_exp(16)];
                out.extend(campaign::submit_experiments(&w2, Some("rw"), &batch).unwrap());
            }
            out
        });
        let reader = s.spawn(|| {
            let mut reads: Vec<Vec<String>> = Vec::new();
            while !done.load(Ordering::Relaxed) {
                match campaign::campaign_jobs(&dir, "rw") {
                    Ok(ids) => reads.push(ids),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(msg.contains("no campaign"), "torn campaign read: {msg}");
                    }
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            reads
        });
        let all_a = wa.join().unwrap();
        let all_b = wb.join().unwrap();
        done.store(true, Ordering::Relaxed);
        (all_a, all_b, reader.join().unwrap())
    });
    let final_ids = campaign::campaign_jobs(&dir, "rw").unwrap();
    // no lost updates: every id from both writers, exactly once
    assert_eq!(final_ids.len(), 32, "{final_ids:?}");
    let mut want: Vec<String> = Vec::new();
    want.append(&mut all_a);
    want.append(&mut all_b);
    want.sort();
    let mut got = final_ids.clone();
    got.sort();
    assert_eq!(got, want, "merges must not drop concurrent batches");
    // every snapshot a reader saw is whole-batch and order-consistent
    // with the final record (merges append, never reorder)
    let mut prev_len = 0usize;
    for ids in &reads {
        assert_eq!(ids.len() % 2, 0, "reader saw a half-merged batch: {ids:?}");
        assert!(ids.len() >= prev_len, "campaign record shrank under a reader");
        prev_len = ids.len();
        let mut fin = final_ids.iter();
        for id in ids {
            assert!(
                fin.any(|f| f == id),
                "snapshot not an ordered subsequence of the final record: {id}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
