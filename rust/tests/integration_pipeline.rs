//! Integration: the full coordinator → sampler → report pipeline over
//! in-process samplers, exercising ranges, repetitions, vary, OpenMP
//! groups, counters, serialization and the batch spooler together.

use elaps::coordinator::{
    io, run_local, DataGen, Experiment, Expr, Metric, RangeDef, Spooler, Stat, Vary,
};
use elaps::figures::call;
use elaps::util::json::Json;

fn dgemm_exp(n: i64, lib: &str) -> Experiment {
    let ns = n.to_string();
    Experiment {
        name: format!("it-dgemm-{lib}"),
        library: lib.into(),
        nreps: 3,
        calls: vec![call(
            "dgemm",
            &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
        )
        .unwrap()],
        ..Default::default()
    }
}

#[test]
fn all_rust_libraries_run_the_same_experiment() {
    for lib in elaps::libraries::RUST_LIBRARIES {
        let report = run_local(&dgemm_exp(48, lib)).unwrap();
        let g = report.series(Metric::Gflops, Stat::Median)[0].1;
        assert!(g > 0.01, "{lib}: {g}");
    }
}

#[test]
fn sequence_breakdown_sums_to_rep_total() {
    let mut exp = dgemm_exp(64, "rustblocked");
    exp.calls = vec![
        call("dgetrf", &["64", "64", "$A", "64"]).unwrap(),
        call("dtrsm", &["L", "L", "N", "U", "64", "8", "1.0", "$A", "64", "$B", "64"]).unwrap(),
        call("dtrsm", &["L", "U", "N", "N", "64", "8", "1.0", "$A", "64", "$B", "64"]).unwrap(),
    ];
    let report = run_local(&exp).unwrap();
    let breakdown = &report.call_breakdown(Stat::Avg)[0];
    assert_eq!(breakdown.len(), 3);
    assert!(breakdown[0].0.starts_with("dgetrf"));
    let sum: f64 = breakdown.iter().map(|(_, v)| v).sum();
    let total = report.series(Metric::TimeS, Stat::Avg)[0].1;
    assert!((sum - total).abs() < 1e-9 * total.max(1.0), "{sum} vs {total}");
}

#[test]
fn parameter_range_and_sumrange_compose() {
    let mut exp = dgemm_exp(0, "rustblocked");
    exp.range = Some(RangeDef::new("n", vec![16, 32]));
    exp.sumrange = Some(RangeDef::new("i", vec![0, 1, 2]));
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
    )
    .unwrap()];
    exp.vary.insert("C".into(), Vary { with_sumrange: true, ..Default::default() });
    let report = run_local(&exp).unwrap();
    assert_eq!(report.points.len(), 2);
    assert_eq!(report.points[0].sum_iters, 3);
    // 3 reps × 3 iters × 1 call
    assert_eq!(report.points[0].records.len(), 9);
    // flops of one rep at n: 3 gemms
    let f16 = report.rep_flops(&report.points[0], 0);
    assert_eq!(f16, 3.0 * 2.0 * 16f64.powi(3));
}

#[test]
fn omp_group_reduction_parallelizes() {
    let mut exp = dgemm_exp(48, "rustblocked");
    exp.machine = "sandybridge".into(); // 8 cores for the model
    exp.omp = true;
    exp.sumrange = Some(RangeDef::new("i", (0..8).collect()));
    exp.vary.insert("C".into(), Vary { with_sumrange: true, ..Default::default() });
    let report = run_local(&exp).unwrap();
    let point = &report.points[0];
    let serial: f64 = point.records[..8].iter().map(|r| r.seconds).sum();
    let wall = report.rep_seconds(point, 0);
    assert!(
        wall < serial * 0.6,
        "omp wall {wall} should be well below serial {serial}"
    );
    // records carry the group tag
    assert!(point.records[0].omp_group.is_some());
}

#[test]
fn counters_flow_end_to_end() {
    let mut exp = dgemm_exp(32, "rustblocked");
    exp.counters = vec!["PAPI_L1_TCM".into(), "PAPI_BR_MSP".into()];
    let report = run_local(&exp).unwrap();
    let misses = report.series(Metric::Counter(0), Stat::Max)[0].1;
    assert!(misses > 0.0);
}

#[test]
fn spd_datagen_supports_factorizations() {
    let mut exp = dgemm_exp(40, "rustblocked");
    exp.calls =
        vec![call("dpotrf", &["L", "40", "$M", "40"]).unwrap()];
    exp.datagen.insert("M".into(), DataGen::Spd(Expr::Const(40)));
    // fresh SPD matrix every repetition (potrf destroys it)
    exp.vary.insert("M".into(), Vary { with_rep: true, ..Default::default() });
    let report = run_local(&exp).unwrap();
    assert_eq!(report.points[0].records.len(), 3);
}

#[test]
fn experiment_files_round_trip_through_disk() {
    let mut exp = dgemm_exp(24, "rustref");
    exp.range = Some(RangeDef::span("n", 16, 8, 32));
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
    )
    .unwrap()];
    let dir = std::env::temp_dir().join(format!("elaps-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(&path, io::experiment_to_json(&exp).to_string_pretty()).unwrap();
    let loaded =
        io::experiment_from_json(&Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap())
            .unwrap();
    let report = run_local(&loaded).unwrap();
    assert_eq!(report.points.len(), 3);
    // report file round trip preserves series
    let rpath = dir.join("report.json");
    std::fs::write(&rpath, io::report_to_json(&report).to_string_pretty()).unwrap();
    let report2 =
        io::report_from_json(&Json::parse(&std::fs::read_to_string(&rpath).unwrap()).unwrap())
            .unwrap();
    let s1 = report.series(Metric::Gflops, Stat::Median);
    let s2 = report2.series(Metric::Gflops, Stat::Median);
    for (a, b) in s1.iter().zip(&s2) {
        assert_eq!(a.0, b.0);
        assert!((a.1 - b.1).abs() < 1e-12);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_spooler_matches_local_shape() {
    let dir = std::env::temp_dir().join(format!("elaps-it-spool-{}", std::process::id()));
    let spool = Spooler::new(&dir).unwrap();
    let exp = dgemm_exp(32, "rustblocked");
    let via_queue = spool.run_through_queue(&exp).unwrap();
    let local = run_local(&exp).unwrap();
    assert_eq!(via_queue.points.len(), local.points.len());
    assert_eq!(via_queue.points[0].records.len(), local.points[0].records.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eigensolver_drivers_through_pipeline() {
    for driver in ["dsyev", "dsyevd", "dsyevx", "dsyevr"] {
        let mut exp = dgemm_exp(0, "rustref");
        exp.calls = vec![call(driver, &["V", "L", "24", "$A", "24", "$W"]).unwrap()];
        exp.datagen.insert("A".into(), DataGen::Spd(Expr::Const(24)));
        exp.vary.insert("A".into(), Vary { with_rep: true, ..Default::default() });
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points[0].records.len(), 3, "{driver}");
    }
}

#[test]
fn failure_surfaces_cleanly_not_panics() {
    // non-SPD input to dposv must produce an error result, not a panic
    let mut exp = dgemm_exp(16, "rustblocked");
    exp.calls = vec![call("dposv", &["L", "16", "1", "$M", "16", "$b", "16"]).unwrap()];
    // default datagen is uniform random — NOT positive definite
    let err = run_local(&exp).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("positive definite"), "{msg}");
}

#[test]
fn thread_range_reports_scaled_series() {
    let mut exp = dgemm_exp(48, "rustblocked");
    exp.machine = "sandybridge".into();
    exp.range = Some(RangeDef::span("t", 1, 1, 4));
    exp.nthreads = Expr::sym("t");
    let report = run_local(&exp).unwrap();
    let times = report.series(Metric::TimeS, Stat::Median);
    assert_eq!(times.len(), 4);
    // modeled: more threads, less time (dgemm pf = 0.98)
    assert!(times[3].1 < times[0].1);
    // efficiency accounts for the bigger peak at t=4
    let eff = report.series(Metric::Efficiency, Stat::Median);
    assert!(eff[3].1 < eff[0].1 * 1.5);
}
