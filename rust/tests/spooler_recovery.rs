//! Regression tests for the spooler's crashed-worker recovery path
//! (PR 1's hardening, previously without dedicated coverage): a
//! crashed worker's claimed job is requeued exactly once, recovery
//! racing live workers never duplicates or loses jobs, and reports are
//! only ever published atomically (no partial files visible in done/).
//!
//! Since the lease protocol replaced mtime staleness, this file also
//! pins the equivalence contract: legacy claims (a `running/` file
//! with no lease) still recover exactly as the old mtime heuristic
//! did, while leased claims ignore mtimes entirely and reclaim only on
//! lease expiry.

use elaps::coordinator::{lease, Experiment, Spooler};
use elaps::figures::call;
use std::time::Duration;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_recover_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_exp(n: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: format!("rec{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

/// Count the spool files under a subdirectory, by extension.
fn count(dir: &std::path::Path, sub: &str, ext: &str) -> usize {
    std::fs::read_dir(dir.join(sub))
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == ext))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn crashed_claim_is_requeued_exactly_once() {
    let dir = tmpdir("once");
    let spool = Spooler::new(&dir).unwrap();
    let id = spool.submit(&small_exp(16)).unwrap();
    // simulate a worker that claimed the job and died
    std::fs::rename(
        dir.join("queue").join(format!("{id}.json")),
        dir.join("running").join(format!("{id}.json")),
    )
    .unwrap();
    assert_eq!(spool.queued().unwrap(), 0);
    // first recovery requeues it…
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 1);
    assert_eq!(spool.queued().unwrap(), 1);
    assert_eq!(count(&dir, "running", "json"), 0);
    // …the second finds nothing: exactly once, no duplicate copies
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 0);
    assert_eq!(spool.queued().unwrap(), 1);
    // the recovered job runs and publishes exactly one report
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
    assert!(spool.fetch(&id).unwrap().is_some());
    assert_eq!(count(&dir, "done", "json"), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_recovery_and_drain_neither_lose_nor_duplicate_jobs() {
    let dir = tmpdir("race");
    let spool = Spooler::new(&dir).unwrap();
    let ids: Vec<String> =
        (0..6).map(|i| spool.submit(&small_exp(12 + 4 * i)).unwrap()).collect();
    // strand every job in running/, as if a whole pool crashed
    for id in &ids {
        std::fs::rename(
            dir.join("queue").join(format!("{id}.json")),
            dir.join("running").join(format!("{id}.json")),
        )
        .unwrap();
    }
    // two recoverers race each other and a pool of workers draining
    // whatever reappears in the queue
    let total_recovered = std::thread::scope(|s| {
        let r1 = s.spawn(|| spool.recover_stale(Duration::ZERO).unwrap());
        let r2 = s.spawn(|| spool.recover_stale(Duration::ZERO).unwrap());
        r1.join().unwrap() + r2.join().unwrap()
    });
    assert_eq!(total_recovered, 6, "each job requeued exactly once across racers");
    let served = spool.drain(3).unwrap();
    assert_eq!(served, 6);
    for id in &ids {
        assert!(spool.fetch(id).unwrap().is_some(), "{id}");
    }
    // nothing left anywhere, and no half-published reports
    assert_eq!(spool.queued().unwrap(), 0);
    assert_eq!(count(&dir, "running", "json"), 0);
    assert_eq!(count(&dir, "done", "json"), 6);
    assert_eq!(count(&dir, "done", "tmp"), 0, "publish must be atomic");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_claims_recover_by_mtime_exactly_like_the_old_protocol() {
    // a pre-lease worker's crash leaves a claim file with no lease:
    // recover_stale must treat it exactly as the old mtime heuristic
    // did — fresh claims survive a generous max_age, zero tolerance
    // reclaims, and the reclaim happens exactly once
    let dir = tmpdir("legacy_equiv");
    let spool = Spooler::new(&dir).unwrap();
    let id = spool.submit(&small_exp(16)).unwrap();
    std::fs::rename(
        dir.join("queue").join(format!("{id}.json")),
        dir.join("running").join(format!("{id}.json")),
    )
    .unwrap();
    assert!(lease::read(&dir, &id).is_none(), "a legacy claim has no lease");
    // the lease-only reclaim never touches it, at any age
    assert_eq!(spool.reclaim_expired().unwrap(), 0);
    // the mtime heuristic behaves exactly as before the lease protocol
    assert_eq!(spool.recover_stale(Duration::from_secs(3600)).unwrap(), 0);
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 1);
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 0, "exactly once");
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
    assert!(spool.fetch(&id).unwrap().is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn leased_claims_ignore_mtimes_and_reclaim_only_on_expiry() {
    // the behavioral difference the lease protocol buys: a live claim
    // is safe from reclaim even under the paranoid legacy tolerance of
    // zero (on NFS, mtime-based staleness would have stolen it), and
    // reclaim leaves the lease file behind so the next acquisition
    // bumps the fencing epoch
    let dir = tmpdir("lease_equiv");
    // generous TTL so the "mtimes are irrelevant" probe below cannot
    // race a slow test host into real expiry
    let ttl = Duration::from_millis(1500);
    let spool = Spooler::new(&dir).unwrap().with_ttl(ttl);
    let id = spool.submit(&small_exp(16)).unwrap();
    let claim = spool.claim_next().unwrap().unwrap();
    assert_eq!(claim.lease.epoch, 1);
    // mtimes are irrelevant for leased claims
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 0);
    // wait out the lease, then the same call reclaims
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while lease::now_unix() <= claim.lease.expires_unix + 0.05 {
        assert!(std::time::Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 1);
    assert_eq!(spool.recover_stale(Duration::ZERO).unwrap(), 0, "exactly once");
    // the lease survived the reclaim and fences the next acquisition
    assert_eq!(lease::read(&dir, &id).unwrap().epoch, 1);
    let reclaimed = spool.claim_next().unwrap().unwrap();
    assert_eq!(reclaimed.lease.epoch, 2, "epoch chains across reclaims");
    assert!(spool.serve_claim(&reclaimed, false).unwrap().published());
    assert!(spool.fetch(&id).unwrap().is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_is_published_atomically_even_when_job_runs_twice() {
    // at-least-once semantics: a job recovered while still running is
    // executed twice; both publishes are whole-file renames, so readers
    // only ever see one complete report
    let dir = tmpdir("twice");
    let spool = Spooler::new(&dir).unwrap();
    let id = spool.submit(&small_exp(16)).unwrap();
    // first execution
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
    let first = spool.fetch(&id).unwrap().unwrap();
    // resubmit the same job file into the queue, as recover_stale would
    // for a worker presumed dead that actually finishes
    std::fs::write(
        dir.join("queue").join(format!("{id}.json")),
        elaps::coordinator::io::experiment_to_json(&small_exp(16)).to_string_pretty(),
    )
    .unwrap();
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
    let second = spool.fetch(&id).unwrap().unwrap();
    // last writer wins; both are complete, well-formed reports
    assert_eq!(first.points.len(), second.points.len());
    assert_eq!(first.points[0].records.len(), second.points[0].records.len());
    assert_eq!(count(&dir, "done", "json"), 1);
    assert_eq!(count(&dir, "done", "tmp"), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
