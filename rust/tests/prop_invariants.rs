//! Property-based tests (via the from-scratch harness in
//! `elaps::util::prop` — the offline registry has no proptest) over
//! coordinator and linalg invariants:
//!
//! * unrolling: record counts, operand sizing, instance naming
//! * routing: every call reaches the right kernel with the right shape
//! * state: report reduction is permutation/semantics-consistent
//! * linalg: solve∘multiply = identity, factor∘reconstruct = identity

use elaps::coordinator::{run_local, Experiment, Metric, RangeDef, Stat, Vary};
use elaps::engine::shard_contiguous;
use elaps::figures::call;
use elaps::linalg::blas3::{dgemm_blocked, dgemm_naive, dtrsm_blocked, dtrmm};
use elaps::linalg::{Diag, Matrix, Side, Trans, Uplo};
use elaps::util::prop::{all_close, forall};
use elaps::util::rng::Xoshiro256;

#[test]
fn prop_gemm_blocked_equals_naive_any_shape() {
    forall(
        0xA1,
        40,
        |r, size| {
            let m = r.range_usize(1, 8 + size * 6);
            let n = r.range_usize(1, 8 + size * 6);
            let k = r.range_usize(1, 8 + size * 6);
            let seed = r.next_u64();
            (m, n, k, seed)
        },
        |&(m, n, k, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let c0 = Matrix::random(m, n, &mut rng);
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            dgemm_naive(
                Trans::No, Trans::No, m, n, k, 1.3, &a.data, m, &b.data, k, 0.7,
                &mut c1.data, m,
            );
            dgemm_blocked(
                Trans::No, Trans::No, m, n, k, 1.3, &a.data, m, &b.data, k, 0.7,
                &mut c2.data, m,
            );
            all_close(&c1.data, &c2.data, 1e-10 * k as f64)
        },
    );
}

#[test]
fn prop_trsm_inverts_trmm() {
    forall(
        0xA2,
        30,
        |r, size| {
            let n = r.range_usize(1, 4 + size * 4);
            let nrhs = r.range_usize(1, 6);
            let side = if r.chance(0.5) { Side::Left } else { Side::Right };
            let uplo = if r.chance(0.5) { Uplo::Lower } else { Uplo::Upper };
            let trans = if r.chance(0.5) { Trans::No } else { Trans::Yes };
            let nb = r.range_usize(1, 9);
            (n, nrhs, side, uplo, trans, nb, r.next_u64())
        },
        |&(n, nrhs, side, uplo, trans, nb, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a = Matrix::random_triangular(n, uplo, &mut rng);
            let (m_b, n_b) = match side {
                Side::Left => (n, nrhs),
                Side::Right => (nrhs, n),
            };
            let x = Matrix::random(m_b, n_b, &mut rng);
            let mut bmat = x.clone();
            dtrmm(side, uplo, trans, Diag::NonUnit, m_b, n_b, 1.0, &a.data, n, &mut bmat.data, m_b);
            dtrsm_blocked(
                side, uplo, trans, Diag::NonUnit, m_b, n_b, 1.0, &a.data, n,
                &mut bmat.data, m_b, nb,
            );
            all_close(&bmat.data, &x.data, 1e-8)
        },
    );
}

#[test]
fn prop_getrf_solve_recovers_rhs() {
    forall(
        0xA3,
        25,
        |r, size| (r.range_usize(2, 8 + size * 3), r.range_usize(1, 5), r.next_u64()),
        |&(n, nrhs, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a0 = Matrix::random_spd(n, &mut rng);
            let x = Matrix::random(n, nrhs, &mut rng);
            let b0 = a0.matmul(&x);
            let mut a = a0.clone();
            let mut b = b0.clone();
            let mut ipiv = vec![0usize; n];
            elaps::linalg::lapack::dgesv(n, nrhs, &mut a.data, n, &mut ipiv, &mut b.data, n)
                .map_err(|e| e.to_string())?;
            all_close(&b.data, &x.data, 1e-7)
        },
    );
}

#[test]
fn prop_unroll_record_count_always_matches() {
    forall(
        0xB1,
        30,
        |r, _| {
            let nreps = r.range_usize(1, 4);
            let npoints = r.range_usize(1, 3);
            let sum_iters = r.range_usize(1, 3);
            let vary_rep = r.chance(0.5);
            let vary_sum = r.chance(0.5);
            let omp = r.chance(0.3);
            (nreps, npoints, sum_iters, vary_rep, vary_sum, omp)
        },
        |&(nreps, npoints, sum_iters, vary_rep, vary_sum, omp)| {
            let mut exp = Experiment {
                name: "prop".into(),
                library: "rustblocked".into(),
                nreps,
                omp,
                range: Some(RangeDef::new("n", (1..=npoints as i64).map(|v| v * 8).collect())),
                sumrange: Some(RangeDef::new("i", (0..sum_iters as i64).collect())),
                calls: vec![call(
                    "dgemm",
                    &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            exp.vary.insert(
                "C".into(),
                Vary { with_rep: vary_rep, with_sumrange: vary_sum, pad_elems: 0 },
            );
            let report = run_local(&exp).map_err(|e| format!("{e:#}"))?;
            if report.points.len() != npoints {
                return Err(format!("{} points, want {npoints}", report.points.len()));
            }
            for p in &report.points {
                let want = nreps * sum_iters;
                if p.records.len() != want {
                    return Err(format!("{} records, want {want}", p.records.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_report_stats_invariants() {
    // min ≤ med ≤ max and avg within [min, max] for every metric series
    forall(
        0xB2,
        15,
        |r, _| (r.range_usize(2, 6), r.next_u64() % 32 + 8),
        |&(nreps, n)| {
            let ns = n.to_string();
            let exp = Experiment {
                name: "stats".into(),
                library: "rustref".into(),
                nreps,
                calls: vec![call(
                    "dgemm",
                    &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            let report = run_local(&exp).map_err(|e| format!("{e:#}"))?;
            for metric in [Metric::TimeS, Metric::Gflops, Metric::Cycles] {
                let lo = report.series(metric, Stat::Min)[0].1;
                let hi = report.series(metric, Stat::Max)[0].1;
                let med = report.series(metric, Stat::Median)[0].1;
                let avg = report.series(metric, Stat::Avg)[0].1;
                if !(lo <= med && med <= hi && lo <= avg && avg <= hi) {
                    return Err(format!("{metric:?}: {lo} {med} {avg} {hi}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vary_instances_never_alias() {
    // when C varies per rep, the unrolled script must reference a
    // distinct instance in every repetition
    forall(
        0xB3,
        20,
        |r, _| (r.range_usize(2, 5), r.range_usize(1, 3)),
        |&(nreps, sum_iters)| {
            let mut exp = Experiment {
                name: "alias".into(),
                library: "rustblocked".into(),
                nreps,
                sumrange: Some(RangeDef::new("i", (0..sum_iters as i64).collect())),
                calls: vec![call(
                    "dgemm",
                    &["N", "N", "8", "8", "8", "1.0", "$A", "8", "$B", "8", "0.0", "$C", "8"],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            exp.vary.insert(
                "C".into(),
                Vary { with_rep: true, with_sumrange: true, pad_elems: 0 },
            );
            let pts = exp.unroll().map_err(|e| format!("{e:#}"))?;
            let script = &pts[0].script;
            let mut seen = std::collections::BTreeSet::new();
            for line in script.lines().filter(|l| l.starts_with("dgemm")) {
                let cop = line.split_whitespace().nth(12).unwrap().to_string();
                seen.insert(cop);
            }
            let want = nreps * sum_iters;
            if want > 1 {
                if seen.len() != want {
                    return Err(format!("{} distinct C instances, want {want}", seen.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_contiguous_partition_invariants() {
    // the warm engine's determinism contract rests on these: for
    // arbitrary (len, jobs) — including jobs > len and jobs = 0 —
    // concatenating the shards round-trips the input, the shard count
    // never exceeds jobs (jobs = 0 behaves as 1), shard sizes differ
    // by at most one, no shard is empty, and the split is a pure
    // function of its input
    forall(
        0xD1,
        200,
        |r, size| {
            let len = r.range_usize(0, 4 + size * 8);
            // cover jobs = 0, jobs in range, and jobs far above len
            let jobs = match r.below(3) {
                0 => 0,
                1 => r.range_usize(1, 8),
                _ => len + r.range_usize(1, 10),
            };
            (len, jobs)
        },
        |&(len, jobs)| {
            let items: Vec<usize> = (0..len).collect();
            let shards = shard_contiguous(items.clone(), jobs);
            let flat: Vec<usize> = shards.iter().flatten().copied().collect();
            if flat != items {
                return Err(format!("concatenation must round-trip: {shards:?}"));
            }
            if len == 0 {
                return if shards.is_empty() {
                    Ok(())
                } else {
                    Err(format!("empty input must yield no shards: {shards:?}"))
                };
            }
            let effective = jobs.max(1);
            if shards.len() > effective {
                return Err(format!("{} shards for jobs={jobs}", shards.len()));
            }
            if shards.len() != effective.min(len) {
                return Err(format!(
                    "{} shards, want min(max(jobs,1), len) = {}",
                    shards.len(),
                    effective.min(len)
                ));
            }
            let min = shards.iter().map(Vec::len).min().unwrap();
            let max = shards.iter().map(Vec::len).max().unwrap();
            if min == 0 {
                return Err(format!("no shard may be empty: {shards:?}"));
            }
            if max - min > 1 {
                return Err(format!("sizes must differ by ≤ 1: {shards:?}"));
            }
            if shards != shard_contiguous(items, jobs) {
                return Err("sharding must be deterministic".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eigenvalues_match_across_drivers() {
    use elaps::linalg::lapack::{dsyev, dsyevd, dsyevr, dsyevx};
    forall(
        0xC1,
        10,
        |r, size| (r.range_usize(3, 10 + size * 2), r.next_u64()),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a0 = Matrix::random_spd(n, &mut rng);
            let run = |f: fn(usize, &mut [f64], usize, bool) -> elaps::linalg::Result<elaps::linalg::lapack::eig::EigResult>| {
                let mut a = a0.clone();
                f(n, &mut a.data, n, false).map(|r| r.values).map_err(|e| e.to_string())
            };
            let v1 = run(dsyev)?;
            for f in [dsyevd as fn(usize, &mut [f64], usize, bool) -> _, dsyevx, dsyevr] {
                let v = run(f)?;
                all_close(&v1, &v, 1e-6)?;
            }
            Ok(())
        },
    );
}
