//! Property-based tests (via the from-scratch harness in
//! `elaps::util::prop` — the offline registry has no proptest) over
//! coordinator and linalg invariants:
//!
//! * unrolling: record counts, operand sizing, instance naming
//! * routing: every call reaches the right kernel with the right shape
//! * state: report reduction is permutation/semantics-consistent
//! * linalg: solve∘multiply = identity, factor∘reconstruct = identity

use elaps::coordinator::campaign::{
    read_stamps, write_stamp, CampaignManifest, ManifestEntry, Stamp, StampOutcome,
};
use elaps::coordinator::{run_local, Experiment, Metric, RangeDef, Stat, Vary};
use elaps::engine::shard_contiguous;
use elaps::figures::call;
use elaps::util::json::Json;
use elaps::linalg::blas3::{dgemm_blocked, dgemm_naive, dtrsm_blocked, dtrmm};
use elaps::linalg::{Diag, Matrix, Side, Trans, Uplo};
use elaps::util::prop::{all_close, forall};
use elaps::util::rng::Xoshiro256;

#[test]
fn prop_gemm_blocked_equals_naive_any_shape() {
    forall(
        0xA1,
        40,
        |r, size| {
            let m = r.range_usize(1, 8 + size * 6);
            let n = r.range_usize(1, 8 + size * 6);
            let k = r.range_usize(1, 8 + size * 6);
            let seed = r.next_u64();
            (m, n, k, seed)
        },
        |&(m, n, k, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let c0 = Matrix::random(m, n, &mut rng);
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            dgemm_naive(
                Trans::No, Trans::No, m, n, k, 1.3, &a.data, m, &b.data, k, 0.7,
                &mut c1.data, m,
            );
            dgemm_blocked(
                Trans::No, Trans::No, m, n, k, 1.3, &a.data, m, &b.data, k, 0.7,
                &mut c2.data, m,
            );
            all_close(&c1.data, &c2.data, 1e-10 * k as f64)
        },
    );
}

#[test]
fn prop_trsm_inverts_trmm() {
    forall(
        0xA2,
        30,
        |r, size| {
            let n = r.range_usize(1, 4 + size * 4);
            let nrhs = r.range_usize(1, 6);
            let side = if r.chance(0.5) { Side::Left } else { Side::Right };
            let uplo = if r.chance(0.5) { Uplo::Lower } else { Uplo::Upper };
            let trans = if r.chance(0.5) { Trans::No } else { Trans::Yes };
            let nb = r.range_usize(1, 9);
            (n, nrhs, side, uplo, trans, nb, r.next_u64())
        },
        |&(n, nrhs, side, uplo, trans, nb, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a = Matrix::random_triangular(n, uplo, &mut rng);
            let (m_b, n_b) = match side {
                Side::Left => (n, nrhs),
                Side::Right => (nrhs, n),
            };
            let x = Matrix::random(m_b, n_b, &mut rng);
            let mut bmat = x.clone();
            dtrmm(side, uplo, trans, Diag::NonUnit, m_b, n_b, 1.0, &a.data, n, &mut bmat.data, m_b);
            dtrsm_blocked(
                side, uplo, trans, Diag::NonUnit, m_b, n_b, 1.0, &a.data, n,
                &mut bmat.data, m_b, nb,
            );
            all_close(&bmat.data, &x.data, 1e-8)
        },
    );
}

#[test]
fn prop_getrf_solve_recovers_rhs() {
    forall(
        0xA3,
        25,
        |r, size| (r.range_usize(2, 8 + size * 3), r.range_usize(1, 5), r.next_u64()),
        |&(n, nrhs, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a0 = Matrix::random_spd(n, &mut rng);
            let x = Matrix::random(n, nrhs, &mut rng);
            let b0 = a0.matmul(&x);
            let mut a = a0.clone();
            let mut b = b0.clone();
            let mut ipiv = vec![0usize; n];
            elaps::linalg::lapack::dgesv(n, nrhs, &mut a.data, n, &mut ipiv, &mut b.data, n)
                .map_err(|e| e.to_string())?;
            all_close(&b.data, &x.data, 1e-7)
        },
    );
}

#[test]
fn prop_unroll_record_count_always_matches() {
    forall(
        0xB1,
        30,
        |r, _| {
            let nreps = r.range_usize(1, 4);
            let npoints = r.range_usize(1, 3);
            let sum_iters = r.range_usize(1, 3);
            let vary_rep = r.chance(0.5);
            let vary_sum = r.chance(0.5);
            let omp = r.chance(0.3);
            (nreps, npoints, sum_iters, vary_rep, vary_sum, omp)
        },
        |&(nreps, npoints, sum_iters, vary_rep, vary_sum, omp)| {
            let mut exp = Experiment {
                name: "prop".into(),
                library: "rustblocked".into(),
                nreps,
                omp,
                range: Some(RangeDef::new("n", (1..=npoints as i64).map(|v| v * 8).collect())),
                sumrange: Some(RangeDef::new("i", (0..sum_iters as i64).collect())),
                calls: vec![call(
                    "dgemm",
                    &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            exp.vary.insert(
                "C".into(),
                Vary { with_rep: vary_rep, with_sumrange: vary_sum, pad_elems: 0 },
            );
            let report = run_local(&exp).map_err(|e| format!("{e:#}"))?;
            if report.points.len() != npoints {
                return Err(format!("{} points, want {npoints}", report.points.len()));
            }
            for p in &report.points {
                let want = nreps * sum_iters;
                if p.records.len() != want {
                    return Err(format!("{} records, want {want}", p.records.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_report_stats_invariants() {
    // min ≤ med ≤ max and avg within [min, max] for every metric series
    forall(
        0xB2,
        15,
        |r, _| (r.range_usize(2, 6), r.next_u64() % 32 + 8),
        |&(nreps, n)| {
            let ns = n.to_string();
            let exp = Experiment {
                name: "stats".into(),
                library: "rustref".into(),
                nreps,
                calls: vec![call(
                    "dgemm",
                    &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            let report = run_local(&exp).map_err(|e| format!("{e:#}"))?;
            for metric in [Metric::TimeS, Metric::Gflops, Metric::Cycles] {
                let lo = report.series(metric, Stat::Min)[0].1;
                let hi = report.series(metric, Stat::Max)[0].1;
                let med = report.series(metric, Stat::Median)[0].1;
                let avg = report.series(metric, Stat::Avg)[0].1;
                if !(lo <= med && med <= hi && lo <= avg && avg <= hi) {
                    return Err(format!("{metric:?}: {lo} {med} {avg} {hi}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vary_instances_never_alias() {
    // when C varies per rep, the unrolled script must reference a
    // distinct instance in every repetition
    forall(
        0xB3,
        20,
        |r, _| (r.range_usize(2, 5), r.range_usize(1, 3)),
        |&(nreps, sum_iters)| {
            let mut exp = Experiment {
                name: "alias".into(),
                library: "rustblocked".into(),
                nreps,
                sumrange: Some(RangeDef::new("i", (0..sum_iters as i64).collect())),
                calls: vec![call(
                    "dgemm",
                    &["N", "N", "8", "8", "8", "1.0", "$A", "8", "$B", "8", "0.0", "$C", "8"],
                )
                .map_err(|e| e.to_string())?],
                ..Default::default()
            };
            exp.vary.insert(
                "C".into(),
                Vary { with_rep: true, with_sumrange: true, pad_elems: 0 },
            );
            let pts = exp.unroll().map_err(|e| format!("{e:#}"))?;
            let script = &pts[0].script;
            let mut seen = std::collections::BTreeSet::new();
            for line in script.lines().filter(|l| l.starts_with("dgemm")) {
                let cop = line.split_whitespace().nth(12).unwrap().to_string();
                seen.insert(cop);
            }
            let want = nreps * sum_iters;
            if want > 1 {
                if seen.len() != want {
                    return Err(format!("{} distinct C instances, want {want}", seen.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_contiguous_partition_invariants() {
    // the warm engine's determinism contract rests on these: for
    // arbitrary (len, jobs) — including jobs > len and jobs = 0 —
    // concatenating the shards round-trips the input, the shard count
    // never exceeds jobs (jobs = 0 behaves as 1), shard sizes differ
    // by at most one, no shard is empty, and the split is a pure
    // function of its input
    forall(
        0xD1,
        200,
        |r, size| {
            let len = r.range_usize(0, 4 + size * 8);
            // cover jobs = 0, jobs in range, and jobs far above len
            let jobs = match r.below(3) {
                0 => 0,
                1 => r.range_usize(1, 8),
                _ => len + r.range_usize(1, 10),
            };
            (len, jobs)
        },
        |&(len, jobs)| {
            let items: Vec<usize> = (0..len).collect();
            let shards = shard_contiguous(items.clone(), jobs);
            let flat: Vec<usize> = shards.iter().flatten().copied().collect();
            if flat != items {
                return Err(format!("concatenation must round-trip: {shards:?}"));
            }
            if len == 0 {
                return if shards.is_empty() {
                    Ok(())
                } else {
                    Err(format!("empty input must yield no shards: {shards:?}"))
                };
            }
            let effective = jobs.max(1);
            if shards.len() > effective {
                return Err(format!("{} shards for jobs={jobs}", shards.len()));
            }
            if shards.len() != effective.min(len) {
                return Err(format!(
                    "{} shards, want min(max(jobs,1), len) = {}",
                    shards.len(),
                    effective.min(len)
                ));
            }
            let min = shards.iter().map(Vec::len).min().unwrap();
            let max = shards.iter().map(Vec::len).max().unwrap();
            if min == 0 {
                return Err(format!("no shard may be empty: {shards:?}"));
            }
            if max - min > 1 {
                return Err(format!("sizes must differ by ≤ 1: {shards:?}"));
            }
            if shards != shard_contiguous(items, jobs) {
                return Err("sharding must be deterministic".to_string());
            }
            Ok(())
        },
    );
}

/// A minimal dgemm experiment for manifest round-trips (the cfg(test)
/// `tests_support` helpers are not visible to integration tests).
fn manifest_exp(n: i64, nreps: usize) -> Experiment {
    let ns = n.to_string();
    Experiment {
        name: format!("mexp{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps,
        calls: vec![call(
            "dgemm",
            &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
        )
        .unwrap()],
        ..Default::default()
    }
}

#[test]
fn prop_campaign_manifest_parse_serialize_identity() {
    // parse ∘ serialize = id on the JSON form, for arbitrary mixes of
    // path entries and inline experiments under arbitrary tags
    forall(
        0xE1,
        40,
        |r, size| {
            const TAG_CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_.";
            // leading letter: a tag of dots alone ("."/"..") is
            // rejected by validate_tag, and rightly so
            let tag: String = std::iter::once('c')
                .chain((0..r.range_usize(0, 11)).map(|_| TAG_CHARS[r.below(TAG_CHARS.len())] as char))
                .collect();
            let entries: Vec<(bool, usize, usize)> = (0..r.range_usize(1, 2 + size.min(4)))
                .map(|_| (r.chance(0.5), r.range_usize(1, 64), r.range_usize(1, 4)))
                .collect();
            (tag, entries)
        },
        |(tag, entries)| {
            let m = CampaignManifest {
                campaign: tag.clone(),
                experiments: entries
                    .iter()
                    .map(|&(inline, n, nreps)| {
                        if inline {
                            ManifestEntry::Inline(manifest_exp(n as i64, nreps))
                        } else {
                            ManifestEntry::Path(format!("exp_{n}_{nreps}.json"))
                        }
                    })
                    .collect(),
            };
            let j = m.to_json();
            if !CampaignManifest::is_manifest(&j) {
                return Err("serialized manifest must be recognizable".into());
            }
            // through text and back: the round-trip is the identity
            let text = j.to_string_pretty();
            let reparsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let m2 = CampaignManifest::from_json(&reparsed).map_err(|e| format!("{e:#}"))?;
            if m2.campaign != *tag {
                return Err(format!("tag changed: {} vs {tag}", m2.campaign));
            }
            if m2.experiments.len() != entries.len() {
                return Err(format!("{} entries, want {}", m2.experiments.len(), entries.len()));
            }
            let j2 = m2.to_json();
            if j.to_string_compact() != j2.to_string_compact() {
                return Err(format!(
                    "parse ∘ serialize must be the identity:\n{}\nvs\n{}",
                    j.to_string_compact(),
                    j2.to_string_compact()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stamp_roundtrip_and_malformed_stamps_skipped() {
    use std::sync::atomic::{AtomicU64, Ordering};
    static CASE: AtomicU64 = AtomicU64::new(0);
    forall(
        0xE2,
        30,
        |r, size| {
            let valid: Vec<(usize, u64, bool)> = (0..r.range_usize(1, 3 + size.min(6)))
                .map(|i| (i, r.range_usize(1, 9) as u64, r.chance(0.8)))
                .collect();
            let corrupt = r.range_usize(1, 4);
            (valid, corrupt)
        },
        |(valid, corrupt)| {
            let dir = std::env::temp_dir().join(format!(
                "elaps_prop_stamps_{}_{}",
                std::process::id(),
                CASE.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let mut expect = std::collections::BTreeMap::new();
            for &(i, epoch, ok) in valid {
                let s = Stamp {
                    job_id: format!("job-{i}"),
                    host: format!("h{}", i % 3),
                    worker: format!("h{}#{}-{i}", i % 3, std::process::id()),
                    epoch,
                    outcome: if ok { StampOutcome::Ok } else { StampOutcome::Error },
                };
                // per-stamp JSON round-trip is the identity
                let back = Stamp::from_json(&s.to_json())
                    .ok_or("stamp JSON round-trip lost the stamp")?;
                if back != s {
                    return Err(format!("{back:?} != {s:?}"));
                }
                write_stamp(&dir, &s).map_err(|e| format!("{e:#}"))?;
                expect.insert(s.job_id.clone(), s);
            }
            // corrupt sidecars: truncated copies of a real stamp and
            // plain garbage, plus an unrelated file that is not a
            // stamp at all
            let template = expect.values().next().unwrap().to_json().to_string_pretty();
            for k in 0..*corrupt {
                let body = if k % 2 == 0 {
                    template[..template.len() / 2].to_string()
                } else {
                    "]]{ not json".to_string()
                };
                std::fs::write(
                    elaps::coordinator::campaign::stamp_path(&dir, &format!("corrupt-{k}")),
                    body,
                )
                .map_err(|e| e.to_string())?;
            }
            std::fs::write(dir.join("stamps").join("README.txt"), "not a stamp")
                .map_err(|e| e.to_string())?;
            // the scan returns exactly the valid stamps and counts
            // (never panics on) the malformed ones
            let scan = read_stamps(&dir);
            if scan.skipped != *corrupt {
                return Err(format!("skipped {} of {corrupt} corrupt", scan.skipped));
            }
            if scan.stamps != expect {
                return Err(format!("{:?} != {expect:?}", scan.stamps));
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}

#[test]
fn prop_event_log_reader_recovers_complete_events_exactly_once() {
    // the observability contract: over any line-atomic interleaving of
    // per-writer event streams — with garbage lines mixed in and the
    // final line torn mid-write — the reader recovers every complete
    // event exactly once, in seq order per (host, worker), and counts
    // exactly the garbage as skipped (a torn final line is silently
    // ignored: the writer may still be appending it)
    use elaps::obs::events::{parse_events_text, Event, ALL_EVENT_KINDS};
    use std::collections::BTreeMap;
    forall(
        0xF1,
        60,
        |r, size| {
            let writers = r.range_usize(1, 4);
            let per: Vec<usize> =
                (0..writers).map(|_| r.range_usize(1, 3 + size.min(8))).collect();
            // a random order-preserving merge of the writers' lines,
            // with garbage lines (None) mixed in at random positions
            let mut remaining = per.clone();
            let mut garbage = r.range_usize(0, 3);
            let mut ops: Vec<Option<usize>> = Vec::new();
            while remaining.iter().any(|&n| n > 0) || garbage > 0 {
                let total: usize = remaining.iter().sum::<usize>() + garbage;
                let mut pick = r.below(total);
                let mut chosen = None;
                for (w, n) in remaining.iter_mut().enumerate() {
                    if pick < *n {
                        *n -= 1;
                        chosen = Some(w);
                        break;
                    }
                    pick -= *n;
                }
                if chosen.is_none() {
                    garbage -= 1;
                }
                ops.push(chosen);
            }
            let kinds: Vec<Vec<usize>> = per
                .iter()
                .map(|&n| (0..n).map(|_| r.below(ALL_EVENT_KINDS.len())).collect())
                .collect();
            (per, ops, kinds, r.chance(0.7))
        },
        |(per, ops, kinds, truncate_tail)| {
            let writers = per.len();
            // writer w's i-th event; hosts are shared across writers
            // (w % 2) so ordering is genuinely per (host, worker)
            let make = |w: usize, i: usize| Event {
                kind: ALL_EVENT_KINDS[kinds[w][i]],
                job_id: format!("job-{w}-{i}"),
                campaign: if i % 2 == 0 { "camp".to_string() } else { String::new() },
                host: format!("h{}", w % 2),
                worker: format!("w{w}"),
                epoch: i as u64,
                t_unix_ns: 1_700_000_000_000_000_000 + (w as u128) * 1_000 + i as u128,
                seq: (i * 3 + w) as u64,
                extra: BTreeMap::new(),
            };
            let mut text = String::new();
            let mut counters = vec![0usize; writers];
            let mut written: Vec<Vec<Event>> = vec![Vec::new(); writers];
            let mut garbage_lines = 0usize;
            for op in ops {
                match op {
                    Some(w) => {
                        let i = counters[*w];
                        counters[*w] += 1;
                        let ev = make(*w, i);
                        text.push_str(&ev.to_line());
                        written[*w].push(ev);
                    }
                    None => {
                        garbage_lines += 1;
                        text.push_str("]]{ not a json event\n");
                    }
                }
            }
            if *truncate_tail {
                // a writer torn mid-append: a valid event minus its
                // newline and final byte (events are pure ASCII)
                let mut tail = make(0, 0);
                tail.seq = 999_999;
                let line = tail.to_line();
                text.push_str(&line[..line.len() - 2]);
            }
            let scan = parse_events_text(&text);
            if scan.skipped != garbage_lines {
                return Err(format!("skipped {}, want {garbage_lines}", scan.skipped));
            }
            let total: usize = written.iter().map(Vec::len).sum();
            if scan.events.len() != total {
                return Err(format!("recovered {}, want {total}", scan.events.len()));
            }
            let mut got: BTreeMap<(String, String), Vec<Event>> = BTreeMap::new();
            for ev in scan.events {
                got.entry((ev.host.clone(), ev.worker.clone())).or_default().push(ev);
            }
            for (w, expect) in written.iter().enumerate() {
                let key = (format!("h{}", w % 2), format!("w{w}"));
                let empty = Vec::new();
                let g = got.get(&key).unwrap_or(&empty);
                if g != expect {
                    return Err(format!("writer {w}: events lost, duplicated or reordered"));
                }
                for pair in g.windows(2) {
                    if pair[0].seq >= pair[1].seq {
                        return Err(format!(
                            "writer {w}: seq not strictly increasing ({} then {})",
                            pair[0].seq, pair[1].seq
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ledger_scan_recovers_complete_facts_exactly_once_at_any_split() {
    // the ledger twin of the event-log contract: over any
    // order-preserving interleaving of per-writer framed facts — with
    // corrupt lines mixed in and the final line torn mid-append — a
    // one-shot scan recovers every complete fact exactly once and
    // counts exactly the corrupt lines as skipped; and for ANY byte
    // split, scanning the prefix and resuming from its cursor yields
    // the same facts and the same skipped count (a line straddling the
    // split is torn in the prefix scan and recovered — or counted —
    // exactly once by the resume)
    use elaps::coordinator::ledger::{frame_record, parse_ledger_text};
    use elaps::obs::events::{Event, EventKind};
    use std::collections::BTreeMap;
    forall(
        0xF2,
        60,
        |r, size| {
            let writers = r.range_usize(1, 3);
            let mut remaining: Vec<usize> =
                (0..writers).map(|_| r.range_usize(1, 3 + size.min(8))).collect();
            let mut corrupt = r.range_usize(0, 3);
            // a random order-preserving merge of the writers' fact
            // streams, with corrupt lines (None) at random positions
            let mut ops: Vec<Option<usize>> = Vec::new();
            while remaining.iter().any(|&n| n > 0) || corrupt > 0 {
                let total: usize = remaining.iter().sum::<usize>() + corrupt;
                let mut pick = r.below(total);
                let mut chosen = None;
                for (w, n) in remaining.iter_mut().enumerate() {
                    if pick < *n {
                        *n -= 1;
                        chosen = Some(w);
                        break;
                    }
                    pick -= *n;
                }
                if chosen.is_none() {
                    corrupt -= 1;
                }
                ops.push(chosen);
            }
            (ops, r.chance(0.5), r.next_u64())
        },
        |(ops, torn_tail, splitter)| {
            let make = |w: usize, i: usize| Event {
                kind: EventKind::Submitted,
                job_id: format!("job-{w}-{i}"),
                campaign: "camp".to_string(),
                host: format!("h{w}"),
                worker: format!("h{w}#0"),
                epoch: 0,
                t_unix_ns: 1_700_000_000_000_000_000,
                seq: i as u64,
                extra: BTreeMap::new(),
            };
            // three corruption shapes a reader must reject and count:
            // CRC mismatch, an unframed line, a length mismatch (a
            // blank line is the one shape skipped *silently*, so none
            // here — the count would drift)
            const CORRUPT: [&str; 3] =
                ["00000000 5 xxxxx\n", "deadbeef notaframe\n", "deadbeef 10 ab\n"];
            let mut text = String::new();
            let mut counters = vec![0usize; 4];
            let mut merged: Vec<Event> = Vec::new();
            let mut corrupt_lines = 0usize;
            for op in ops {
                match op {
                    Some(w) => {
                        let i = counters[*w];
                        counters[*w] += 1;
                        let ev = make(*w, i);
                        text.push_str(&frame_record(&ev.to_json().to_string_compact()));
                        merged.push(ev);
                    }
                    None => {
                        text.push_str(CORRUPT[corrupt_lines % CORRUPT.len()]);
                        corrupt_lines += 1;
                    }
                }
            }
            if *torn_tail {
                // a writer torn mid-append: a valid frame minus its
                // newline and final byte (frames are pure ASCII)
                let mut tail = make(0, 0);
                tail.seq = 999_999;
                let line = frame_record(&tail.to_json().to_string_compact());
                text.push_str(&line[..line.len() - 2]);
            }
            let whole = parse_ledger_text(&text);
            if whole.events != merged {
                return Err(format!(
                    "one-shot scan recovered {} facts, want {}",
                    whole.events.len(),
                    merged.len()
                ));
            }
            if whole.skipped != corrupt_lines {
                return Err(format!("skipped {}, want {corrupt_lines}", whole.skipped));
            }
            if *torn_tail && whole.bytes as usize >= text.len() {
                return Err("torn tail was consumed by the cursor".to_string());
            }
            // resumability: split anywhere, scan the prefix, resume
            // from its cursor — nothing lost, duplicated, or recounted
            let k = (*splitter as usize) % (text.len() + 1);
            let first = parse_ledger_text(&text[..k]);
            let rest = parse_ledger_text(&text[first.bytes as usize..]);
            let mut combined = first.events;
            combined.extend(rest.events);
            if combined != merged {
                return Err(format!("split at {k}: facts lost, duplicated or reordered"));
            }
            if first.skipped + rest.skipped != corrupt_lines {
                return Err(format!(
                    "split at {k}: skipped {} + {} != {corrupt_lines}",
                    first.skipped, rest.skipped
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ledger_index_incremental_fold_matches_one_shot_reference() {
    // the index contract: folding a campaign's facts incrementally —
    // random append batches, with snapshot save/reload cycles and
    // archiving compactions interleaved at random — converges to
    // exactly the state a fresh one-shot fold of the same facts
    // produces. This is what makes `elaps wait`/`status`/`retry` safe
    // to run concurrently with `spool compact --archive`.
    use elaps::coordinator::ledger::{append, compact, CampaignIndex};
    use elaps::obs::events::{Event, EventKind};
    use std::collections::BTreeMap;
    forall(
        0xF3,
        20,
        |r, size| {
            let jobs = r.range_usize(2, 4 + size.min(6));
            // per-job retry-chain shape: 0 = plain submit (1 fact),
            // 1 = failed + retried (3 facts), 2 = dead-lettered (2)
            let kinds: Vec<usize> = (0..jobs).map(|_| r.below(3)).collect();
            let total: usize = kinds.iter().map(|&k| [1usize, 3, 2][k]).sum();
            let mut chunks = Vec::new();
            let mut covered = 0;
            while covered < total {
                let sz = r.range_usize(1, 4);
                chunks.push((sz, r.chance(0.4), r.chance(0.3)));
                covered += sz;
            }
            (kinds, chunks, r.next_u64())
        },
        |(kinds, chunks, salt)| {
            let fact = |kind: EventKind, id: &str, seq: u64| Event {
                kind,
                job_id: id.to_string(),
                campaign: "plc".to_string(),
                host: "hostP".to_string(),
                worker: "hostP#0".to_string(),
                epoch: 0,
                t_unix_ns: 1_700_000_000_000_000_000,
                seq,
                extra: BTreeMap::new(),
            };
            let mut facts: Vec<Event> = Vec::new();
            for (i, &k) in kinds.iter().enumerate() {
                let id = format!("job-{i:02}");
                let mut exp = Json::obj();
                exp.set("library", "rustblocked").set("n", i as u64);
                let mut sub = fact(EventKind::Submitted, &id, facts.len() as u64);
                sub.extra.insert("attempt".into(), 1u64.into());
                sub.extra.insert("experiment".into(), exp.clone());
                facts.push(sub);
                match k {
                    1 => {
                        let rid = format!("{id}-r");
                        let mut retried = fact(EventKind::Retried, &rid, facts.len() as u64);
                        retried.extra.insert("of".into(), Json::Str(id.clone()));
                        retried.extra.insert("attempt".into(), 2u64.into());
                        facts.push(retried);
                        let mut sub2 = fact(EventKind::Submitted, &rid, facts.len() as u64);
                        sub2.extra.insert("attempt".into(), 2u64.into());
                        sub2.extra.insert("experiment".into(), exp);
                        facts.push(sub2);
                    }
                    2 => {
                        let mut dead = fact(EventKind::DeadLettered, &id, facts.len() as u64);
                        dead.extra.insert("attempts".into(), 1u64.into());
                        facts.push(dead);
                    }
                    _ => {}
                }
            }
            let base = std::env::temp_dir()
                .join(format!("elaps_prop_plc_{}_{salt:016x}", std::process::id()));
            let dir = base.join("inc");
            let refdir = base.join("ref");
            let _ = std::fs::remove_dir_all(&base);
            let fail = |e: anyhow::Error| format!("{e:#}");
            // incremental: batched appends, with reload and archiving
            // compaction interleaved per the generated schedule
            let mut idx = CampaignIndex::load(&dir, "plc").map_err(fail)?;
            let mut cursor = 0usize;
            for &(sz, reload, archive) in chunks {
                if cursor >= facts.len() {
                    break;
                }
                let end = (cursor + sz).min(facts.len());
                append(&dir, "plc", &facts[cursor..end]).map_err(fail)?;
                cursor = end;
                idx.refresh(&dir).map_err(fail)?;
                if archive {
                    compact(&dir, "plc", true).map_err(fail)?;
                }
                if reload {
                    idx.save(&dir).map_err(fail)?;
                    idx = CampaignIndex::load(&dir, "plc").map_err(fail)?;
                }
            }
            if cursor < facts.len() {
                append(&dir, "plc", &facts[cursor..]).map_err(fail)?;
            }
            idx.refresh(&dir).map_err(fail)?;
            // reference: every fact in one append, folded once
            append(&refdir, "plc", &facts).map_err(fail)?;
            let mut reference = CampaignIndex::load(&refdir, "plc").map_err(fail)?;
            reference.refresh(&refdir).map_err(fail)?;
            // compare the folded entries (cursor and generation
            // legitimately differ after archives)
            let got = idx.to_json();
            let want = reference.to_json();
            if got.get("jobs") != want.get("jobs") {
                return Err(format!(
                    "incremental fold diverged from one-shot reference:\n{}\nvs\n{}",
                    got.to_string_pretty(),
                    want.to_string_pretty()
                ));
            }
            if idx.skipped != 0 {
                return Err(format!("incremental fold skipped {} facts", idx.skipped));
            }
            let _ = std::fs::remove_dir_all(&base);
            Ok(())
        },
    );
}

/// A random symbolic expression with the given maximum depth. Avoids
/// `i64::MIN` constants: their `Display` magnitude does not fit the
/// tokenizer's unsigned literal, so they are the one constant that
/// legitimately cannot round-trip.
fn random_expr(r: &mut Xoshiro256, depth: usize) -> elaps::coordinator::Expr {
    use elaps::coordinator::Expr;
    if depth == 0 || r.chance(0.3) {
        return if r.chance(0.5) {
            const SYMS: &[&str] = &["n", "m", "k", "i", "nb", "x_1"];
            Expr::sym(SYMS[r.below(SYMS.len())])
        } else if r.chance(0.5) {
            Expr::c(r.range_usize(0, 1024) as i64 - 512)
        } else {
            Expr::c((r.next_u64() as i64).max(i64::MIN + 1))
        };
    }
    let l = Box::new(random_expr(r, depth - 1));
    let rhs = Box::new(random_expr(r, depth - 1));
    match r.below(7) {
        0 => Expr::Add(l, rhs),
        1 => Expr::Sub(l, rhs),
        2 => Expr::Mul(l, rhs),
        3 => Expr::Div(l, rhs),
        4 => Expr::CeilDiv(l, rhs),
        5 => Expr::Min(l, rhs),
        _ => Expr::Max(l, rhs),
    }
}

#[test]
fn prop_symbolic_display_reparses_identically() {
    // parse ∘ Display = id on the AST, for arbitrary expressions over
    // every operator — including negative constants in any position
    // ("(x - -5)" must reparse to Sub(x, Const(-5))). Experiments
    // persist expressions through Display, so a round-trip loss would
    // silently change a reloaded experiment's operand sizes.
    use elaps::coordinator::Expr;
    forall(
        0xC7,
        400,
        |r, size| random_expr(r, 1 + size.min(5)),
        |e| {
            let text = e.to_string();
            let back = Expr::parse(&text)
                .map_err(|err| format!("'{text}' failed to reparse: {err}"))?;
            if back != *e {
                return Err(format!("'{text}' reparsed to '{back}' ({back:?} != {e:?})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eigenvalues_match_across_drivers() {
    use elaps::linalg::lapack::{dsyev, dsyevd, dsyevr, dsyevx};
    forall(
        0xC1,
        10,
        |r, size| (r.range_usize(3, 10 + size * 2), r.next_u64()),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seeded(seed);
            let a0 = Matrix::random_spd(n, &mut rng);
            let run = |f: fn(usize, &mut [f64], usize, bool) -> elaps::linalg::Result<elaps::linalg::lapack::eig::EigResult>| {
                let mut a = a0.clone();
                f(n, &mut a.data, n, false).map(|r| r.values).map_err(|e| e.to_string())
            };
            let v1 = run(dsyev)?;
            for f in [dsyevd as fn(usize, &mut [f64], usize, bool) -> _, dsyevx, dsyevr] {
                let v = run(f)?;
                all_close(&v1, &v, 1e-6)?;
            }
            Ok(())
        },
    );
}
