//! End-to-end tests of the calibration-and-prediction subsystem:
//! `elaps calibrate` (profile fitting, determinism, file workflow) and
//! `elaps rank` (modeled ranking), including the differential test that
//! the predicted ordering matches the ordering a seeded run measures,
//! and the seeded trusted-only cache rule.

use std::process::Command;

use elaps::coordinator::{io, Metric, Stat};
use elaps::perfmodel::MachineProfile;
use elaps::util::json::Json;

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps-calrank-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A dgemm sweep over shuffled sizes: the modeled-time ordering (sorted
/// by n) is a non-identity permutation of the grid order, so a ranking
/// that merely echoed the input would fail.
const SWEEP_EXP: &str = r#"{"name":"rank-sweep","library":"rustblocked",
    "machine":"haswell","nreps":2,"discard_first":false,
    "range":{"sym":"n","values":[48,16,64,24,32]},
    "calls":[["dgemm","N","N","n","n","n",1,"$A","n","$B","n",0,"$C","n"]]}"#;

/// Kendall rank correlation between two orderings of the same items.
fn kendall_tau(a: &[i64], b: &[i64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let pos = |v: &[i64], x: i64| v.iter().position(|&y| y == x).unwrap();
    let (mut conc, mut disc) = (0i64, 0i64);
    for i in 0..a.len() {
        for j in i + 1..a.len() {
            if pos(b, a[i]) < pos(b, a[j]) {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    (conc - disc) as f64 / (conc + disc).max(1) as f64
}

#[test]
fn rank_ordering_matches_seeded_measured_ordering() {
    let dir = temp_dir("diff");
    let exp = dir.join("exp.json");
    std::fs::write(&exp, SWEEP_EXP).unwrap();
    // predicted ordering: elaps rank --json (no kernel execution)
    let out = Command::new(elaps_bin())
        .args(["rank", exp.to_str().unwrap(), "--seed", "7", "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    let predicted: Vec<i64> = j
        .get("ranking")
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("range_value").as_i64().unwrap())
        .collect();
    assert_eq!(predicted.len(), 5);
    // measured ordering: a seeded run of the same experiment
    let report_path = dir.join("report.json");
    let out = Command::new(elaps_bin())
        .args([
            "run",
            exp.to_str().unwrap(),
            "--seed",
            "7",
            "--out",
            report_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let rj = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    let report = io::report_from_json(&rj).unwrap();
    let mut series = report.series(Metric::TimeS, Stat::Median);
    series.sort_by(|a, b| a.1.total_cmp(&b.1));
    let measured: Vec<i64> = series.iter().map(|&(x, _)| x).collect();
    // the predictive sampler is bit-identical to the seeded executed
    // one, so the orderings must agree essentially perfectly
    assert_eq!(predicted[0], measured[0], "top-1 must match");
    let tau = kendall_tau(&predicted, &measured);
    assert!(tau >= 0.999, "kendall tau {tau}: predicted {predicted:?} vs {measured:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn calibrate_json_is_byte_identical_across_runs() {
    let dir = temp_dir("det");
    let run = || {
        let out = Command::new(elaps_bin())
            .args(["calibrate", "--quick", "--json", "--machine", "haswell", "--seed", "7"])
            .current_dir(&dir)
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out.stdout
    };
    let first = run();
    let second = run();
    assert!(!first.is_empty());
    assert_eq!(first, second, "calibrate --json must be deterministic under --seed");
    // --json without --out must not drop a profile file into the cwd
    assert!(
        !dir.join(".elaps-machine-profile.json").exists(),
        "--json mode should write no implicit profile file"
    );
    let j = Json::parse(&String::from_utf8_lossy(&first)).unwrap();
    assert_eq!(j.get("schema").as_u64(), Some(1));
    assert_eq!(j.get("base").as_str(), Some("haswell"));
    let fit = j.get("fit");
    let fitted_err = fit.get("mean_abs_rel_err").as_f64().unwrap();
    let uncal_err = fit.get("uncalibrated_mean_abs_rel_err").as_f64().unwrap();
    // the fitted model must beat the uncalibrated constants on haswell,
    // whose instance penalties differ from the defaults
    assert!(fitted_err < 0.05, "fitted err {fitted_err}");
    assert!(fitted_err < uncal_err, "fitted {fitted_err} vs uncalibrated {uncal_err}");
    assert!(uncal_err > 0.01, "uncalibrated err should be visible: {uncal_err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn calibrate_profile_file_feeds_rank_machine_spec() {
    let dir = temp_dir("profile");
    let profile_path = dir.join("p.json");
    let out = Command::new(elaps_bin())
        .args([
            "calibrate",
            "--quick",
            "--machine",
            "haswell",
            "--seed",
            "7",
            "--out",
            profile_path.to_str().unwrap(),
        ])
        .current_dir(&dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let profile = MachineProfile::load(&profile_path).unwrap();
    assert_eq!(profile.name, "haswell+calibrated");
    assert_eq!(profile.base, "haswell");
    // the profile file is a valid --machine spec everywhere
    let exp = dir.join("exp.json");
    std::fs::write(&exp, SWEEP_EXP).unwrap();
    let spec = format!("profile:{}", profile_path.display());
    let out = Command::new(elaps_bin())
        .args(["rank", exp.to_str().unwrap(), "--machine", &spec, "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("machine").as_str(), Some("haswell+calibrated"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_machine_error_lists_valid_specs() {
    let dir = temp_dir("unknown-machine");
    let exp = dir.join("exp.json");
    std::fs::write(&exp, SWEEP_EXP).unwrap();
    let out = Command::new(elaps_bin())
        .args(["rank", exp.to_str().unwrap(), "--machine", "cray"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for name in ["sandybridge", "haswell", "localhost", "profile:PATH"] {
        assert!(err.contains(name), "error must mention {name}: {err}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_counter_metric_is_rejected() {
    let dir = temp_dir("metric");
    let exp = dir.join("exp.json");
    std::fs::write(&exp, SWEEP_EXP).unwrap();
    let report = dir.join("report.json");
    let out = Command::new(elaps_bin())
        .args(["run", exp.to_str().unwrap(), "--seed", "1", "--out", report.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // pre-fix this silently aliased to counter0; now it must fail loudly
    let out = Command::new(elaps_bin())
        .args(["view", report.to_str().unwrap(), "--metric", "counterfoo"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "counterfoo must not alias counter0");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown metric 'counterfoo'"), "{err}");
    // well-formed counter indices still parse (the report has no
    // counters, so the series is all zeros — but the metric resolves)
    let out = Command::new(elaps_bin())
        .args(["view", report.to_str().unwrap(), "--metric", "counter0"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trusted_only_serves_seeded_entries_from_any_pool_width() {
    let dir = temp_dir("trusted");
    let exp = dir.join("exp.json");
    std::fs::write(&exp, SWEEP_EXP).unwrap();
    let cache = dir.join("cache");
    let run = |extra: &[&str]| {
        let mut args = vec![
            "run",
            exp.to_str().unwrap(),
            "--jobs",
            "2",
            "--cache",
            cache.to_str().unwrap(),
            "--out",
            dir.join("report.json").to_str().unwrap(),
        ];
        args.extend_from_slice(extra);
        let out = Command::new(elaps_bin()).args(&args).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    // seeded entries are pure functions of the script: stored at jobs=2
    // they must still satisfy a --trusted-only re-run
    run(&["--seed", "7"]);
    let second = run(&["--seed", "7", "--trusted-only"]);
    assert!(
        second.contains("0 executed"),
        "seeded entries must be trusted at any pool width: {second}"
    );
    // whereas unseeded (wall-clock) entries stored at jobs=2 stay
    // untrusted and are re-measured
    let cache2 = dir.join("cache-wall");
    let run_wall = |extra: &[&str]| {
        let mut args = vec![
            "run",
            exp.to_str().unwrap(),
            "--jobs",
            "2",
            "--cache",
            cache2.to_str().unwrap(),
            "--out",
            dir.join("report2.json").to_str().unwrap(),
        ];
        args.extend_from_slice(extra);
        let out = Command::new(elaps_bin()).args(&args).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    run_wall(&[]);
    let wall_second = run_wall(&["--trusted-only"]);
    assert!(
        !wall_second.contains("0 executed"),
        "contended wall-clock entries must be re-measured: {wall_second}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
