//! End-to-end tests of the `elaps cache {stats,gc,clear}` subcommands
//! through real process boundaries: exit codes, output, strict
//! `--max-bytes` parsing, and the fully-cached `elaps batch` re-run.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

/// Run `elaps` with the given args, the environment scrubbed of engine
/// variables so each test controls its own cache.
fn elaps(args: &[&str]) -> Output {
    Command::new(elaps_bin())
        .args(args)
        .env_remove("ELAPS_CACHE")
        .env_remove("ELAPS_JOBS")
        .env_remove("ELAPS_TRUSTED_ONLY")
        .env_remove("ELAPS_WARM")
        .env_remove("ELAPS_SEED")
        .output()
        .unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_cli_cache_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a small two-point experiment file and return its path.
fn write_exp(dir: &Path) -> PathBuf {
    let exp = dir.join("exp.json");
    std::fs::write(
        &exp,
        r#"{"name":"cache-cli","library":"rustblocked","machine":"localhost",
           "nreps":2,
           "range":{"sym":"n","values":[16,24]},
           "calls":[["dgemm","N","N","n","n","n",1,"$A","n","$B","n",0,"$C","n"]]}"#,
    )
    .unwrap();
    exp
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn stats_gc_clear_workflow() {
    let dir = tmpdir("workflow");
    let exp = write_exp(&dir);
    let cache = dir.join("cache");
    let cache_s = cache.to_str().unwrap();
    // seed the cache through a run
    let out = elaps(&[
        "run",
        exp.to_str().unwrap(),
        "--cache",
        cache_s,
        "--out",
        dir.join("r.json").to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    // stats reports entries and bytes
    let out = elaps(&["cache", "stats", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("entries:     2"), "{text}");
    assert!(text.contains("bytes:"), "{text}");
    assert!(text.contains("trusted:     2"), "{text}");
    assert!(text.contains("age histogram"), "{text}");
    // a generous budget deletes nothing
    let out = elaps(&["cache", "gc", "--max-bytes", "1G", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("deleted 0/2"), "{}", stdout(&out));
    // a zero budget deletes everything, oldest first
    let out = elaps(&["cache", "gc", "--max-bytes", "0", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("deleted 2/2"), "{}", stdout(&out));
    let out = elaps(&["cache", "stats", "--cache", cache_s]);
    assert!(stdout(&out).contains("entries:     0"), "{}", stdout(&out));
    // reseed, then clear
    let out = elaps(&[
        "run",
        exp.to_str().unwrap(),
        "--cache",
        cache_s,
        "--out",
        dir.join("r2.json").to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let out = elaps(&["cache", "clear", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("cleared 2 entries"), "{}", stdout(&out));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_rejects_bad_max_bytes_strictly() {
    let dir = tmpdir("badbytes");
    let cache = dir.join("cache");
    std::fs::create_dir_all(&cache).unwrap();
    let cache_s = cache.to_str().unwrap();
    for bad in ["-5", "garbage", "1.5M", "10KB", ""] {
        let out = elaps(&["cache", "gc", "--max-bytes", bad, "--cache", cache_s]);
        assert!(!out.status.success(), "--max-bytes {bad:?} must fail");
        assert!(stderr(&out).contains("max-bytes"), "{}", stderr(&out));
    }
    // missing entirely
    let out = elaps(&["cache", "gc", "--cache", cache_s]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--max-bytes"), "{}", stderr(&out));
    // K/M/G suffixes parse
    for good in ["4096", "64K", "2m", "1G"] {
        let out = elaps(&["cache", "gc", "--max-bytes", good, "--cache", cache_s]);
        assert!(out.status.success(), "--max-bytes {good:?}: {}", stderr(&out));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A minimal valid schema-2 cache entry with the given store time.
fn write_entry(dir: &Path, name: &str, created_unix: u64) {
    std::fs::write(
        dir.join(format!("{name}.json")),
        format!(
            r#"{{"schema":2,"jobs":1,"warm":false,"created_unix":{created_unix},
               "result":{{"range_value":0,"nthreads":1,"sum_iters":1,
                          "calls_per_iter":1,"records":[]}}}}"#
        ),
    )
    .unwrap();
}

#[test]
fn stats_break_entries_down_per_host() {
    let dir = tmpdir("perhost");
    let exp = write_exp(&dir);
    let cache = dir.join("cache");
    let cache_s = cache.to_str().unwrap();
    // seed two entries measured "on" a pinned host (ELAPS_HOST
    // overrides hostname resolution, so the snapshot is stable)
    let out = Command::new(elaps_bin())
        .args([
            "run",
            exp.to_str().unwrap(),
            "--cache",
            cache_s,
            "--out",
            dir.join("r.json").to_str().unwrap(),
        ])
        .env("ELAPS_HOST", "snaphost")
        .env_remove("ELAPS_CACHE")
        .env_remove("ELAPS_JOBS")
        .env_remove("ELAPS_TRUSTED_ONLY")
        .env_remove("ELAPS_WARM")
        .env_remove("ELAPS_SEED")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", stderr(&out));
    // plus one pre-schema-3 entry: provenance unknown
    write_entry(&cache, "older", 1_700_000_000);
    // snapshot of the per-host section
    let out = elaps(&["cache", "stats", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("entries:     3"), "{text}");
    assert!(text.contains("per-host:"), "{text}");
    assert!(text.contains(&format!("{:<16} {}", "snaphost", 2)), "{text}");
    assert!(text.contains(&format!("{:<16} {}", "(unknown)", 1)), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_max_age_parses_strictly_and_expires_by_store_time() {
    let dir = tmpdir("maxage");
    let cache = dir.join("cache");
    std::fs::create_dir_all(&cache).unwrap();
    let cache_s = cache.to_str().unwrap();
    // strict parsing: malformed durations are hard errors
    for bad in ["-5", "1.5h", "garbage", "10min", ""] {
        let out = elaps(&["cache", "gc", "--max-age", bad, "--cache", cache_s]);
        assert!(!out.status.success(), "--max-age {bad:?} must fail");
        assert!(stderr(&out).contains("max-age"), "{}", stderr(&out));
    }
    // s/m/h/d suffixes (and bare seconds) parse
    for good in ["3600", "60m", "24h", "7d", "90s"] {
        let out = elaps(&["cache", "gc", "--max-age", good, "--cache", cache_s]);
        assert!(out.status.success(), "--max-age {good:?}: {}", stderr(&out));
    }
    // an old entry expires, a fresh one survives
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_secs();
    write_entry(&cache, "old", now - 14 * 86_400);
    write_entry(&cache, "fresh", now);
    let out = elaps(&["cache", "gc", "--max-age", "7d", "--cache", cache_s]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("deleted 1/2"), "{}", stdout(&out));
    assert!(!cache.join("old.json").exists());
    assert!(cache.join("fresh.json").exists());
    // combined sweep: age cutoff first, then the byte budget finishes
    // the job — here budget 0 deletes the survivor
    let out = elaps(&[
        "cache", "gc", "--max-age", "7d", "--max-bytes", "0", "--cache", cache_s,
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(!cache.join("fresh.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cache_command_requires_a_directory_and_known_subcommand() {
    // no --cache and no ELAPS_CACHE
    let out = elaps(&["cache", "stats"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("no cache directory"), "{}", stderr(&out));
    // unknown subcommand
    let dir = tmpdir("unknown");
    let out = elaps(&["cache", "shrink", "--cache", dir.to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown cache subcommand"), "{}", stderr(&out));
    // missing subcommand
    let out = elaps(&["cache", "--cache", dir.to_str().unwrap()]);
    assert!(!out.status.success());
    // stats on a cache dir that was never created
    let out = elaps(&["cache", "stats", "--cache", dir.join("nope").to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("no cache directory"), "{}", stderr(&out));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fully_cached_batch_rerun_enqueues_nothing() {
    let dir = tmpdir("rerun");
    let exp = write_exp(&dir);
    let cache = dir.join("cache");
    let cache_s = cache.to_str().unwrap();
    let run = || {
        elaps(&[
            "batch",
            exp.to_str().unwrap(),
            "--jobs",
            "2",
            "--cache",
            cache_s,
            "--out-dir",
            dir.join("out").to_str().unwrap(),
        ])
    };
    let out = run();
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("2 executed, 0 cache hit(s)"), "{text}");
    // the re-run probes the cache before enqueueing: zero jobs, 100%
    // hits, the experiment counted as fully cached
    let out = run();
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("0 executed, 2 cache hit(s) (2 scheduled)"), "{text}");
    assert!(text.contains("1/1 experiment(s) fully cached"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
