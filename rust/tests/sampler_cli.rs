//! End-to-end tests of the `elaps` binary: the sampler's stdin/stdout
//! protocol (the paper's §3.1 workflow), the experiment-file workflow,
//! and the worker/batch path — all through real process boundaries.

use std::io::Write;
use std::process::{Command, Stdio};

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

#[test]
fn sampler_protocol_roundtrip() {
    let mut child = Command::new(elaps_bin())
        .args(["sampler", "--library", "rustblocked", "--machine", "sandybridge"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let script = "\
set_counters PAPI_L1_TCM
dmalloc A 1024
dmalloc B 1024
dmalloc C 1024
dgerand A
dgerand B
dgemm N N 32 32 32 1.0 A 32 B 32 0.0 C 32
dgemm N N 32 32 32 1.0 A 32 B 32 0.0 C 32
go
";
    child.stdin.as_mut().unwrap().write_all(script.as_bytes()).unwrap();
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8(out.stdout).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    for l in &lines {
        assert!(l.starts_with("dgemm "), "{l}");
        let fields: Vec<&str> = l.split_whitespace().collect();
        assert_eq!(fields.len(), 3); // kernel cycles counter
        assert!(fields[1].parse::<f64>().unwrap() > 0.0);
    }
}

#[test]
fn sampler_reports_errors_without_dying() {
    let mut child = Command::new(elaps_bin())
        .args(["sampler"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let script = "zgemm N N 4 4 4 1.0 A 4 B 4 0.0 C 4\ndmalloc A 16\nfree A\ngo\n";
    child.stdin.as_mut().unwrap().write_all(script.as_bytes()).unwrap();
    drop(child.stdin.take());
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("error: unknown kernel"), "{text}");
}

#[test]
fn run_experiment_file_and_view_report() {
    let dir = std::env::temp_dir().join(format!("elaps-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let exp = dir.join("exp.json");
    std::fs::write(
        &exp,
        r#"{"name":"cli-test","library":"rustblocked","machine":"localhost",
           "nreps":3,"discard_first":true,
           "range":{"sym":"n","values":[16,32]},
           "calls":[["dgemm","N","N","n","n","n",1,"$A","n","$B","n",0,"$C","n"]]}"#,
    )
    .unwrap();
    let report = dir.join("report.json");
    let out = Command::new(elaps_bin())
        .args(["run", exp.to_str().unwrap(), "--out", report.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(report.exists());
    // view
    let out = Command::new(elaps_bin())
        .args(["view", report.to_str().unwrap(), "--metric", "gflops", "--stat", "max"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Gflops/s"), "{text}");
    // plot with svg
    let svg = dir.join("plot.svg");
    let out = Command::new(elaps_bin())
        .args(["plot", report.to_str().unwrap(), "--svg", svg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(std::fs::read_to_string(&svg).unwrap().starts_with("<svg"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_submit_and_worker_once() {
    let dir = std::env::temp_dir().join(format!("elaps-cli-batch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let exp = dir.join("exp.json");
    std::fs::write(
        &exp,
        r#"{"name":"batch-test","library":"rustref","nreps":2,
           "calls":[["dgemm","N","N",24,24,24,1,"$A",24,"$B",24,0,"$C",24]]}"#,
    )
    .unwrap();
    let spool = dir.join("spool");
    let out = Command::new(elaps_bin())
        .args([
            "run",
            exp.to_str().unwrap(),
            "--batch",
            "--spool",
            spool.to_str().unwrap(),
            "--out",
            dir.join("report.json").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("report.json").exists());
    // queue fully drained: worker --once exits immediately
    let out = Command::new(elaps_bin())
        .args(["worker", "--spool", spool.to_str().unwrap(), "--once"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kernels_and_libraries_listings() {
    let out = Command::new(elaps_bin()).args(["kernels"]).output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    for k in ["dgemm", "dtrsyl", "dsyevr", "dposv"] {
        assert!(text.contains(k), "missing {k}");
    }
    let out = Command::new(elaps_bin()).args(["libraries"]).output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rustblocked"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = Command::new(elaps_bin()).args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("USAGE"));
}
