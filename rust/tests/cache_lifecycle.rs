//! Integration tests for the cache lifecycle subsystem: the versioned
//! entry envelope (round-trip, legacy compatibility, corruption
//! tolerance), the stats/gc/clear operations, and gc running
//! concurrently with a multi-worker batch.

use elaps::coordinator::{Experiment, Metric, PointResult, RangeDef, Stat};
use elaps::engine::gc::{cache_stats, clear_cache, gc_max_bytes};
use elaps::engine::{Engine, EngineConfig, ResultCache};
use elaps::figures::call;
use elaps::sampler::Record;
use elaps::util::json::Json;
use elaps::util::prop::forall;
use elaps::Report;
use std::sync::atomic::{AtomicBool, Ordering};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_lifecycle_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A dgemm range experiment (one point per value, `nreps` records).
fn range_experiment(name: &str, values: Vec<i64>, nreps: usize) -> Experiment {
    let mut exp = Experiment {
        name: name.into(),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps,
        range: Some(RangeDef::new("n", values)),
        counters: vec!["PAPI_L1_TCM".into()],
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
    )
    .unwrap()];
    exp
}

fn synthetic_result(nrecords: usize, seed: u64) -> PointResult {
    PointResult {
        range_value: seed as i64 % 97,
        nthreads: 1,
        sum_iters: 1,
        calls_per_iter: 1,
        records: (0..nrecords)
            .map(|i| Record {
                kernel: "dgemm".into(),
                seconds: 1e-4 * (i + 1) as f64,
                cycles: 2.6e5 * (i + 1) as f64,
                counters: vec![seed ^ i as u64],
                omp_group: None,
                flops: 1000.0,
            })
            .collect(),
    }
}

/// Everything deterministic about a report (wall times are not).
fn assert_structurally_identical(a: &Report, b: &Report) {
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.range_value, pb.range_value);
        assert_eq!(pa.records.len(), pb.records.len());
        for (ra, rb) in pa.records.iter().zip(&pb.records) {
            assert_eq!(ra.kernel, rb.kernel);
            assert_eq!(ra.counters, rb.counters, "point {}", pa.range_value);
            assert_eq!(ra.flops, rb.flops);
        }
    }
}

#[test]
fn prop_envelope_roundtrip_preserves_provenance_and_records() {
    let dir = tmpdir("prop");
    let cache_base = ResultCache::open(&dir).unwrap();
    forall(
        0xCAFE,
        24,
        |r, size| {
            let nrecords = r.range_usize(1, 4 + size);
            let jobs = r.range_usize(1, 16);
            let warm = r.next_u64() % 2 == 1;
            let seed = r.next_u64();
            (nrecords, jobs, warm, seed)
        },
        |&(nrecords, jobs, warm, seed)| {
            let key = format!("prop{seed:016x}");
            let cache =
                ResultCache::open(&dir).unwrap().with_provenance(jobs).with_warm(warm);
            let point = synthetic_result(nrecords, seed);
            cache.store(&key, &point).map_err(|e| e.to_string())?;
            let env = cache_base
                .lookup_entry(&key)
                .ok_or_else(|| "stored entry must parse".to_string())?;
            if env.schema != elaps::coordinator::io::CACHE_ENTRY_SCHEMA {
                return Err(format!("stored schema {} is stale", env.schema));
            }
            if env.jobs != Some(jobs) {
                return Err(format!("jobs {:?} != {jobs}", env.jobs));
            }
            if env.warm != warm {
                return Err(format!("warm flag lost: {} != {warm}", env.warm));
            }
            if env.trusted() != (jobs <= 1) {
                return Err(format!("trust rule broken for jobs={jobs}"));
            }
            // a matching-mode handle hits; the opposite mode must miss
            // (warm and cold measurements never serve each other)
            let same_mode = ResultCache::open(&dir).unwrap().with_warm(warm);
            let cross_mode = ResultCache::open(&dir).unwrap().with_warm(!warm);
            let hit = same_mode
                .lookup(&key, nrecords)
                .ok_or_else(|| "entry must hit with its own count".to_string())?;
            if cross_mode.lookup(&key, nrecords).is_some() {
                return Err("cross-mode lookup must miss".into());
            }
            if hit.records.len() != nrecords {
                return Err("record count changed in roundtrip".into());
            }
            if hit.records[0].counters != point.records[0].counters {
                return Err("counters changed in roundtrip".into());
            }
            // off-by-one expected count must miss, not mis-serve
            if same_mode.lookup(&key, nrecords + 1).is_some() {
                return Err("wrong expected count must miss".into());
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_warm_keys_are_disjoint_and_chain_on_their_prefix() {
    let exp = range_experiment("warmkeys", vec![16, 24], 2);
    let points = exp.unroll().unwrap();
    forall(
        0xBEEF,
        24,
        |r, _| (r.next_u64() % 2 == 1, r.next_u64() % 3, r.next_u64()),
        |&(seeded, which, seed)| {
            let pt = &points[(which % 2) as usize];
            let s = seeded.then_some(seed);
            let cold = ResultCache::fingerprint_with("rustblocked", "localhost", 2, pt, s);
            let w0 =
                ResultCache::warm_fingerprint("rustblocked", "localhost", 2, pt, s, None);
            let w1 = ResultCache::warm_fingerprint(
                "rustblocked",
                "localhost",
                2,
                pt,
                s,
                Some(&w0),
            );
            if !w0.starts_with('w') || !w1.starts_with('w') {
                return Err("warm keys must carry the w prefix".into());
            }
            if w0 == cold || w1 == cold || w0 == w1 {
                return Err(format!("keys must be pairwise distinct: {cold} {w0} {w1}"));
            }
            // pure functions: recomputing yields the same key
            if w1
                != ResultCache::warm_fingerprint(
                    "rustblocked",
                    "localhost",
                    2,
                    pt,
                    s,
                    Some(&w0),
                )
            {
                return Err("warm key must be deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn schema1_entries_parse_as_cold_and_unknown_schemas_miss() {
    let dir = tmpdir("schema1");
    let cache = ResultCache::open(&dir).unwrap();
    let point = synthetic_result(2, 11);
    // a schema-1 entry, as a PR-2 build would have written it (no
    // warm flag, no host/worker provenance)
    let mut v1 = elaps::coordinator::io::cache_envelope_to_json(
        &point,
        1,
        Some(1_700_000_000),
        false,
        None,
        None,
    );
    v1.set("schema", 1u64);
    let v1 = {
        let mut j = v1;
        // schema 1 had no warm field at all
        if let Json::Obj(m) = &mut j {
            m.remove("warm");
        }
        j
    };
    std::fs::write(dir.join("v1.json"), v1.to_string_pretty()).unwrap();
    let env = cache.lookup_entry("v1").unwrap();
    assert_eq!(env.schema, 1);
    assert_eq!(env.jobs, Some(1));
    assert!(!env.warm, "schema-1 entries are cold by construction");
    assert!(env.trusted());
    // cold lookups serve it; warm-mode lookups must not
    assert!(cache.lookup("v1", 2).is_some());
    let warm = ResultCache::open(&dir).unwrap().with_warm(true);
    assert!(warm.lookup("v1", 2).is_none());
    // unknown/corrupt schemas stay misses, never errors
    std::fs::write(dir.join("v9.json"), r#"{"schema":9,"jobs":1,"result":{"records":[]}}"#)
        .unwrap();
    std::fs::write(dir.join("junk.json"), "not json").unwrap();
    for key in ["v9", "junk"] {
        assert!(cache.lookup_entry(key).is_none(), "{key}");
        assert!(cache.lookup(key, 0).is_none(), "{key}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_pre_envelope_entries_still_hit_the_engine() {
    let dir = tmpdir("legacy");
    let exp = range_experiment("legacy", vec![16, 24], 2);
    let engine = Engine::new(EngineConfig::default().with_cache(&dir));
    let (first, s1) = engine.run_stats(&exp).unwrap();
    assert_eq!((s1.executed, s1.cache_hits), (2, 0));
    // strip every entry down to the PR-1 format: the bare result object
    let mut stripped = 0;
    for e in std::fs::read_dir(&dir).unwrap().filter_map(|e| e.ok()) {
        let path = e.path();
        if path.extension().is_some_and(|x| x == "json") {
            let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            let bare = j.get("result").clone();
            assert!(!bare.is_null(), "entry must carry an envelope");
            std::fs::write(&path, bare.to_string_pretty()).unwrap();
            stripped += 1;
        }
    }
    assert_eq!(stripped, 2);
    // legacy entries still hit…
    let (second, s2) = engine.run_stats(&exp).unwrap();
    assert_eq!((s2.executed, s2.cache_hits), (0, 2));
    assert_structurally_identical(&first, &second);
    // …and stats classifies them as legacy
    let st = cache_stats(&dir).unwrap();
    assert_eq!(st.legacy, 2);
    assert_eq!(st.unreadable, 0);
    // but a trusted-only engine re-measures them (provenance unknown)
    let strict = Engine::new(
        EngineConfig::default().with_cache(&dir).with_trusted_only(true),
    );
    let (_, s3) = strict.run_stats(&exp).unwrap();
    assert_eq!((s3.executed, s3.cache_hits), (2, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_entries_are_misses_and_counted_unreadable() {
    let dir = tmpdir("corrupt");
    let exp = range_experiment("corrupt", vec![16], 2);
    let engine = Engine::new(EngineConfig::default().with_cache(&dir));
    engine.run(&exp).unwrap();
    // truncate the single entry mid-file
    let entry = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "json"))
        .unwrap();
    let text = std::fs::read_to_string(&entry).unwrap();
    std::fs::write(&entry, &text[..text.len() / 2]).unwrap();
    let st = cache_stats(&dir).unwrap();
    assert_eq!((st.entries, st.unreadable), (1, 1));
    // the engine treats it as a miss and repairs it by re-measuring
    let (_, s) = engine.run_stats(&exp).unwrap();
    assert_eq!((s.executed, s.cache_hits), (1, 0));
    assert_eq!(cache_stats(&dir).unwrap().unreadable, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_brings_real_cache_under_budget_oldest_first() {
    let dir = tmpdir("budget");
    let engine = Engine::new(EngineConfig::default().with_cache(&dir));
    engine.run(&range_experiment("sweep", vec![16, 20, 24, 28, 32, 36], 1)).unwrap();
    let st = cache_stats(&dir).unwrap();
    assert_eq!(st.entries, 6);
    assert!(st.total_bytes > 0);
    // budget for roughly half the entries
    let budget = st.total_bytes / 2;
    let out = gc_max_bytes(&dir, budget).unwrap();
    assert!(out.deleted >= 3, "{out:?}");
    assert!(out.bytes_after <= budget, "{out:?}");
    let st2 = cache_stats(&dir).unwrap();
    assert_eq!(st2.entries, 6 - out.deleted);
    assert_eq!(st2.total_bytes, out.bytes_after);
    // every survivor still parses
    assert_eq!(st2.unreadable, 0);
    // clear empties the rest
    assert_eq!(clear_cache(&dir).unwrap(), st2.entries);
    assert_eq!(cache_stats(&dir).unwrap().entries, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_racing_a_parallel_batch_is_safe() {
    let dir = tmpdir("race");
    std::fs::create_dir_all(&dir).unwrap();
    let exps = vec![
        range_experiment("race-a", vec![16, 20, 24, 28], 2),
        range_experiment("race-b", vec![16, 32, 36], 2),
        range_experiment("race-c", vec![24, 40], 2),
    ];
    // the reference: serial, uncached
    let serial = Engine::new(EngineConfig::default()).run_batch(&exps).unwrap();

    let stop = AtomicBool::new(false);
    let (reports, stats) = std::thread::scope(|s| {
        // an adversarial collector deleting everything it sees, plus a
        // stats reader, racing the workers' stores
        s.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                let _ = gc_max_bytes(&dir, 0);
                let _ = cache_stats(&dir);
                std::thread::yield_now();
            }
        });
        let engine =
            Engine::new(EngineConfig::default().with_jobs(4).with_cache(&dir));
        let result = engine.run_batch_stats(&exps);
        stop.store(true, Ordering::Relaxed);
        result
    })
    .unwrap();

    // no worker panicked or errored, and the merged output is
    // bit-identical (in its deterministic parts) to the serial run
    assert_eq!(reports.len(), 3);
    for (a, b) in serial.iter().zip(&reports) {
        assert_structurally_identical(a, b);
    }
    assert_eq!(stats.total_points(), 9);
    // whatever survived the sweeps must be whole entries — the atomic
    // temp+rename store means a reader can never observe a partial one
    let st = cache_stats(&dir).unwrap();
    assert_eq!(st.unreadable, 0, "partially-deleted/written entry observed");
    // and a quiet follow-up run still works, re-measuring what gc ate
    let engine = Engine::new(EngineConfig::default().with_cache(&dir));
    let (again, s2) = engine.run_batch_stats(&exps).unwrap();
    assert_eq!(s2.total_points(), 9);
    for (a, b) in serial.iter().zip(&again) {
        assert_structurally_identical(a, b);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deterministic_metric_survives_cache_replay() {
    // counters are simulated and deterministic: a cache round-trip must
    // reproduce them exactly
    let dir = tmpdir("replay");
    let exp = range_experiment("replay", vec![16, 24, 32], 2);
    let engine = Engine::new(EngineConfig::default().with_jobs(2).with_cache(&dir));
    let (first, _) = engine.run_stats(&exp).unwrap();
    let (second, s2) = engine.run_stats(&exp).unwrap();
    assert_eq!(s2.executed, 0);
    let a = first.series(Metric::Counter(0), Stat::Median);
    let b = second.series(Metric::Counter(0), Stat::Median);
    assert_eq!(a, b);
    let _ = std::fs::remove_dir_all(&dir);
}
