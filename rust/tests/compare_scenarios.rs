//! End-to-end tests of the differential-study subsystem: `elaps
//! compare` (cross-library report, seeded byte-identity, the
//! measured-vs-predicted agreement bar) and the S1–S4 scenario pack
//! (`elaps figures scenarios`) as deterministic regression fixtures.

use std::process::Command;

use elaps::util::json::Json;

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps-compare-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Kendall rank correlation between two orderings of the same items.
fn kendall_tau(a: &[i64], b: &[i64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let pos = |v: &[i64], x: i64| v.iter().position(|&y| y == x).unwrap();
    let (mut conc, mut disc) = (0i64, 0i64);
    for i in 0..a.len() {
        for j in i + 1..a.len() {
            if pos(b, a[i]) < pos(b, a[j]) {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    (conc - disc) as f64 / (conc + disc).max(1) as f64
}

fn compare_json(extra: &[&str]) -> Json {
    let mut args = vec![
        "compare",
        "dgemm",
        "--range",
        "16:16:64",
        "--libraries",
        "rustref,rustblocked,rustrecursive",
        "--seed",
        "7",
        "--json",
    ];
    args.extend_from_slice(extra);
    let out = Command::new(elaps_bin()).args(&args).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap()
}

fn ranking_order(j: &Json) -> Vec<String> {
    j.get("ranking")
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("library").as_str().unwrap().to_string())
        .collect()
}

#[test]
fn compare_json_is_byte_identical_under_seed() {
    let run = || {
        let out = Command::new(elaps_bin())
            .args([
                "compare", "dgemm", "--range", "16:16:48", "--libraries",
                "rustref,rustblocked", "--predicted", "--seed", "11", "--json",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out.stdout
    };
    let first = run();
    let second = run();
    assert!(!first.is_empty());
    assert_eq!(first, second, "seeded compare --json must be byte-identical");
    let j = Json::parse(&String::from_utf8_lossy(&first)).unwrap();
    assert_eq!(j.get("mode").as_str(), Some("predicted"));
    assert_eq!(j.get("metric").as_str(), Some("Gflops/s"));
    let series = j.get("series").as_arr().unwrap();
    assert_eq!(series.len(), 2, "one series per library");
    for s in series {
        assert_eq!(s.get("points").as_arr().unwrap().len(), 3, "shared 16:16:48 grid");
    }
    assert_eq!(j.get("winners").as_arr().unwrap().len(), 3);
    assert_eq!(j.get("ranking").as_arr().unwrap().len(), 2);
}

#[test]
fn compare_measured_ranking_agrees_with_predicted() {
    // the model-vs-measurement acceptance bar: under the same seed the
    // measured run uses modeled timings too, so the library ordering
    // must agree essentially perfectly (top-1 exact, Kendall tau ≥
    // 0.999 — i.e. identical for 3 libraries)
    let measured = ranking_order(&compare_json(&[]));
    let predicted = ranking_order(&compare_json(&["--predicted"]));
    assert_eq!(measured[0], predicted[0], "top-1 library must match");
    let index = |order: &[String]| -> Vec<i64> {
        let mut all: Vec<&String> = order.iter().collect();
        all.sort();
        order.iter().map(|l| all.iter().position(|x| *x == l).unwrap() as i64).collect()
    };
    let tau = kendall_tau(&index(&measured), &index(&predicted));
    assert!(tau >= 0.999, "kendall tau {tau}: measured {measured:?} vs predicted {predicted:?}");
}

#[test]
fn compare_rejects_unknown_inputs() {
    let out = Command::new(elaps_bin())
        .args(["compare", "dnoexist", "--json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unsupported compare operation"));
    let out = Command::new(elaps_bin())
        .args(["compare", "dgemm", "--libraries", "rustref,noexist"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown library"));
}

#[test]
fn scenario_pack_replays_byte_identically_under_seed() {
    let dir = temp_dir("scen");
    let run = |out_dir: &std::path::Path| {
        let out = Command::new(elaps_bin())
            .args([
                "figures",
                "scenarios",
                "--seed",
                "7",
                "--out-dir",
                out_dir.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    };
    let (d1, d2) = (dir.join("a"), dir.join("b"));
    run(&d1);
    run(&d2);
    for id in ["S1", "S2", "S3", "S4"] {
        let a = std::fs::read(d1.join(format!("{id}.csv")))
            .unwrap_or_else(|e| panic!("{id}.csv missing: {e}"));
        let b = std::fs::read(d2.join(format!("{id}.csv"))).unwrap();
        assert!(!a.is_empty(), "{id}.csv must have content");
        assert_eq!(a, b, "{id}.csv must replay byte-identically under --seed");
    }
    // S4's differential block must carry the ranking fixture
    let s4 = std::fs::read_to_string(d1.join("S4.csv")).unwrap();
    assert!(s4.contains("rank,library,score,wins"), "S4 must embed the ranking block:\n{s4}");
    let _ = std::fs::remove_dir_all(&dir);
}
