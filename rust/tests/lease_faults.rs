//! Differential fault-injection suite for the spooler's lease
//! protocol: multiple in-process "hosts" drive one spool directory and
//! are killed, paused or zombified at injected points. Invariants under
//! every injection:
//!
//! * **exactly-once output** — every job ends with exactly one
//!   published report, queue/running/leases are empty afterwards, and
//!   the number of successful (non-fenced) publishes equals the number
//!   of jobs;
//! * **epoch fencing** — a zombie worker (claim held past lease
//!   expiry) can never publish: its attempt is fenced by the expired
//!   lease or the successor's bumped epoch, asserted in-test;
//! * **differential determinism** — runs use the engine's fixed-seed
//!   mode (modeled timings), so the merged fault-run reports are
//!   byte-identical (after the report-JSON normalization `fetch`
//!   applies) to a plain serial `run_local` of the same experiments.
//!
//! Timing margins are deliberately generous (waits poll actual lease
//! expiry instead of sleeping fixed amounts) so the suite stays
//! flake-free under `--test-threads=1` and `ELAPS_LEASE_TTL=1s` in the
//! tier-2 CI job.

use elaps::coordinator::lease::{self, FenceReason, PublishOutcome};
use elaps::coordinator::{io, ClaimOutcome, Experiment, Spooler};
use elaps::engine::{set_default_config, EngineConfig};
use elaps::figures::call;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Pin the process-default engine config to serial, fixed-seed
/// execution: modeled timings make every report a pure function of its
/// experiment, which is what turns "compare fault run against serial
/// run" into a byte-equality check. Idempotent, so concurrent tests in
/// this binary can all call it.
fn det_config() {
    set_default_config(EngineConfig::default().with_seed(7));
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_faults_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_exp(n: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: format!("flt{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

/// Canonical serialization of a report (the byte-identity yardstick).
fn normalize(r: &elaps::Report) -> String {
    io::report_to_json(r).to_string_pretty()
}

/// The serial reference: what a plain single-host run produces for
/// `exp` under the fixed-seed config.
fn serial_reference(exp: &Experiment) -> String {
    normalize(&elaps::coordinator::run_local(exp).unwrap())
}

fn count_json(dir: &Path, sub: &str) -> usize {
    std::fs::read_dir(dir.join(sub))
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                .count()
        })
        .unwrap_or(0)
}

/// Block until the claim's lease is past its expiry (plus a small
/// margin), polling the wall clock — no fixed sleeps, no flakes.
fn wait_past_expiry(expires_unix: f64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while lease::now_unix() <= expires_unix + 0.05 {
        assert!(Instant::now() < deadline, "lease never expired?");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn killed_worker_job_is_reclaimed_and_served_exactly_once() {
    det_config();
    let dir = tmpdir("kill");
    // generous TTL: the "fresh lease is never stolen" assertions below
    // must hold even when the test host stalls this thread for a while
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let b = Spooler::new(&dir).unwrap().with_host("hostB").with_ttl(ttl);
    let exp = small_exp(16);
    let id = a.submit(&exp).unwrap();
    // host A claims the job and "dies": the claim is simply dropped,
    // no publish, no heartbeat
    let killed = a.claim_next().unwrap().unwrap();
    assert_eq!(killed.lease.epoch, 1);
    // while the lease lives, nobody can steal the job — even with the
    // paranoid legacy tolerance of zero, because leases ignore mtimes
    assert_eq!(b.recover_stale(Duration::ZERO).unwrap(), 0);
    assert_eq!(b.claim_next().unwrap().map(|c| c.job_id), None);
    // after expiry, host B reclaims and serves it
    wait_past_expiry(killed.lease.expires_unix);
    assert_eq!(b.reclaim_expired().unwrap(), 1);
    assert_eq!(b.serve_one().unwrap().as_deref(), Some(id.as_str()));
    // exactly one report, byte-identical to the serial run
    assert_eq!(count_json(&dir, "done"), 1);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0, "lease released on publish");
    let report = b.fetch(&id).unwrap().unwrap();
    assert_eq!(normalize(&report), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zombie_publish_is_fenced_by_epoch() {
    det_config();
    let dir = tmpdir("zombie");
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let b = Spooler::new(&dir).unwrap().with_host("hostB").with_ttl(ttl);
    let exp = small_exp(20);
    let id = a.submit(&exp).unwrap();
    // host A claims under epoch 1, then pauses past its own expiry
    let zombie = a.claim_next().unwrap().unwrap();
    assert_eq!(zombie.lease.epoch, 1);
    wait_past_expiry(zombie.lease.expires_unix);
    // the zombie can no longer renew...
    assert!(!a.renew(&zombie).unwrap());
    // ...host B reclaims and re-acquires under a bumped epoch
    assert_eq!(b.reclaim_expired().unwrap(), 1);
    let succ = b.claim_next().unwrap().unwrap();
    assert_eq!(succ.job_id, id);
    assert_eq!(succ.lease.epoch, 2, "reacquisition must bump the epoch");
    assert!(succ.lease.epoch > zombie.lease.epoch, "the epoch fence");
    // the zombie wakes up and tries to publish a poisoned payload:
    // fenced by the successor's epoch, nothing is written
    let outcome = a.publish(&zombie, r#"{"error":"ZOMBIE PAYLOAD"}"#).unwrap();
    assert_eq!(
        outcome,
        PublishOutcome::Fenced(FenceReason::Superseded {
            current_epoch: 2,
            current_worker: succ.lease.worker_id.clone(),
        })
    );
    assert_eq!(count_json(&dir, "done"), 0, "fenced publish writes nothing");
    // the successor publishes normally
    assert!(b.serve_claim(&succ, false).unwrap().published());
    let raw = std::fs::read_to_string(dir.join("done").join(format!("{id}.report.json")))
        .unwrap();
    assert!(!raw.contains("ZOMBIE"), "zombie payload must never land: {raw}");
    assert!(raw.contains("hostB"), "provenance names the real server: {raw}");
    // a second zombie attempt after completion is fenced too (the
    // lease is gone)
    assert_eq!(
        a.publish(&zombie, "{}").unwrap(),
        PublishOutcome::Fenced(FenceReason::LeaseGone)
    );
    assert_eq!(normalize(&b.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn expired_lease_fences_publish_even_before_reclaim() {
    det_config();
    let dir = tmpdir("expired");
    let ttl = Duration::from_millis(1000);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let exp = small_exp(12);
    let id = a.submit(&exp).unwrap();
    let claim = a.claim_next().unwrap().unwrap();
    wait_past_expiry(claim.lease.expires_unix);
    // nobody reclaimed yet, but the lease is expired: publishing now
    // could race a reclaim that is about to happen, so it is refused
    match a.publish(&claim, "{}").unwrap() {
        PublishOutcome::Fenced(FenceReason::Expired { expires_unix }) => {
            assert!((expires_unix - claim.lease.expires_unix).abs() < 1e-6);
        }
        other => panic!("expected an expiry fence, got {other:?}"),
    }
    assert_eq!(count_json(&dir, "done"), 0);
    // normal recovery still works afterwards
    assert_eq!(a.reclaim_expired().unwrap(), 1);
    assert_eq!(a.serve_one().unwrap().as_deref(), Some(id.as_str()));
    assert_eq!(normalize(&a.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn heartbeat_keeps_a_paused_worker_alive_across_ttls() {
    det_config();
    let dir = tmpdir("pause");
    let ttl = Duration::from_millis(1000);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let exp = small_exp(12);
    let id = a.submit(&exp).unwrap();
    let claim = a.claim_next().unwrap().unwrap();
    // the worker pauses for ~2 TTLs total but keeps heartbeating at a
    // 5x margin: the lease must stay unexpired and unreclaimable
    for _ in 0..10 {
        std::thread::sleep(Duration::from_millis(200));
        assert!(a.renew(&claim).unwrap(), "heartbeat must keep the lease ours");
        assert_eq!(a.reclaim_expired().unwrap(), 0, "a renewed lease is never reclaimed");
    }
    assert!(a.serve_claim(&claim, false).unwrap().published());
    assert_eq!(normalize(&a.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_renew_is_serialized_against_reacquisition() {
    det_config();
    let dir = tmpdir("renewrace");
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let b = Spooler::new(&dir).unwrap().with_host("hostB").with_ttl(ttl);
    let exp = small_exp(16);
    let id = a.submit(&exp).unwrap();
    let claim = a.claim_next().unwrap().unwrap();
    assert_eq!(claim.lease.epoch, 1);
    // Inject an expiry + reclaim + re-acquisition into the renewal's
    // historical read-modify-write window. The unserialized renew
    // checked the lease once and then wrote its extension back
    // unconditionally: it would return true here and put an epoch-1
    // lease back over the successor's epoch-2 one, letting BOTH
    // workers pass the publish fence. The locked renew re-verifies
    // under the per-job lease lock and must refuse instead.
    let mut succ = None;
    let renewed = a
        .renew_with_pause(&claim, || {
            wait_past_expiry(claim.lease.expires_unix);
            assert_eq!(b.reclaim_expired().unwrap(), 1);
            let c = b.claim_next().unwrap().unwrap();
            assert_eq!(c.job_id, id);
            assert_eq!(c.lease.epoch, 2, "re-acquisition bumps the epoch");
            succ = Some(c);
        })
        .unwrap();
    assert!(!renewed, "a renew that lost its lease must refuse to extend it");
    let succ = succ.expect("the injected re-acquisition must have claimed");
    // the successor's lease is untouched: same epoch, same worker
    let on_disk = lease::read(&dir, &id).unwrap();
    assert_eq!(on_disk.epoch, 2, "a stale renew must never regress the epoch");
    assert_eq!(on_disk.worker_id, succ.lease.worker_id);
    // the loser's publish is fenced...
    let outcome = a.publish(&claim, r#"{"error":"STALE RENEW PAYLOAD"}"#).unwrap();
    assert_eq!(
        outcome,
        PublishOutcome::Fenced(FenceReason::Superseded {
            current_epoch: 2,
            current_worker: succ.lease.worker_id.clone(),
        })
    );
    assert_eq!(count_json(&dir, "done"), 0, "fenced publish writes nothing");
    // ...and the successor's wins: exactly one report, byte-identical
    assert!(b.serve_claim(&succ, false).unwrap().published());
    assert_eq!(count_json(&dir, "done"), 1);
    let raw = std::fs::read_to_string(dir.join("done").join(format!("{id}.report.json")))
        .unwrap();
    assert!(!raw.contains("STALE RENEW"), "loser payload must never land: {raw}");
    assert_eq!(normalize(&b.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn claim_writes_lease_before_rename() {
    det_config();
    let dir = tmpdir("claimorder");
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let exp = small_exp(12);
    let id = a.submit(&exp).unwrap();
    let fired = AtomicBool::new(false);
    let outcome = a
        .try_claim_with_pause(|job_id| {
            fired.store(true, Ordering::Relaxed);
            // inside the injection window the lease is on disk...
            let l = lease::read(&dir, job_id).expect("the lease must precede the rename");
            assert_eq!(l.epoch, 1);
            assert_eq!(l.worker_id, a.worker_id());
            // ...while the job file has not moved yet: a crash right
            // here leaves a queued job with an expiring lease, never a
            // lease-less running job for the slow mtime heuristic
            assert!(dir.join("queue").join(format!("{job_id}.json")).exists());
            assert!(!dir.join("running").join(format!("{job_id}.json")).exists());
        })
        .unwrap();
    assert!(fired.load(Ordering::Relaxed), "the injection hook must fire");
    let claim = match outcome {
        ClaimOutcome::Claimed(c) => c,
        other => panic!("expected a claim, got {other:?}"),
    };
    assert_eq!(claim.job_id, id);
    assert!(a.serve_claim(&claim, false).unwrap().published());
    assert_eq!(normalize(&a.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn claimer_crash_between_lease_and_rename_leaves_job_recoverable() {
    det_config();
    let dir = tmpdir("claimcrash");
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let b = Spooler::new(&dir).unwrap().with_host("hostB").with_ttl(ttl);
    let exp = small_exp(16);
    let id = a.submit(&exp).unwrap();
    // host A "crashes" in the historical stranding window: after its
    // lease hit the disk, before the queue→running rename
    let crash = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = a.try_claim_with_pause(|_| panic!("injected claimer crash"));
    }));
    assert!(crash.is_err(), "the injected crash must propagate");
    // the residue: the job is still queued, under A's unexpired
    // epoch-1 lease — nothing was stranded in running/
    assert!(dir.join("queue").join(format!("{id}.json")).exists());
    assert_eq!(count_json(&dir, "running"), 0);
    let residue = lease::read(&dir, &id).unwrap();
    assert_eq!(residue.epoch, 1);
    assert!(!residue.expired_at(lease::now_unix()));
    // host B claims immediately — the crashed claimer's advisory lock
    // died with it, and the residue lease only feeds the epoch chain;
    // no expiry wait, no recover_stale pass needed
    let succ = b.claim_next().unwrap().unwrap();
    assert_eq!(succ.job_id, id);
    assert_eq!(succ.lease.epoch, 2, "must chain past the residue lease");
    assert!(b.serve_claim(&succ, false).unwrap().published());
    assert_eq!(count_json(&dir, "done"), 1);
    assert_eq!(count_json(&dir, "leases"), 0, "lease released on publish");
    assert_eq!(normalize(&b.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rename_loser_withdraws_its_own_lease() {
    det_config();
    let dir = tmpdir("renamelost");
    let ttl = Duration::from_millis(1500);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let exp = small_exp(12);
    let id = a.submit(&exp).unwrap();
    let queued = dir.join("queue").join(format!("{id}.json"));
    let running = dir.join("running").join(format!("{id}.json"));
    // a claimer outside the lock protocol (an older binary sharing the
    // spool) steals the queue file inside the injection window; our
    // claimer loses the rename and must withdraw the lease it wrote
    let outcome = a
        .try_claim_with_pause(|job_id| {
            assert_eq!(job_id, id);
            std::fs::rename(&queued, &running).unwrap();
        })
        .unwrap();
    assert!(matches!(outcome, ClaimOutcome::Empty), "{outcome:?}");
    assert_eq!(count_json(&dir, "leases"), 0, "the loser's lease must be withdrawn");
    assert!(running.exists(), "the thief owns the claim now");
    // the stolen claim is a legacy (lease-less) one; the mtime
    // heuristic recovers it and a normal serve finishes the job
    assert_eq!(a.recover_stale(Duration::ZERO).unwrap(), 1);
    assert_eq!(a.serve_one().unwrap().as_deref(), Some(id.as_str()));
    assert_eq!(count_json(&dir, "done"), 1);
    assert_eq!(normalize(&a.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_reclaim_is_serialized_against_concurrent_reclaim_and_reclaim() {
    det_config();
    let dir = tmpdir("legacyrace");
    let ttl = Duration::from_secs(30);
    let a = Spooler::new(&dir).unwrap().with_host("hostA").with_ttl(ttl);
    let b = Spooler::new(&dir).unwrap().with_host("hostB").with_ttl(ttl);
    let c = Spooler::new(&dir).unwrap().with_host("hostC").with_ttl(ttl);
    let exp = small_exp(16);
    let id = a.submit(&exp).unwrap();
    // a legacy claim: a pre-lease worker moved the job into running/
    // without writing any lease — only the mtime heuristic can judge it
    std::fs::rename(
        dir.join("queue").join(format!("{id}.json")),
        dir.join("running").join(format!("{id}.json")),
    )
    .unwrap();
    // Reclaimer A pre-checks the claim as stale, then pauses. In the
    // pause window a concurrent reclaimer B requeues the job and a
    // fresh worker C re-claims it under the lease protocol. The rename
    // preserved the claim file's old mtime, so A's heuristic STILL
    // calls it stale — the unserialized reclaim would now steal C's
    // live claim back into the queue and the job would run twice. The
    // locked re-verify must see C's lease instead and skip.
    let fired = AtomicUsize::new(0);
    let mut succ = None;
    let recovered = a
        .recover_stale_with_pause(Duration::ZERO, |job_id| {
            assert_eq!(job_id, id);
            fired.fetch_add(1, Ordering::Relaxed);
            assert_eq!(b.recover_stale(Duration::ZERO).unwrap(), 1);
            let claim = c.claim_next().unwrap().unwrap();
            assert_eq!(claim.job_id, id);
            assert_eq!(claim.lease.epoch, 1);
            succ = Some(claim);
        })
        .unwrap();
    assert_eq!(fired.load(Ordering::Relaxed), 1, "the injection hook must fire");
    assert_eq!(recovered, 0, "a live successor claim must never be re-reclaimed");
    let succ = succ.expect("the injected re-claim must have claimed");
    // C's claim and lease are untouched: still running, still epoch 1
    assert!(dir.join("running").join(format!("{id}.json")).exists());
    assert_eq!(count_json(&dir, "queue"), 0, "the job must not be stolen back");
    let on_disk = lease::read(&dir, &id).unwrap();
    assert_eq!(on_disk.epoch, 1);
    assert!(!on_disk.expired_at(lease::now_unix()));
    // C serves normally: exactly one report, byte-identical
    assert!(c.serve_claim(&succ, false).unwrap().published());
    assert_eq!(count_json(&dir, "done"), 1);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0, "lease released on publish");
    assert_eq!(normalize(&c.fetch(&id).unwrap().unwrap()), serial_reference(&exp));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The multi-host fault storm: `workers` in-process hosts drain one
/// spool while injections kill the first claim of host 0, zombify the
/// first claim of host 1 and pause-with-heartbeat the first claim of
/// host 2. Asserts exactly-once output and byte-identity against the
/// serial run.
fn fault_storm(workers: usize) {
    det_config();
    let dir = tmpdir(&format!("storm{workers}"));
    let ttl = Duration::from_millis(400);
    let submitter = Spooler::new(&dir).unwrap();
    let exps: Vec<Experiment> = (0..6).map(|i| small_exp(8 + 4 * i)).collect();
    let ids: Vec<String> = exps.iter().map(|e| submitter.submit(e).unwrap()).collect();
    let references: Vec<String> = exps.iter().map(serial_reference).collect();

    let spoolers: Vec<Spooler> = (0..workers)
        .map(|w| {
            Spooler::new(&dir)
                .unwrap()
                .with_host(format!("h{w}"))
                .with_worker(format!("h{w}#storm"))
                .with_ttl(ttl)
        })
        .collect();
    let published = AtomicUsize::new(0);
    let fenced = AtomicUsize::new(0);
    let total = ids.len();
    let deadline = Instant::now() + Duration::from_secs(120);
    std::thread::scope(|s| {
        for (w, sp) in spoolers.iter().enumerate() {
            let published = &published;
            let fenced = &fenced;
            s.spawn(move || {
                // one scripted injection per host, then honest serving
                let mut inject_kill = w == 0;
                let mut inject_zombie = workers > 1 && w == 1;
                let mut inject_pause = workers > 2 && w == 2;
                loop {
                    if count_json(&sp.dir, "done") >= total {
                        break;
                    }
                    assert!(Instant::now() < deadline, "fault storm did not converge");
                    sp.reclaim_expired().unwrap();
                    let Some(claim) = sp.claim_next().unwrap() else {
                        std::thread::sleep(Duration::from_millis(20));
                        continue;
                    };
                    if inject_kill {
                        inject_kill = false;
                        // kill: drop the claim, no publish, no
                        // heartbeat — the lease just expires
                        continue;
                    }
                    if inject_zombie {
                        inject_zombie = false;
                        // zombie: outlive the lease, then attempt a
                        // poisoned late publish — must be fenced
                        wait_past_expiry(claim.lease.expires_unix);
                        sp.reclaim_expired().unwrap();
                        match sp.publish(&claim, r#"{"error":"ZOMBIE"}"#).unwrap() {
                            PublishOutcome::Published => {
                                panic!("zombie publish must be fenced")
                            }
                            PublishOutcome::Fenced(_) => {
                                fenced.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        continue;
                    }
                    if inject_pause {
                        inject_pause = false;
                        // pause: stall for ~1.5 TTLs but heartbeat at a
                        // generous margin, then serve normally
                        for _ in 0..12 {
                            std::thread::sleep(Duration::from_millis(50));
                            if !sp.renew(&claim).unwrap() {
                                break;
                            }
                        }
                    }
                    if sp.serve_claim(&claim, true).unwrap().published() {
                        published.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });

    // exactly-once: one report per job, every publish that landed was
    // a real one, and the spool is fully drained
    assert_eq!(count_json(&dir, "done"), total);
    assert_eq!(published.load(Ordering::Relaxed), total, "each job published exactly once");
    if workers > 1 {
        assert_eq!(fenced.load(Ordering::Relaxed), 1, "the zombie was fenced");
    }
    assert_eq!(count_json(&dir, "queue"), 0);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0, "all leases released");
    // differential: the merged reports are byte-identical to the
    // serial run of the same experiments
    for (id, reference) in ids.iter().zip(&references) {
        let report = submitter.fetch(id).unwrap().unwrap();
        assert_eq!(&normalize(&report), reference, "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fault_storm_single_worker_recovers_its_own_kill() {
    fault_storm(1);
}

#[test]
fn fault_storm_four_hosts_kill_pause_zombie() {
    fault_storm(4);
}

#[test]
fn worker_pool_drains_gracefully_on_shutdown_flag() {
    det_config();
    let dir = tmpdir("drainflag");
    let spool = Spooler::new(&dir).unwrap().with_ttl(Duration::from_secs(30));
    let total = 6usize;
    let ids: Vec<String> =
        (0..total).map(|i| spool.submit(&small_exp(8 + 2 * i as i64)).unwrap()).collect();
    let shutdown = AtomicBool::new(false);
    let served = std::thread::scope(|s| {
        let handle = s.spawn(|| spool.run_worker_pool(2, false, None, &shutdown).unwrap());
        // let the pool make some progress, then raise the SIGTERM flag
        let deadline = Instant::now() + Duration::from_secs(60);
        while count_json(&dir, "done") < 2 {
            assert!(Instant::now() < deadline, "pool made no progress");
            std::thread::sleep(Duration::from_millis(20));
        }
        shutdown.store(true, Ordering::Relaxed);
        handle.join().unwrap()
    });
    // graceful: in-flight jobs were finished and published, nothing is
    // left half-claimed, unclaimed jobs stay queued for the next pool
    assert!(served >= 2, "{served}");
    assert!(served <= total);
    assert_eq!(count_json(&dir, "running"), 0, "no abandoned claims");
    assert_eq!(count_json(&dir, "leases"), 0, "no abandoned leases");
    assert_eq!(count_json(&dir, "done"), served);
    assert_eq!(spool.queued().unwrap(), total - served);
    // a fresh pool (fresh flag) finishes the drain
    let rest = spool
        .run_worker_pool(2, true, None, &AtomicBool::new(false))
        .unwrap();
    assert_eq!(served + rest, total);
    for id in &ids {
        assert!(spool.fetch(id).unwrap().is_some(), "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ CLI path

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

#[test]
fn worker_and_spool_status_cli() {
    let dir = tmpdir("cli");
    let spool = Spooler::new(&dir).unwrap();
    let ids: Vec<String> = (0..2).map(|_| spool.submit(&small_exp(10)).unwrap()).collect();
    let spool_s = dir.to_str().unwrap().to_string();
    // status before serving: 2 queued, nothing done
    let out = std::process::Command::new(elaps_bin())
        .args(["spool", "status", "--spool", &spool_s])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("queued: 2"), "{text}");
    assert!(text.contains("done: 0"), "{text}");
    // a one-shot worker daemon with an explicit lease TTL and host
    let out = std::process::Command::new(elaps_bin())
        .args([
            "worker", "--spool", &spool_s, "--once", "--workers", "2", "--lease-ttl", "30s",
        ])
        .env("ELAPS_HOST", "clihost")
        .env_remove("ELAPS_JOBS")
        .env_remove("ELAPS_CACHE")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("served 2 job(s)"), "{text}");
    for id in &ids {
        assert!(spool.fetch(id).unwrap().is_some(), "{id}");
    }
    // status after: drained, and the done reports are grouped by the
    // serving host's provenance stamp
    let out = std::process::Command::new(elaps_bin())
        .args(["spool", "status", "--spool", &spool_s])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("queued: 0"), "{text}");
    assert!(text.contains("done: 2"), "{text}");
    assert!(text.contains("clihost"), "{text}");
    // a malformed --lease-ttl is a hard error, not a silent default
    let out = std::process::Command::new(elaps_bin())
        .args(["worker", "--spool", &spool_s, "--once", "--lease-ttl", "garbage"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("lease-ttl"), "{err}");
    // status on a directory that is not a spool fails cleanly
    let out = std::process::Command::new(elaps_bin())
        .args(["spool", "status", "--spool", dir.join("nope").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let _ = std::fs::remove_dir_all(&dir);
}
