//! Observability acceptance suite: the structured job-lifecycle event
//! log and the `elaps analyze` campaign analysis layer, end to end.
//! Invariants:
//!
//! * **zero result perturbation** — a seeded two-host campaign drain
//!   produces byte-identical done reports with events on and with
//!   `--no-events`; the log is an observer, never a participant;
//! * **exactly-once audit** — `analyze` reconstructs every job's
//!   lifecycle from the per-host logs: one `published` event per done
//!   job, campaign-consistent counts, finite ordered percentiles
//!   (p50 ≤ p90 ≤ p99) for queue-wait / service / publish;
//! * **fence visibility** — a kill-injected worker (claim, lose the
//!   lease, publish late) shows up as a `fenced` event on its host
//!   without breaking the audit: the reclaimer's publish is the one
//!   that counts;
//! * **CLI surface** — `elaps analyze --json` and `elaps spool status
//!   --json` emit parseable, NaN-free JSON through the real binary.
//!
//! Like `campaign_roundtrip.rs`, timing margins are generous and waits
//! poll real state, so the suite stays flake-free under
//! `--test-threads=1` with `ELAPS_LEASE_TTL=1s` in the tier-2 CI leg.

use elaps::coordinator::campaign;
use elaps::coordinator::{io, Experiment, PublishOutcome, Spooler};
use elaps::engine::{set_default_config, EngineConfig};
use elaps::figures::call;
use elaps::obs::analyze;
use elaps::obs::events::{read_events, EventKind};
use elaps::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant};

/// Seeded modeled timings: every report is a pure function of its
/// experiment, so the events-on vs events-off comparison is a
/// byte-equality check. CLI workers get the same config via `--seed 7`.
fn det_config() {
    set_default_config(EngineConfig::default().with_seed(7));
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps_observe_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Equal-width sizes keep queue order (lexicographic by job file name)
/// aligned with submission order.
fn small_exp(n: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: format!("obs{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

/// A CLI invocation scrubbed of the engine/spool environment the test
/// process may have inherited, so subprocesses see exactly the flags
/// we pass (plus `ELAPS_HOST` where a test sets one).
fn elaps_cmd(args: &[&str]) -> Command {
    let mut cmd = Command::new(elaps_bin());
    cmd.args(args);
    for var in [
        "ELAPS_JOBS",
        "ELAPS_CACHE",
        "ELAPS_WARM",
        "ELAPS_SEED",
        "ELAPS_TRUSTED_ONLY",
        "ELAPS_HOST",
        "ELAPS_EVENTS",
    ] {
        cmd.env_remove(var);
    }
    cmd
}

// ------------------------------------- differential: events-on == off

/// Drain one campaign over two pinned simulated hosts, alternating
/// jobs between them in submission order. Returns the job ids.
fn drain_two_hosts(dir: &Path, events: bool, exps: &[Experiment]) -> Vec<String> {
    let client = Spooler::new(dir).unwrap().with_events(events);
    let ids = campaign::submit_experiments(&client, Some("camp-obs"), exps).unwrap();
    let a = Spooler::new(dir)
        .unwrap()
        .with_events(events)
        .with_host("obsA")
        .with_worker("obsA#w0");
    let b = Spooler::new(dir)
        .unwrap()
        .with_events(events)
        .with_host("obsB")
        .with_worker("obsB#w0");
    for (i, id) in ids.iter().enumerate() {
        let sp = if i % 2 == 0 { &a } else { &b };
        let served = sp.serve_one().unwrap();
        assert_eq!(served.as_deref(), Some(id.as_str()), "serve order for job {i}");
    }
    ids
}

#[test]
fn two_host_campaign_reports_are_byte_identical_with_and_without_events() {
    det_config();
    let base = tmpdir("diff");
    std::fs::create_dir_all(&base).unwrap();
    let exps: Vec<Experiment> = (0..4).map(|i| small_exp(10 + 2 * i)).collect();

    let dir_on = base.join("on");
    let dir_off = base.join("off");
    let ids_on = drain_two_hosts(&dir_on, true, &exps);
    let ids_off = drain_two_hosts(&dir_off, false, &exps);

    // the observer never perturbs the observed: identical raw report
    // bytes per submission slot (hosts, workers and epochs are pinned)
    for (on, off) in ids_on.iter().zip(&ids_off) {
        let on_bytes = std::fs::read(dir_on.join("done").join(format!("{on}.report.json"))).unwrap();
        let off_bytes =
            std::fs::read(dir_off.join("done").join(format!("{off}.report.json"))).unwrap();
        assert_eq!(on_bytes, off_bytes, "report bytes differ for {on} vs {off}");
    }
    // --no-events leaves no event log at all
    assert!(read_events(&dir_off).events.is_empty());

    // events-on: full lifecycle reconstructed, exactly once per job
    let scan = read_events(&dir_on);
    assert_eq!(scan.skipped, 0);
    let a = analyze(&dir_on, Some("camp-obs")).unwrap();
    assert!(a.audit.ok(), "audit violations: {:?}", a.audit.violations);
    assert_eq!(a.audit.done, 4);
    assert_eq!(a.audit.published_once, 4);
    for kind in ["submitted", "claimed", "serve_started", "serve_finished", "published"] {
        assert_eq!(a.counts.get(kind), Some(&4), "count of '{kind}' events");
    }
    assert_eq!(a.counts.get("fenced"), None);
    for (label, l) in [("queue_wait", &a.queue_wait), ("service", &a.service), ("publish", &a.publish)]
    {
        assert_eq!(l.n, 4, "{label} sample count");
        assert!(l.p50.is_finite() && l.p90.is_finite() && l.p99.is_finite(), "{label}: {l:?}");
        assert!(l.p50 <= l.p90 && l.p90 <= l.p99, "{label} percentiles out of order: {l:?}");
        assert!(l.p50 >= 0.0, "{label}: negative latency");
    }
    assert_eq!(a.hosts.get("obsA").map(|h| (h.published, h.fenced)), Some((2, 0)));
    assert_eq!(a.hosts.get("obsB").map(|h| (h.published, h.fenced)), Some((2, 0)));
    // seeded modeled run without a cache: every executed point is a
    // cache_skip in the "seeded" class, attributed via the job context
    let seeded = a.cache.get("seeded").unwrap();
    assert_eq!((seeded.hits, seeded.misses), (0, 0));
    assert_eq!(seeded.skips, 4, "{seeded:?}");

    // JSON stays parseable (NaN-free) and agrees with the struct
    let text = a.to_json().to_string_pretty();
    assert!(!text.contains("NaN"), "{text}");
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("audit").get("ok").as_bool(), Some(true));
    assert_eq!(j.get("audit").get("done").as_u64(), Some(4));
    assert_eq!(j.get("events").get("by_kind").get("published").as_u64(), Some(4));
    assert!(a.render().contains("PASS"));
    let _ = std::fs::remove_dir_all(&base);
}

// ----------------------------------------- fence visibility under kill

#[test]
fn killed_worker_surfaces_as_fenced_publish_without_breaking_audit() {
    det_config();
    let dir = tmpdir("fence");
    let zombie = Spooler::new(&dir)
        .unwrap()
        .with_events(true)
        .with_host("obsZ")
        .with_worker("obsZ#w0")
        .with_ttl(Duration::from_millis(50));
    let id = zombie.submit(&small_exp(8)).unwrap();

    // the "kill": claim without heartbeating, then stall past the TTL
    let claim = zombie.claim_next().unwrap().unwrap();
    let healthy = Spooler::new(&dir)
        .unwrap()
        .with_events(true)
        .with_host("obsH")
        .with_worker("obsH#w0");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if healthy.reclaim_expired().unwrap() == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "zombie lease never expired");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(healthy.serve_one().unwrap().as_deref(), Some(id.as_str()));

    // the zombie wakes up and tries to publish its stale epoch
    let outcome = zombie.serve_claim(&claim, false).unwrap();
    assert!(matches!(outcome, PublishOutcome::Fenced(_)), "{outcome:?}");

    // the log tells the story: one real publish (obsH), one fence (obsZ)
    let scan = read_events(&dir);
    let published: Vec<_> = scan
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Published && e.job_id == id)
        .collect();
    assert_eq!(published.len(), 1);
    assert_eq!(published[0].host, "obsH");
    let fenced: Vec<_> = scan
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Fenced && e.job_id == id)
        .collect();
    assert_eq!(fenced.len(), 1);
    assert_eq!(fenced[0].host, "obsZ");
    assert!(fenced[0].extra.get("reason").is_some(), "{:?}", fenced[0]);

    // ...and analyze still passes the audit: fenced alongside one
    // publish is the lease protocol working, not a violation
    let a = analyze(&dir, None).unwrap();
    assert!(a.audit.ok(), "{:?}", a.audit.violations);
    assert_eq!(a.audit.done, 1);
    assert_eq!(a.audit.published_once, 1);
    assert_eq!(a.counts.get("fenced"), Some(&1));
    assert_eq!(a.hosts.get("obsZ").map(|h| (h.published, h.fenced)), Some((0, 1)));
    assert_eq!(a.hosts.get("obsH").map(|h| (h.published, h.fenced)), Some((1, 0)));
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- CLI end to end

#[test]
fn cli_analyze_json_and_spool_status_json_report_the_drained_campaign() {
    det_config();
    let dir = tmpdir("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let spool_dir = dir.join("spool");
    let spool_s = spool_dir.to_str().unwrap().to_string();

    // submit three experiments by path under one campaign tag
    let exps: Vec<Experiment> = (0..3).map(|i| small_exp(10 + 2 * i)).collect();
    let mut paths: Vec<String> = Vec::new();
    for (i, e) in exps.iter().enumerate() {
        let p = dir.join(format!("exp{i}.json"));
        std::fs::write(&p, io::experiment_to_json(e).to_string_pretty()).unwrap();
        paths.push(p.to_str().unwrap().to_string());
    }
    let mut args: Vec<&str> = vec!["submit"];
    args.extend(paths.iter().map(|s| s.as_str()));
    args.extend_from_slice(&["--campaign", "camp-cli", "--spool", &spool_s]);
    let out = elaps_cmd(&args).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // two worker daemons on simulated hosts drain the queue
    let spawn_worker = |host: &str| {
        let mut cmd =
            elaps_cmd(&["worker", "--spool", &spool_s, "--once", "--workers", "2", "--seed", "7"]);
        cmd.env("ELAPS_HOST", host);
        cmd.spawn().unwrap()
    };
    let mut wa = spawn_worker("cliA");
    let mut wb = spawn_worker("cliB");
    assert!(wa.wait().unwrap().success());
    assert!(wb.wait().unwrap().success());

    // analyze --json: exactly-once audit, finite ordered percentiles
    let out = elaps_cmd(&["analyze", "--campaign", "camp-cli", "--spool", &spool_s, "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(!stdout.contains("NaN"), "{stdout}");
    let j = Json::parse(&stdout).unwrap();
    assert_eq!(j.get("audit").get("ok").as_bool(), Some(true), "{stdout}");
    assert_eq!(j.get("audit").get("done").as_u64(), Some(3));
    assert_eq!(j.get("audit").get("published_once").as_u64(), Some(3));
    assert_eq!(j.get("events").get("by_kind").get("submitted").as_u64(), Some(3));
    assert_eq!(j.get("events").get("by_kind").get("published").as_u64(), Some(3));
    for metric in ["queue_wait_s", "service_s", "publish_s"] {
        let lat = j.get("latency").get(metric);
        assert_eq!(lat.get("n").as_u64(), Some(3), "{metric}");
        let p50 = lat.get("p50").as_f64().unwrap();
        let p90 = lat.get("p90").as_f64().unwrap();
        let p99 = lat.get("p99").as_f64().unwrap();
        assert!(p50.is_finite() && p50 <= p90 && p90 <= p99, "{metric}: {p50} {p90} {p99}");
    }

    // the human table agrees on the audit
    let out =
        elaps_cmd(&["analyze", "--campaign", "camp-cli", "--spool", &spool_s]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("PASS"));

    // spool status --json mirrors the drained spool
    let out = elaps_cmd(&["spool", "status", "--spool", &spool_s, "--json"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("queued").as_u64(), Some(0));
    assert_eq!(j.get("done").as_u64(), Some(3));
    assert_eq!(j.get("done_errors").as_u64(), Some(0));

    // a --no-events rerun of the same flow writes no event log, and
    // analyze degrades gracefully instead of failing
    let spool2 = dir.join("spool2");
    let spool2_s = spool2.to_str().unwrap().to_string();
    let out = elaps_cmd(&["submit", &paths[0], "--spool", &spool2_s, "--no-events"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = elaps_cmd(&["worker", "--spool", &spool2_s, "--once", "--seed", "7", "--no-events"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(read_events(&spool2).events.is_empty());
    let out = elaps_cmd(&["analyze", "--spool", &spool2_s]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("no events recorded"));
    let _ = std::fs::remove_dir_all(&dir);
}
