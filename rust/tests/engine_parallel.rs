//! Integration tests for the parallel execution engine: determinism of
//! N-thread runs vs serial, cache hit/miss behaviour (a cached re-run
//! executes zero sampler scripts), and batch submission.

use elaps::coordinator::{Experiment, Metric, RangeDef, Stat};
use elaps::engine::{Engine, EngineConfig};
use elaps::figures::call;
use elaps::Report;

/// A range experiment with enough points to keep several workers busy.
fn range_experiment(name: &str, values: Vec<i64>) -> Experiment {
    let mut exp = Experiment {
        name: name.into(),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        range: Some(RangeDef::new("n", values)),
        counters: vec!["PAPI_L1_TCM".into(), "PAPI_L3_TCM".into()],
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
    )
    .unwrap()];
    exp
}

/// Everything about a report that is deterministic (wall times are
/// not): point order and shape, kernels, simulated counters, flop
/// counts and OpenMP groups must be bit-identical between runs.
fn assert_structurally_identical(a: &Report, b: &Report) {
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.range_value, pb.range_value);
        assert_eq!(pa.nthreads, pb.nthreads);
        assert_eq!(pa.sum_iters, pb.sum_iters);
        assert_eq!(pa.calls_per_iter, pb.calls_per_iter);
        assert_eq!(pa.records.len(), pb.records.len());
        for (ra, rb) in pa.records.iter().zip(&pb.records) {
            assert_eq!(ra.kernel, rb.kernel);
            assert_eq!(ra.counters, rb.counters, "point {}", pa.range_value);
            assert_eq!(ra.flops, rb.flops);
            assert_eq!(ra.omp_group, rb.omp_group);
        }
    }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps_engine_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn parallel_run_is_structurally_identical_to_serial() {
    let exp = range_experiment("det", vec![16, 24, 32, 40, 48, 56]);
    let serial = Engine::new(EngineConfig::default().with_jobs(1)).run(&exp).unwrap();
    let parallel = Engine::new(EngineConfig::default().with_jobs(4)).run(&exp).unwrap();
    assert_structurally_identical(&serial, &parallel);
    // the deterministic metric (simulated counters) agrees exactly
    let s = serial.series(Metric::Counter(0), Stat::Median);
    let p = parallel.series(Metric::Counter(0), Stat::Median);
    assert_eq!(s, p);
}

#[test]
fn cached_rerun_executes_zero_sampler_scripts() {
    let dir = tmpdir("cache");
    let exp = range_experiment("cached", vec![16, 24, 32]);
    let engine = Engine::new(EngineConfig::default().with_jobs(2).with_cache(&dir));

    let (first, stats1) = engine.run_stats(&exp).unwrap();
    assert_eq!(stats1.executed, 3);
    assert_eq!(stats1.cache_hits, 0);

    let (second, stats2) = engine.run_stats(&exp).unwrap();
    assert_eq!(stats2.executed, 0, "second run must touch zero samplers");
    assert_eq!(stats2.cache_hits, 3);
    // the probe finds every hit before enqueueing: the experiment
    // bypasses the worker pool entirely
    assert_eq!(stats2.scheduled_hits, 3);
    assert_eq!(stats2.fully_cached, 1);
    assert_eq!(stats2.experiments, 1);
    assert!(stats2.summary_line().contains("0 executed"));
    assert!(stats2.summary_line().contains("3 cache hit(s)"));
    assert!(stats2.summary_line().contains("1/1 experiment(s) fully cached"));

    // the replayed report matches the stored measurements, times included
    assert_structurally_identical(&first, &second);
    let t1 = first.series(Metric::TimeS, Stat::Avg);
    let t2 = second.series(Metric::TimeS, Stat::Avg);
    for ((x1, v1), (x2, v2)) in t1.iter().zip(&t2) {
        assert_eq!(x1, x2);
        assert!((v1 - v2).abs() <= 1e-9 * v1.abs().max(1e-12), "{v1} vs {v2}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn overlapping_sweeps_share_cached_points() {
    let dir = tmpdir("overlap");
    let engine = Engine::new(EngineConfig::default().with_jobs(2).with_cache(&dir));
    let (_, s1) = engine.run_stats(&range_experiment("a", vec![16, 24])).unwrap();
    assert_eq!((s1.executed, s1.cache_hits), (2, 0));
    // same script content under a different experiment name: the
    // fingerprint is content-addressed, so the shared points hit
    let (_, s2) = engine.run_stats(&range_experiment("b", vec![16, 24, 32])).unwrap();
    assert_eq!((s2.executed, s2.cache_hits), (1, 2));
    // a partially-cached experiment enqueues only its misses
    assert_eq!(s2.scheduled_hits, 2);
    assert_eq!(s2.fully_cached, 0);
    assert_eq!(s2.jobs, 1, "one miss needs exactly one worker");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_submission_reports_in_input_order() {
    let exps = vec![
        range_experiment("batch-a", vec![16, 24]),
        range_experiment("batch-b", vec![32]),
        range_experiment("batch-c", vec![16, 40, 48]),
    ];
    let engine = Engine::new(EngineConfig::default().with_jobs(3));
    let (reports, stats) = engine.run_batch_stats(&exps).unwrap();
    assert_eq!(reports.len(), 3);
    assert_eq!(reports[0].experiment.name, "batch-a");
    assert_eq!(reports[1].experiment.name, "batch-b");
    assert_eq!(reports[2].experiment.name, "batch-c");
    assert_eq!(reports[0].points.len(), 2);
    assert_eq!(reports[1].points.len(), 1);
    assert_eq!(reports[2].points.len(), 3);
    assert_eq!(stats.total_points(), 6);
    // each report individually matches its serial run
    for (exp, parallel) in exps.iter().zip(&reports) {
        let serial = Engine::new(EngineConfig::default()).run(exp).unwrap();
        assert_structurally_identical(&serial, parallel);
    }
}

#[test]
fn parallel_probe_matches_serial_probe_exactly() {
    // the pre-enqueue cache probe fans out across the worker pool; its
    // combined result must be identical to the serial probe. Two cache
    // dirs are populated by identical serial runs, then the same
    // partially-cached batch is probed serially (jobs=1) in one dir and
    // in parallel (jobs=4) in the other.
    let exps = vec![
        range_experiment("probe-a", vec![16, 24, 32]),
        range_experiment("probe-b", vec![24, 40]),
        range_experiment("probe-c", vec![48]),
    ];
    // only the first two experiments are pre-cached: the batch below is
    // a mix of scheduled hits and misses
    let seeded: Vec<Experiment> = exps[..2].to_vec();
    let mut outcomes = Vec::new();
    for (tag, jobs) in [("serial", 1usize), ("parallel", 4)] {
        let dir = tmpdir(&format!("probe_{tag}"));
        let seed_engine = Engine::new(EngineConfig::default().with_cache(&dir));
        seed_engine.run_batch(&seeded).unwrap();
        let engine = Engine::new(EngineConfig::default().with_jobs(jobs).with_cache(&dir));
        outcomes.push((dir, engine.run_batch_stats(&exps).unwrap()));
    }
    let (serial, parallel) = (&outcomes[0].1, &outcomes[1].1);
    // identical accounting: same hits, same scheduled hits, same
    // misses, same fully-cached experiments
    assert_eq!(serial.1.scheduled_hits, parallel.1.scheduled_hits);
    assert_eq!(serial.1.cache_hits, parallel.1.cache_hits);
    assert_eq!(serial.1.executed, parallel.1.executed);
    assert_eq!(serial.1.fully_cached, parallel.1.fully_cached);
    assert_eq!(serial.1.scheduled_hits, 5, "the five pre-cached points must hit");
    assert_eq!(serial.1.executed, 1, "the one uncached point must execute");
    // identical reports (in their deterministic parts)
    for (a, b) in serial.0.iter().zip(&parallel.0) {
        assert_structurally_identical(a, b);
    }
    for (dir, _) in &outcomes {
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn engine_surfaces_sampler_failures() {
    let mut exp = range_experiment("bad", vec![16]);
    exp.machine = "nosuchmachine".into();
    let err = Engine::new(EngineConfig::default().with_jobs(2)).run(&exp).unwrap_err();
    assert!(err.to_string().contains("nosuchmachine"), "{err}");
}
