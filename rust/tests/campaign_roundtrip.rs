//! End-to-end differential campaign suite: the asynchronous client
//! workflow (`elaps submit` → worker daemons → `elaps wait` → `elaps
//! fetch`) driven through the real CLI binary across ≥2 simulated
//! hosts, plus the per-host `--max-leases` backpressure and the
//! stamp-sidecar O(#jobs) `spool status` path. Invariants:
//!
//! * **differential byte-identity** — with seeded modeled timings, the
//!   reports fetched from a multi-host campaign drain are
//!   byte-identical (after the report-JSON normalization) to a serial
//!   `run_local` of the same experiments, exactly once per job;
//! * **backpressure** — a host capped at `--max-leases 2` never holds
//!   more than 2 unexpired leases at any observation point, while an
//!   unconstrained host still drains the rest (no deadlock, no
//!   starvation);
//! * **O(#jobs) status** — `spool status` groups done reports by their
//!   stamp sidecars and never opens a report body: a deliberately
//!   corrupt done-report payload still yields correct per-host counts.
//!
//! Like `lease_faults.rs`, timing margins are generous and waits poll
//! real state, so the suite stays flake-free under `--test-threads=1`
//! with `ELAPS_LEASE_TTL=1s` in the tier-2 CI leg.

use elaps::coordinator::campaign::{self, StampOutcome};
use elaps::coordinator::lease;
use elaps::coordinator::ledger;
use elaps::coordinator::{io, ClaimOutcome, Experiment, Spooler};
use elaps::engine::{set_default_config, EngineConfig};
use elaps::figures::call;
use elaps::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Pin the process-default engine config to serial, fixed-seed
/// execution (modeled timings): every report becomes a pure function
/// of its experiment, turning the campaign-vs-serial comparison into a
/// byte-equality check. The CLI workers below get the same config via
/// `--seed 7`.
fn det_config() {
    set_default_config(EngineConfig::default().with_seed(7));
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_campaign_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_exp(n: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: format!("camp{n}"),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

/// Canonical serialization of a report (the byte-identity yardstick).
fn normalize(r: &elaps::Report) -> String {
    io::report_to_json(r).to_string_pretty()
}

fn count_json(dir: &Path, sub: &str) -> usize {
    std::fs::read_dir(dir.join(sub))
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                .count()
        })
        .unwrap_or(0)
}

fn elaps_bin() -> &'static str {
    env!("CARGO_BIN_EXE_elaps")
}

/// A CLI invocation scrubbed of the engine/spool environment the test
/// process may have inherited, so subprocesses see exactly the flags
/// we pass (plus `ELAPS_HOST` where a test sets one).
fn elaps_cmd(args: &[&str]) -> Command {
    let mut cmd = Command::new(elaps_bin());
    cmd.args(args);
    for var in [
        "ELAPS_JOBS",
        "ELAPS_CACHE",
        "ELAPS_WARM",
        "ELAPS_SEED",
        "ELAPS_TRUSTED_ONLY",
        "ELAPS_HOST",
        "ELAPS_EVENTS",
    ] {
        cmd.env_remove(var);
    }
    cmd
}

// ------------------------------------------------- the e2e roundtrip

#[test]
fn campaign_submit_wait_fetch_roundtrip_is_differential() {
    det_config();
    let dir = tmpdir("rt");
    std::fs::create_dir_all(&dir).unwrap();
    let spool_dir = dir.join("spool");
    let spool_s = spool_dir.to_str().unwrap().to_string();

    // the campaign: two experiments by path, two inline
    let exps: Vec<Experiment> = (0..4).map(|i| small_exp(8 + 4 * i)).collect();
    for (i, e) in exps.iter().enumerate().take(2) {
        std::fs::write(
            dir.join(format!("exp{i}.json")),
            io::experiment_to_json(e).to_string_pretty(),
        )
        .unwrap();
    }
    let mut mj = Json::obj();
    mj.set("campaign", "camp-rt").set(
        "experiments",
        Json::Arr(vec![
            Json::Str("exp0.json".into()),
            Json::Str("exp1.json".into()),
            io::experiment_to_json(&exps[2]),
            io::experiment_to_json(&exps[3]),
        ]),
    );
    let manifest = dir.join("manifest.json");
    std::fs::write(&manifest, mj.to_string_pretty()).unwrap();

    // submit: prints one job id per line on stdout, never blocks
    let out = elaps_cmd(&["submit", manifest.to_str().unwrap(), "--spool", &spool_s])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ids: Vec<String> = String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(String::from)
        .collect();
    assert_eq!(ids.len(), 4, "{ids:?}");
    assert_eq!(count_json(&spool_dir, "queue"), 4);
    // the CLI submit records the campaign in the ledger, not the old
    // flock'd record file — the resolved job list is identical
    assert!(ledger::has_ledger(&spool_dir, "camp-rt"));
    assert_eq!(ledger::campaign_jobs_resolved(&spool_dir, "camp-rt", true).unwrap(), ids);

    // two worker daemons on two simulated hosts drain the campaign
    // concurrently, each with a 2-thread pool and the same fixed seed
    let worker = |host: &str| {
        let mut cmd = elaps_cmd(&[
            "worker", "--spool", &spool_s, "--once", "--workers", "2", "--seed", "7",
        ]);
        cmd.env("ELAPS_HOST", host);
        cmd.spawn().unwrap()
    };
    let mut ha = worker("hostA");
    let mut hb = worker("hostB");
    assert!(ha.wait().unwrap().success());
    assert!(hb.wait().unwrap().success());

    // wait: the whole campaign by tag, O(#jobs) polling
    let out = elaps_cmd(&[
        "wait", "--campaign", "camp-rt", "--spool", &spool_s, "--timeout", "120s",
    ])
    .output()
    .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("ok (host host"), "{text}");
    assert!(text.contains("4 ok, 0 error"), "{text}");

    // fetch: raw report bytes to local files, one per job
    let fetched_dir = dir.join("fetched");
    let out = elaps_cmd(&[
        "fetch",
        "--campaign",
        "camp-rt",
        "--spool",
        &spool_s,
        "--out-dir",
        fetched_dir.to_str().unwrap(),
    ])
    .output()
    .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // exactly-once: one report + one stamp per job, spool fully drained
    assert_eq!(count_json(&spool_dir, "done"), 4);
    assert_eq!(count_json(&spool_dir, "queue"), 0);
    assert_eq!(count_json(&spool_dir, "running"), 0);
    assert_eq!(count_json(&spool_dir, "leases"), 0, "all leases released");
    let scan = campaign::read_stamps(&spool_dir);
    assert_eq!(scan.stamps.len(), 4);
    assert_eq!(scan.skipped, 0);
    for (id, stamp) in &scan.stamps {
        assert_eq!(stamp.outcome, StampOutcome::Ok, "{id}");
        assert!(stamp.host == "hostA" || stamp.host == "hostB", "{stamp:?}");
    }

    // differential: every fetched report is byte-identical to a serial
    // run_local of its experiment (same fixed seed), and the raw bytes
    // keep the served_by provenance + match the spool's copy exactly
    for (id, exp) in ids.iter().zip(&exps) {
        let path = fetched_dir.join(format!("{id}.report.json"));
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(raw.contains("served_by"), "{id}: {raw}");
        let in_spool =
            std::fs::read_to_string(spool_dir.join("done").join(format!("{id}.report.json")))
                .unwrap();
        assert_eq!(raw, in_spool, "{id}: fetch must be byte-for-byte");
        let report = io::report_from_json(&Json::parse(&raw).unwrap()).unwrap();
        let reference = normalize(&elaps::coordinator::run_local(exp).unwrap());
        assert_eq!(normalize(&report), reference, "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn submit_rejects_malformed_manifests_and_wait_times_out() {
    let dir = tmpdir("badcli");
    std::fs::create_dir_all(&dir).unwrap();
    let spool_dir = dir.join("spool");
    let spool_s = spool_dir.to_str().unwrap().to_string();
    // a manifest without a campaign tag is a hard error
    let bad = dir.join("bad.json");
    std::fs::write(&bad, r#"{"experiments":["x.json"]}"#).unwrap();
    let out =
        elaps_cmd(&["submit", bad.to_str().unwrap(), "--spool", &spool_s]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("campaign"), "{err}");
    // as is a dangling path entry
    let dangling = dir.join("dangling.json");
    std::fs::write(&dangling, r#"{"campaign":"c","experiments":["missing.json"]}"#).unwrap();
    assert!(!elaps_cmd(&["submit", dangling.to_str().unwrap(), "--spool", &spool_s])
        .output()
        .unwrap()
        .status
        .success());
    // waiting on an unserved job times out with the pending ids named
    let spool = Spooler::new(&spool_dir).unwrap();
    let id = spool.submit(&small_exp(8)).unwrap();
    let out = elaps_cmd(&["wait", &id, "--spool", &spool_s, "--timeout", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("timed out"), "{err}");
    // a malformed --timeout is a hard error, not a silent default
    let out = elaps_cmd(&["wait", &id, "--spool", &spool_s, "--timeout", "soon"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("timeout"), "{err}");
    // wait/fetch with nothing addressed is a usage error
    let out = elaps_cmd(&["wait", "--spool", &spool_s]).output().unwrap();
    assert!(!out.status.success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wait_surfaces_error_outcomes_from_stamps() {
    let dir = tmpdir("waiterr");
    let spool = Spooler::new(&dir).unwrap();
    let spool_s = dir.to_str().unwrap().to_string();
    // a poison job publishes an error report (and an error stamp)
    std::fs::write(dir.join("queue").join("poison.json"), "{not json").unwrap();
    assert_eq!(spool.serve_one().unwrap().as_deref(), Some("poison"));
    let stamp = campaign::read_stamp(&dir, "poison").unwrap();
    assert_eq!(stamp.outcome, StampOutcome::Error);
    // wait finds the report immediately but exits nonzero on the error
    let out = elaps_cmd(&["wait", "poison", "--spool", &spool_s, "--timeout", "10s"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("poison  error"), "{text}");
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("error report"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- backpressure

#[test]
fn max_leases_backpressures_claims_and_other_host_drains() {
    det_config();
    let dir = tmpdir("bp");
    let ttl = Duration::from_secs(30);
    let a = Spooler::new(&dir).unwrap().with_host("bpA").with_ttl(ttl).with_max_leases(2);
    let b = Spooler::new(&dir).unwrap().with_host("bpB").with_ttl(ttl);
    // equal-width sizes: queue order (lexicographic by job file name)
    // then matches submission order, which the claim assertions rely on
    let exps: Vec<Experiment> = (0..5).map(|i| small_exp(10 + 2 * i)).collect();
    let ids: Vec<String> = exps.iter().map(|e| a.submit(e).unwrap()).collect();
    // host A claims up to its cap...
    let c1 = match a.try_claim().unwrap() {
        ClaimOutcome::Claimed(c) => c,
        other => panic!("expected a claim, got {other:?}"),
    };
    let c2 = match a.try_claim().unwrap() {
        ClaimOutcome::Claimed(c) => c,
        other => panic!("expected a claim, got {other:?}"),
    };
    assert_eq!(lease::live_leases_for_host(&dir, "bpA").unwrap(), 2);
    // ...and is then refused more, even though jobs are queued
    assert!(matches!(a.try_claim().unwrap(), ClaimOutcome::Backpressured));
    assert!(a.claim_next().unwrap().is_none());
    assert_eq!(a.queued().unwrap(), 3, "backpressure must not consume the queue");
    // the unconstrained host is unaffected and drains the rest: the
    // capped host never starves the campaign
    assert_eq!(b.drain(2).unwrap(), 3);
    assert_eq!(count_json(&dir, "done"), 3);
    // still at its cap, but with the queue drained a capped host
    // reports Empty — a --once pool must be able to exit instead of
    // spinning on its own in-flight leases
    assert!(matches!(a.try_claim().unwrap(), ClaimOutcome::Empty));
    assert!(a.serve_claim(&c1, false).unwrap().published());
    drop(c1);
    assert_eq!(lease::live_leases_for_host(&dir, "bpA").unwrap(), 1);
    assert!(matches!(a.try_claim().unwrap(), ClaimOutcome::Empty));
    assert!(a.serve_claim(&c2, false).unwrap().published());
    drop(c2);
    // exactly once each, with per-host provenance in the stamps
    assert_eq!(count_json(&dir, "done"), 5);
    assert_eq!(count_json(&dir, "leases"), 0);
    let scan = campaign::read_stamps(&dir);
    assert_eq!(scan.stamps.len(), 5);
    assert_eq!(scan.stamps[&ids[0]].host, "bpA");
    assert_eq!(scan.stamps[&ids[1]].host, "bpA");
    for id in &ids[2..] {
        assert_eq!(scan.stamps[id].host, "bpB", "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn backpressured_pool_never_exceeds_cap_under_contention() {
    det_config();
    let dir = tmpdir("bp_storm");
    let ttl = Duration::from_secs(30);
    let total = 10usize;
    let submitter = Spooler::new(&dir).unwrap();
    for i in 0..total {
        submitter.submit(&small_exp(8 + 2 * (i as i64 % 5))).unwrap();
    }
    let a = Spooler::new(&dir).unwrap().with_host("bpA").with_ttl(ttl).with_max_leases(2);
    let b = Spooler::new(&dir).unwrap().with_host("bpB").with_ttl(ttl);
    let stop = AtomicBool::new(false);
    let max_seen = AtomicUsize::new(0);
    let flag_a = AtomicBool::new(false);
    let flag_b = AtomicBool::new(false);
    let (served_a, served_b) = std::thread::scope(|s| {
        // the observer: sample host A's live-lease count the whole
        // time; the backpressure contract is that it never exceeds 2
        // at ANY observation point
        let observer = s.spawn(|| {
            let mut worst = 0;
            while !stop.load(Ordering::Relaxed) {
                worst = worst.max(lease::live_leases_for_host(&dir, "bpA").unwrap());
                std::thread::sleep(Duration::from_millis(1));
            }
            worst
        });
        // an oversized pool on the capped host contends for the 2
        // slots; the unconstrained host races it for the same queue
        let ha = s.spawn(|| a.run_worker_pool(4, true, None, &flag_a).unwrap());
        let hb = s.spawn(|| b.run_worker_pool(2, true, None, &flag_b).unwrap());
        let served_a = ha.join().unwrap();
        let served_b = hb.join().unwrap();
        stop.store(true, Ordering::Relaxed);
        max_seen.store(observer.join().unwrap(), Ordering::Relaxed);
        (served_a, served_b)
    });
    // no deadlock, no starvation: the pools drained everything between
    // them, exactly once
    assert_eq!(served_a + served_b, total, "a={served_a} b={served_b}");
    assert_eq!(count_json(&dir, "done"), total);
    assert_eq!(count_json(&dir, "queue"), 0);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0);
    // the cap held at every observation point
    assert!(
        max_seen.load(Ordering::Relaxed) <= 2,
        "host A held {} live leases",
        max_seen.load(Ordering::Relaxed)
    );
    let scan = campaign::read_stamps(&dir);
    assert_eq!(scan.stamps.len(), total);
    assert_eq!(
        scan.stamps.values().filter(|s| s.host == "bpA").count(),
        served_a,
        "stamp provenance must match the pools' own counts"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_claim_batch_drains_exactly_once_under_cap_and_contention() {
    det_config();
    let dir = tmpdir("batchstorm");
    let ttl = Duration::from_secs(30);
    let total = 16usize;
    let submitter = Spooler::new(&dir).unwrap();
    let ids: Vec<String> = (0..total)
        .map(|i| submitter.submit(&small_exp(8 + 2 * (i as i64 % 5))).unwrap())
        .collect();
    // six claimer threads share ONE capped spooler handle: the shared
    // state under test is its claim batch (one queue scan feeding many
    // claims) and its lease-cap slot counter + amortized disk estimate
    let base =
        Spooler::new(&dir).unwrap().with_host("batchA").with_ttl(ttl).with_max_leases(3);
    let clones: Vec<Spooler> =
        (0..6).map(|i| base.clone().with_worker(format!("batchA#{i}"))).collect();
    let stop = AtomicBool::new(false);
    let served: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let max_seen = std::thread::scope(|s| {
        // the observer: the backpressure contract is that the host
        // never holds more than 3 live leases at ANY observation point,
        // batched claims or not
        let observer = s.spawn(|| {
            let mut worst = 0;
            while !stop.load(Ordering::Relaxed) {
                worst = worst.max(lease::live_leases_for_host(&dir, "batchA").unwrap());
                std::thread::sleep(Duration::from_millis(1));
            }
            worst
        });
        let handles: Vec<_> = clones
            .iter()
            .map(|sp| {
                let served = &served;
                s.spawn(move || loop {
                    match sp.try_claim().unwrap() {
                        ClaimOutcome::Claimed(claim) => {
                            assert!(sp.serve_claim(&claim, false).unwrap().published());
                            served.lock().unwrap().push(claim.job_id.clone());
                        }
                        ClaimOutcome::Backpressured => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        ClaimOutcome::Empty => break,
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        observer.join().unwrap()
    });
    // exactly once: every job served by exactly one claimer
    let mut got = served.into_inner().unwrap();
    got.sort();
    let mut want = ids.clone();
    want.sort();
    assert_eq!(got, want, "each job must be claimed and served exactly once");
    assert_eq!(count_json(&dir, "done"), total);
    assert_eq!(count_json(&dir, "queue"), 0);
    assert_eq!(count_json(&dir, "running"), 0);
    assert_eq!(count_json(&dir, "leases"), 0);
    assert!(max_seen <= 3, "host batchA held {max_seen} live leases");
    // differential: byte-identical to serial runs of the same exps
    for (i, id) in ids.iter().enumerate() {
        let exp = small_exp(8 + 2 * (i as i64 % 5));
        let report = submitter.fetch(id).unwrap().unwrap();
        let reference = normalize(&elaps::coordinator::run_local(&exp).unwrap());
        assert_eq!(normalize(&report), reference, "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------- O(#jobs) spool status

#[test]
fn spool_status_uses_stamps_and_survives_corrupt_report_bodies() {
    det_config();
    let dir = tmpdir("statuszero");
    let spool_s = dir.to_str().unwrap().to_string();
    let a = Spooler::new(&dir).unwrap().with_host("stA");
    let b = Spooler::new(&dir).unwrap().with_host("stB");
    // equal-width sizes so queue order matches submission order (see
    // the backpressure test)
    let ids: Vec<String> =
        (0..3).map(|i| a.submit(&small_exp(10 + 2 * i)).unwrap()).collect();
    // host A serves the first two jobs, host B the third
    assert_eq!(a.serve_one().unwrap().as_deref(), Some(ids[0].as_str()));
    assert_eq!(a.serve_one().unwrap().as_deref(), Some(ids[1].as_str()));
    assert_eq!(b.serve_one().unwrap().as_deref(), Some(ids[2].as_str()));
    // clobber one done report's payload wholesale: status must not
    // care, because it never opens report bodies — the stamp sidecars
    // carry everything it needs
    std::fs::write(dir.join("done").join(format!("{}.report.json", ids[0])), "{CORRUPT")
        .unwrap();
    let st = lease::spool_status(&dir).unwrap();
    assert_eq!(st.done, 3);
    assert_eq!(st.done_errors, 0);
    assert_eq!(st.done_by_host.get("stA"), Some(&2));
    assert_eq!(st.done_by_host.get("stB"), Some(&1));
    // the CLI view agrees
    let out = elaps_cmd(&["spool", "status", "--spool", &spool_s]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("done: 3"), "{text}");
    assert!(text.contains("stA"), "{text}");
    assert!(text.contains("stB"), "{text}");
    // a corrupt *stamp* downgrades only that job to unknown provenance
    std::fs::write(campaign::stamp_path(&dir, &ids[1]), "{truncated").unwrap();
    let st = lease::spool_status(&dir).unwrap();
    assert_eq!(st.done, 3);
    assert_eq!(st.done_by_host.get("stA"), Some(&1));
    assert_eq!(st.done_by_host.get("(unknown)"), Some(&1));
    assert_eq!(st.done_by_host.get("stB"), Some(&1));
    let _ = std::fs::remove_dir_all(&dir);
}
