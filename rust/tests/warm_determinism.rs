//! Differential determinism harness for the engine's execution modes.
//!
//! The contracts under test (see `engine` module docs):
//! * cold mode: parallel runs are structurally identical to serial for
//!   any worker count (fresh sampler per point — scheduling is a race,
//!   results are not);
//! * warm mode: per-worker sampler reuse over deterministic
//!   contiguous-block shards — with a fixed seed, two runs at the same
//!   `--jobs` are **byte-identical**, and `--jobs 1` reproduces strict
//!   serial back-to-back execution (one sampler carried across the
//!   whole point sequence, checked against a hand-rolled reference);
//! * warm is observable: on a cache-resident sweep the carried
//!   simulated cache state changes counters and modeled timings;
//! * warm and cold cache entries never serve each other.

use elaps::coordinator::{io, Experiment, RangeDef};
use elaps::engine::{Engine, EngineConfig};
use elaps::figures::call;
use elaps::perfmodel::MachineModel;
use elaps::sampler::Sampler;
use elaps::Report;
use std::process::{Command, Output};

/// A dgemm range experiment: one point per value, `nreps` records each.
fn range_experiment(name: &str, values: Vec<i64>) -> Experiment {
    let mut exp = Experiment {
        name: name.into(),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        range: Some(RangeDef::new("n", values)),
        counters: vec!["PAPI_L1_TCM".into(), "PAPI_L3_TCM".into()],
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
    )
    .unwrap()];
    exp
}

/// The same cache-resident point repeated `npoints` times: the range
/// symbol is a run index the call does not use, so every point unrolls
/// to an identical script — the purest back-to-back scenario.
fn repeated_point_experiment(name: &str, n: i64, npoints: i64) -> Experiment {
    let ns = n.to_string();
    let mut exp = Experiment {
        name: name.into(),
        library: "rustblocked".into(),
        machine: "localhost".into(),
        nreps: 2,
        range: Some(RangeDef::new("run", (1..=npoints).collect())),
        counters: vec!["PAPI_L1_TCM".into(), "PAPI_L3_TCM".into()],
        ..Default::default()
    };
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )
    .unwrap()];
    exp
}

fn report_bytes(r: &Report) -> String {
    io::report_to_json(r).to_string_pretty()
}

/// Everything about a report that is deterministic in *cold* mode
/// (wall times are not): point order and shape, kernels, simulated
/// counters, flop counts and OpenMP groups.
fn assert_structurally_identical(a: &Report, b: &Report) {
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.range_value, pb.range_value);
        assert_eq!(pa.nthreads, pb.nthreads);
        assert_eq!(pa.sum_iters, pb.sum_iters);
        assert_eq!(pa.calls_per_iter, pb.calls_per_iter);
        assert_eq!(pa.records.len(), pb.records.len());
        for (ra, rb) in pa.records.iter().zip(&pb.records) {
            assert_eq!(ra.kernel, rb.kernel);
            assert_eq!(ra.counters, rb.counters, "point {}", pa.range_value);
            assert_eq!(ra.flops, rb.flops);
            assert_eq!(ra.omp_group, rb.omp_group);
        }
    }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("elaps_warm_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ------------------------------------------------------------- cold

#[test]
fn cold_parallel_matches_serial_for_jobs_matrix() {
    let exp = range_experiment("cold-matrix", vec![16, 24, 32, 40, 48]);
    let serial = Engine::new(EngineConfig::default().with_jobs(1)).run(&exp).unwrap();
    for jobs in [1usize, 2, 4] {
        let parallel =
            Engine::new(EngineConfig::default().with_jobs(jobs)).run(&exp).unwrap();
        assert_structurally_identical(&serial, &parallel);
    }
}

// ------------------------------------------------------------- warm

#[test]
fn warm_runs_are_byte_identical_at_fixed_jobs() {
    let exp = range_experiment("warm-bytes", vec![16, 24, 32, 40, 48, 56]);
    for jobs in [1usize, 4] {
        let cfg = EngineConfig::default().with_jobs(jobs).with_warm(true).with_seed(42);
        let a = Engine::new(cfg.clone()).run(&exp).unwrap();
        let b = Engine::new(cfg).run(&exp).unwrap();
        assert_eq!(
            report_bytes(&a),
            report_bytes(&b),
            "warm+seed at jobs={jobs} must be byte-identical"
        );
    }
}

#[test]
fn warm_jobs1_reproduces_strict_serial_back_to_back() {
    const SEED: u64 = 7;
    let exp = range_experiment("warm-serial", vec![16, 24, 32, 40]);
    // hand-rolled reference: ONE sampler carried across all points in
    // order, warm-reset at every script boundary
    let machine = MachineModel::by_name(&exp.machine).unwrap();
    let mut sampler: Option<Sampler> = None;
    let mut expected = Vec::new();
    for point in exp.unroll().unwrap() {
        if sampler.is_none() {
            let lib = elaps::libraries::by_name(&exp.library).unwrap();
            sampler = Some(Sampler::new(lib, machine.clone()).deterministic(SEED));
        } else {
            sampler.as_mut().unwrap().reset_warm();
        }
        let s = sampler.as_mut().unwrap();
        expected.push(s.run_script(&point.script).unwrap());
    }
    let cfg = EngineConfig::default().with_jobs(1).with_warm(true).with_seed(SEED);
    let report = Engine::new(cfg).run(&exp).unwrap();
    assert_eq!(report.points.len(), expected.len());
    for (point, recs) in report.points.iter().zip(&expected) {
        assert_eq!(point.records.len(), recs.len());
        for (a, b) in point.records.iter().zip(recs) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.counters, b.counters, "point {}", point.range_value);
            assert_eq!(a.flops, b.flops);
            assert_eq!(a.omp_group, b.omp_group);
            // modeled timings: bit-equal, not approximately equal
            assert_eq!(a.seconds.to_bits(), b.seconds.to_bits());
            assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        }
    }
}

#[test]
fn warm_differs_from_cold_on_cache_resident_sweep() {
    let exp = repeated_point_experiment("warm-observable", 32, 4);
    let cold_cfg = EngineConfig::default().with_seed(5);
    let warm_cfg = EngineConfig::default().with_seed(5).with_warm(true);
    let cold = Engine::new(cold_cfg).run(&exp).unwrap();
    let warm = Engine::new(warm_cfg).run(&exp).unwrap();

    // cold: every point starts from empty simulated caches, so all
    // points are bit-identical repetitions of the same measurement
    for p in &cold.points[1..] {
        assert_eq!(p.records[0].counters, cold.points[0].records[0].counters);
    }
    let cold_first = &cold.points[0].records[0];
    assert!(cold_first.counters[0] > 0, "a cold point must miss L1");

    // warm point 1 carries no state yet: identical to cold
    let warm_first = &warm.points[0].records[0];
    assert_eq!(warm_first.counters, cold_first.counters);
    assert_eq!(warm_first.seconds.to_bits(), cold_first.seconds.to_bits());

    // warm points 2+: operands are simulated-resident — fewer misses,
    // and the modeled time is strictly smaller. The mode is observable.
    for p in &warm.points[1..] {
        let r = &p.records[0];
        assert!(
            r.counters[0] < cold_first.counters[0],
            "carried state must reduce L1 misses (point {})",
            p.range_value
        );
        assert!(
            r.seconds < cold_first.seconds,
            "warm modeled time must undercut cold (point {})",
            p.range_value
        );
    }
}

#[test]
fn warm_and_cold_cache_entries_never_cross_contaminate() {
    let dir = tmpdir("cache_disjoint");
    let exp = range_experiment("warm-cache", vec![16, 24, 32]);
    let cold_cfg = EngineConfig::default().with_seed(9).with_cache(&dir);
    let warm_cfg = cold_cfg.clone().with_warm(true);

    let cold_engine = Engine::new(cold_cfg);
    let warm_engine = Engine::new(warm_cfg);

    let (_, s1) = cold_engine.run_stats(&exp).unwrap();
    assert_eq!((s1.executed, s1.cache_hits), (3, 0));
    // cold entries must not serve the warm run...
    let (warm1, s2) = warm_engine.run_stats(&exp).unwrap();
    assert_eq!((s2.executed, s2.cache_hits), (3, 0), "cold entries served warm");
    // ...but the warm re-run replays its own entries byte-identically
    let (warm2, s3) = warm_engine.run_stats(&exp).unwrap();
    assert_eq!((s3.executed, s3.cache_hits), (0, 3));
    assert_eq!(s3.fully_cached, 1);
    assert_eq!(report_bytes(&warm1), report_bytes(&warm2));
    // ...and the cold entries are still intact for cold lookups
    let (_, s4) = cold_engine.run_stats(&exp).unwrap();
    assert_eq!((s4.executed, s4.cache_hits), (0, 3), "warm run disturbed cold entries");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------- CLI

fn elaps(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_elaps"))
        .args(args)
        .env_remove("ELAPS_CACHE")
        .env_remove("ELAPS_JOBS")
        .env_remove("ELAPS_TRUSTED_ONLY")
        .env_remove("ELAPS_WARM")
        .env_remove("ELAPS_SEED")
        .output()
        .unwrap()
}

#[test]
fn warm_cli_runs_are_byte_identical_per_jobs() {
    let dir = tmpdir("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let exp = dir.join("exp.json");
    std::fs::write(
        &exp,
        r#"{"name":"warm-cli","library":"rustblocked","machine":"localhost",
           "nreps":2,
           "range":{"sym":"n","values":[16,24,32,40]},
           "calls":[["dgemm","N","N","n","n","n",1,"$A","n","$B","n",0,"$C","n"]]}"#,
    )
    .unwrap();
    for jobs in ["1", "4"] {
        let run = |out: &str| {
            let out_path = dir.join(out);
            let o = elaps(&[
                "run",
                exp.to_str().unwrap(),
                "--warm",
                "--seed",
                "1",
                "--jobs",
                jobs,
                "--out",
                out_path.to_str().unwrap(),
            ]);
            assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
            let stdout = String::from_utf8_lossy(&o.stdout).into_owned();
            assert!(stdout.contains("[warm]"), "summary must mark warm mode: {stdout}");
            std::fs::read(out_path).unwrap()
        };
        let a = run(&format!("a{jobs}.json"));
        let b = run(&format!("b{jobs}.json"));
        assert_eq!(a, b, "elaps run --warm --seed 1 --jobs {jobs} must be byte-identical");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
