//! Regenerates the paper's F14 (see DESIGN.md per-experiment index).
//! Quick sizes by default; ELAPS_BENCH_FULL=1 for paper-scaled sizes.
fn main() {
    elaps::figures::bench_main("F14");
}
