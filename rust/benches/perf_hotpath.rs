//! §Perf micro-benchmarks for the L3 hot paths (EXPERIMENTS.md §Perf):
//! gemm variants vs problem size (roofline tracking), sampler dispatch
//! overhead, and the xla-backend call overhead.
//!
//! ELAPS_BENCH_FULL=1 for larger sizes.

use elaps::linalg::blas3::{dgemm_blocked, dgemm_naive, dgemm_recursive};
use elaps::linalg::{Matrix, Trans};
use elaps::perfmodel::MachineModel;
use elaps::sampler::Sampler;
use elaps::util::rng::Xoshiro256;
use std::time::Instant;

type GemmFn = fn(
    Trans, Trans, usize, usize, usize, f64, &[f64], usize, &[f64], usize, f64, &mut [f64], usize,
);

fn time_gemm(f: GemmFn, n: usize, reps: usize) -> f64 {
    let mut rng = Xoshiro256::seeded(1);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let mut c = Matrix::zeros(n, n);
    // warmup
    f(Trans::No, Trans::No, n, n, n, 1.0, &a.data, n, &b.data, n, 0.0, &mut c.data, n);
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f(Trans::No, Trans::No, n, n, n, 1.0, &a.data, n, &b.data, n, 0.0, &mut c.data, n);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let full = std::env::var("ELAPS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let sizes: &[usize] = if full { &[128, 256, 512, 1000] } else { &[128, 256, 512] };
    let machine = MachineModel::localhost();
    println!("=== perf_hotpath: gemm variants (best of 3) ===");
    println!(
        "{:>6} {:>13} {:>13} {:>13} {:>10}",
        "n", "naive GF/s", "blocked GF/s", "recur GF/s", "blk/naive"
    );
    for &n in sizes {
        let flops = 2.0 * (n as f64).powi(3);
        let tn = time_gemm(dgemm_naive, n, 3);
        let tb = time_gemm(dgemm_blocked, n, 3);
        let tr = time_gemm(dgemm_recursive, n, 3);
        println!(
            "{n:>6} {:>13.3} {:>13.3} {:>13.3} {:>9.1}x",
            flops / tn / 1e9,
            flops / tb / 1e9,
            flops / tr / 1e9,
            tn / tb
        );
    }
    println!(
        "\nnominal 1-core roofline (localhost model): {:.1} GF/s",
        machine.peak_flops_core() / 1e9
    );

    // sampler dispatch overhead: tiny kernel, many calls
    println!("\n=== sampler dispatch overhead ===");
    let lib = elaps::libraries::by_name("rustblocked").unwrap();
    let mut sampler = Sampler::new(lib, machine.clone());
    sampler
        .run_script("dmalloc A 16\ndmalloc B 16\ndmalloc C 16\ndgerand A\ndgerand B")
        .unwrap();
    let ncalls = 2000;
    let mut script = String::new();
    for _ in 0..ncalls {
        script.push_str("dgemm N N 4 4 4 1.0 A 4 B 4 0.0 C 4\n");
    }
    script.push_str("go\n");
    let t0 = Instant::now();
    let recs = sampler.run_script(&script).unwrap();
    let total = t0.elapsed().as_secs_f64();
    let kernel_time: f64 = recs.iter().map(|r| r.seconds).sum();
    println!(
        "{} calls in {:.3}s: {:.2} µs/call dispatch+parse overhead (kernel time {:.3}s)",
        recs.len(),
        total,
        (total - kernel_time) / ncalls as f64 * 1e6,
        kernel_time
    );

    // xla backend round-trip overhead (if artifacts are built)
    let dir = elaps::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        println!("\n=== xla (PJRT) backend round-trip ===");
        let reg = elaps::runtime::register_xla_library(&dir).unwrap();
        let n = 256;
        let meta = reg.find("dgemm", n, n, n, "jnp").unwrap().clone();
        let mut rng = Xoshiro256::seeded(2);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut c = vec![0.0; n * n];
        reg.run_gemm(&meta, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0).unwrap(); // compile+warm
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            reg.run_gemm(&meta, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let flops = 2.0 * (n as f64).powi(3);
        println!(
            "dgemm {n}³ via PJRT: {:.4}s best → {:.2} GF/s (incl. literal copies)",
            best,
            flops / best / 1e9
        );
        // pallas-kernel artifact
        if let Some(pal) = reg.find("dgemm", n, n, n, "pallas") {
            if pal.key.impl_name == "pallas" {
                let pal = pal.clone();
                reg.run_gemm(&pal, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0).unwrap();
                let t0 = Instant::now();
                reg.run_gemm(&pal, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0).unwrap();
                let t = t0.elapsed().as_secs_f64();
                println!(
                    "dgemm {n}³ via interpreted-Pallas artifact: {:.3}s → {:.3} GF/s \
                     (interpret=True is a correctness path, not a perf proxy)",
                    t,
                    flops / t / 1e9
                );
            }
        }
    }
}
