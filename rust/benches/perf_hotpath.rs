//! §Perf micro-benchmarks (EXPERIMENTS.md §Perf), on the shared
//! timing/JSON harness of `elaps::obs::bench` — the same code behind
//! `elaps bench`. Running this binary (`cargo bench`) prints the gemm
//! roofline table and then measures every framework hot-path suite
//! (cache probe/hash, spooler claims + scans, event log, sampler inner
//! loop), snapshotting machine-readable `BENCH_<suite>.json` files
//! into the working directory for commit-over-commit comparison.
//!
//! ELAPS_BENCH_FULL=1 for larger gemm sizes; ELAPS_BENCH_QUICK=1 for
//! ~10x smaller hot-path workloads (CI smoke).

use elaps::linalg::blas3::{dgemm_blocked, dgemm_naive, dgemm_recursive};
use elaps::linalg::{Matrix, Trans};
use elaps::perfmodel::MachineModel;
use elaps::util::rng::Xoshiro256;
use std::time::Instant;

type GemmFn = fn(
    Trans, Trans, usize, usize, usize, f64, &[f64], usize, &[f64], usize, f64, &mut [f64], usize,
);

fn time_gemm(f: GemmFn, n: usize, reps: usize) -> f64 {
    let mut rng = Xoshiro256::seeded(1);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let mut c = Matrix::zeros(n, n);
    // warmup
    f(Trans::No, Trans::No, n, n, n, 1.0, &a.data, n, &b.data, n, 0.0, &mut c.data, n);
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f(Trans::No, Trans::No, n, n, n, 1.0, &a.data, n, &b.data, n, 0.0, &mut c.data, n);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let full = std::env::var("ELAPS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let quick = std::env::var("ELAPS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let sizes: &[usize] = if full { &[128, 256, 512, 1000] } else { &[128, 256, 512] };
    let machine = MachineModel::localhost();
    println!("=== perf_hotpath: gemm variants (best of 3) ===");
    println!(
        "{:>6} {:>13} {:>13} {:>13} {:>10}",
        "n", "naive GF/s", "blocked GF/s", "recur GF/s", "blk/naive"
    );
    for &n in sizes {
        let flops = 2.0 * (n as f64).powi(3);
        let tn = time_gemm(dgemm_naive, n, 3);
        let tb = time_gemm(dgemm_blocked, n, 3);
        let tr = time_gemm(dgemm_recursive, n, 3);
        println!(
            "{n:>6} {:>13.3} {:>13.3} {:>13.3} {:>9.1}x",
            flops / tn / 1e9,
            flops / tb / 1e9,
            flops / tr / 1e9,
            tn / tb
        );
    }
    println!(
        "\nnominal 1-core roofline (localhost model): {:.1} GF/s",
        machine.peak_flops_core() / 1e9
    );

    println!("\n=== framework hot paths (shared `elaps bench` harness) ===");
    let out_dir = std::env::current_dir().expect("working directory");
    match elaps::obs::run_bench(&out_dir, quick, &[]) {
        Ok(written) => println!("{} BENCH snapshot(s) written", written.len()),
        Err(e) => {
            eprintln!("hot-path suites failed: {e:#}");
            std::process::exit(1);
        }
    }
}
