//! Fitted machine profiles: the persistence format behind
//! `elaps calibrate` and the `--machine profile:PATH` spec.
//!
//! A profile refines a built-in [`MachineModel`] preset (its `base`)
//! with parameters fitted from a calibration sweep: the effective
//! flops/cycle of the compute-bound stage and the per-cache-level line
//! miss penalties recovered by least squares against the simulated
//! miss counters. Everything the fit does not touch (frequency, core
//! count, cache geometry) is inherited from the base preset.
//!
//! Profiles are versioned JSON (`schema` = [`PROFILE_SCHEMA`]); files
//! with an unknown schema are rejected with an error rather than
//! guessed at, mirroring the result-cache envelope policy.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::machine::MachineModel;
use crate::util::json::Json;

/// Version tag of the profile file format.
pub const PROFILE_SCHEMA: u64 = 1;

/// Environment variable consulted when resolving `localhost`.
pub const PROFILE_ENV: &str = "ELAPS_MACHINE_PROFILE";

/// Default profile path (relative to the working directory) consulted
/// when resolving `localhost` and `ELAPS_MACHINE_PROFILE` is unset.
pub const DEFAULT_PROFILE_PATH: &str = ".elaps-machine-profile.json";

/// A fitted machine profile, as persisted by `elaps calibrate`.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineProfile {
    /// Display name the resolved model carries (also keys result-cache
    /// fingerprints, so distinctly-fitted profiles should be named
    /// distinctly).
    pub name: String,
    /// Registry name of the preset the profile refines.
    pub base: String,
    /// Fitted effective flops/cycle (compute-bound stage).
    pub flops_per_cycle: f64,
    /// Fitted per-level line miss penalties, innermost first.
    pub miss_penalty_cycles: Vec<f64>,
    /// Number of calibration points the fit used.
    pub fit_points: usize,
    /// Mean |modeled − observed| / observed over the calibration sweep
    /// under the fitted parameters.
    pub mean_abs_rel_err: f64,
    /// Same error under the uncalibrated preset constants, for
    /// comparison (the fit must beat this).
    pub uncalibrated_mean_abs_rel_err: f64,
}

impl MachineProfile {
    /// Serialize to the versioned profile JSON.
    pub fn to_json(&self) -> Json {
        let mut fitted = Json::obj();
        fitted.set("flops_per_cycle", self.flops_per_cycle);
        fitted.set("miss_penalty_cycles", self.miss_penalty_cycles.clone());
        let mut fit = Json::obj();
        fit.set("points", self.fit_points);
        fit.set("mean_abs_rel_err", self.mean_abs_rel_err);
        fit.set("uncalibrated_mean_abs_rel_err", self.uncalibrated_mean_abs_rel_err);
        let mut j = Json::obj();
        j.set("schema", PROFILE_SCHEMA);
        j.set("name", self.name.as_str());
        j.set("base", self.base.as_str());
        j.set("fitted", fitted);
        j.set("fit", fit);
        j
    }

    /// Parse the versioned profile JSON; unknown schemas are an error,
    /// not a guess.
    pub fn from_json(j: &Json) -> Result<MachineProfile> {
        let schema = j
            .get("schema")
            .as_u64()
            .ok_or_else(|| anyhow!("machine profile: missing numeric 'schema' field"))?;
        if schema != PROFILE_SCHEMA {
            bail!(
                "machine profile: unknown schema {schema} (this build reads schema \
                 {PROFILE_SCHEMA}); re-run `elaps calibrate` to regenerate the profile"
            );
        }
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("machine profile: missing 'name'"))?
            .to_string();
        let base = j
            .get("base")
            .as_str()
            .ok_or_else(|| anyhow!("machine profile: missing 'base'"))?
            .to_string();
        if MachineModel::by_name(&base).is_none() {
            bail!(
                "machine profile: unknown base machine '{base}' (expected one of {})",
                MachineModel::REGISTRY_NAMES.join(", ")
            );
        }
        let fitted = j.get("fitted");
        let flops_per_cycle = fitted
            .get("flops_per_cycle")
            .as_f64()
            .filter(|f| f.is_finite() && *f > 0.0)
            .ok_or_else(|| anyhow!("machine profile: missing/invalid fitted.flops_per_cycle"))?;
        let miss_penalty_cycles: Vec<f64> = fitted
            .get("miss_penalty_cycles")
            .as_arr()
            .ok_or_else(|| anyhow!("machine profile: missing fitted.miss_penalty_cycles"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .filter(|p| p.is_finite() && *p >= 0.0)
                    .ok_or_else(|| anyhow!("machine profile: invalid miss penalty entry"))
            })
            .collect::<Result<_>>()?;
        if miss_penalty_cycles.is_empty() {
            bail!("machine profile: fitted.miss_penalty_cycles must be non-empty");
        }
        let fit = j.get("fit");
        Ok(MachineProfile {
            name,
            base,
            flops_per_cycle,
            miss_penalty_cycles,
            fit_points: fit.get("points").as_u64().unwrap_or(0) as usize,
            mean_abs_rel_err: fit.get("mean_abs_rel_err").as_f64().unwrap_or(f64::NAN),
            uncalibrated_mean_abs_rel_err: fit
                .get("uncalibrated_mean_abs_rel_err")
                .as_f64()
                .unwrap_or(f64::NAN),
        })
    }

    /// Load a profile from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<MachineProfile> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading machine profile {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("machine profile {}: {e}", path.display()))?;
        Self::from_json(&j)
            .with_context(|| format!("loading machine profile {}", path.display()))
    }

    /// Persist the profile as pretty-printed JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing machine profile {}", path.display()))
    }

    /// Materialize the profile as a [`MachineModel`]: the base preset
    /// with the fitted parameters (and the profile's name) spliced in.
    pub fn apply(&self) -> MachineModel {
        let mut m = MachineModel::by_name(&self.base).unwrap_or_else(MachineModel::localhost);
        m.name = self.name.clone();
        m.flops_per_cycle = self.flops_per_cycle;
        m.miss_penalty_cycles = self.miss_penalty_cycles.clone();
        m
    }
}

/// Resolve a machine *spec* — what `--machine` and experiment files
/// accept — into a model:
///
/// * `profile:PATH` loads a fitted profile file;
/// * `localhost` prefers a fitted profile from `$ELAPS_MACHINE_PROFILE`
///   or, failing that, [`DEFAULT_PROFILE_PATH`] in the working
///   directory, falling back to the built-in
///   [`MachineModel::localhost`] constants when neither exists;
/// * any other registry name resolves via [`MachineModel::by_name`].
///
/// Unknown specs report the full list of valid names.
pub fn resolve_machine(spec: &str) -> Result<MachineModel> {
    if let Some(path) = spec.strip_prefix("profile:") {
        return Ok(MachineProfile::load(path)?.apply());
    }
    if spec == "localhost" {
        if let Ok(path) = std::env::var(PROFILE_ENV) {
            if !path.is_empty() {
                // explicitly pointed at: a broken profile is an error,
                // not a silent fallback
                return Ok(MachineProfile::load(&path)?.apply());
            }
        }
        if Path::new(DEFAULT_PROFILE_PATH).is_file() {
            return Ok(MachineProfile::load(DEFAULT_PROFILE_PATH)?.apply());
        }
    }
    MachineModel::by_name(spec).ok_or_else(|| {
        anyhow!(
            "unknown machine '{spec}' (expected one of {}, or profile:PATH for a \
             fitted profile from `elaps calibrate`)",
            MachineModel::REGISTRY_NAMES.join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MachineProfile {
        MachineProfile {
            name: "localhost+fit".into(),
            base: "localhost".into(),
            flops_per_cycle: 3.7,
            miss_penalty_cycles: vec![11.5, 41.25, 198.0],
            fit_points: 24,
            mean_abs_rel_err: 0.013,
            uncalibrated_mean_abs_rel_err: 0.21,
        }
    }

    #[test]
    fn serialize_parse_roundtrip_is_identity() {
        let p = sample();
        let j = Json::parse(&p.to_json().to_string_pretty()).unwrap();
        assert_eq!(MachineProfile::from_json(&j).unwrap(), p);
    }

    #[test]
    fn unknown_schema_is_a_clear_error() {
        let mut j = sample().to_json();
        j.set("schema", 99u64);
        let err = MachineProfile::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("unknown schema 99"), "got: {err}");
        // and a missing schema is equally explicit
        let err = MachineProfile::from_json(&Json::obj()).unwrap_err().to_string();
        assert!(err.contains("schema"), "got: {err}");
    }

    #[test]
    fn apply_splices_fit_into_base() {
        let m = sample().apply();
        let base = MachineModel::localhost();
        assert_eq!(m.name, "localhost+fit");
        assert_eq!(m.flops_per_cycle, 3.7);
        assert_eq!(m.miss_penalty_cycles, vec![11.5, 41.25, 198.0]);
        assert_eq!(m.freq_hz, base.freq_hz);
        assert_eq!(m.caches.len(), base.caches.len());
    }

    #[test]
    fn resolve_rejects_unknown_spec_with_name_list() {
        let err = resolve_machine("cray").unwrap_err().to_string();
        for n in MachineModel::REGISTRY_NAMES {
            assert!(err.contains(n), "error must list '{n}': {err}");
        }
        assert!(err.contains("profile:PATH"), "got: {err}");
    }

    #[test]
    fn resolve_profile_path_roundtrip() {
        let dir = std::env::temp_dir().join(format!("elaps-profile-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        sample().save(&path).unwrap();
        let m = resolve_machine(&format!("profile:{}", path.display())).unwrap();
        assert_eq!(m.name, "localhost+fit");
        assert_eq!(m.flops_per_cycle, 3.7);
        std::fs::remove_dir_all(&dir).ok();
    }
}
