//! Thread-scaling models (DESIGN.md §Substitutions 4).
//!
//! This host has one core, so the paper's multi-threaded measurements
//! (library threads, OpenMP task parallelism, and hybrids — Figs. 5, 7
//! and 13) are *derived*: the serial time of each kernel is measured
//! for real, then scaled with an Amdahl model whose parallel fraction
//! comes from the library ([`crate::libraries::KernelLibrary::
//! parallel_fraction`]) and whose overheads come from the machine
//! description. EXPERIMENTS.md marks every figure produced this way as
//! `simulated-threads`.

use super::machine::MachineModel;

/// Time of one kernel call executed with `t` library-internal threads,
/// given its measured serial time.
///
/// Amdahl with a per-thread synchronization overhead and a mild memory-
/// bandwidth saturation term (parallel BLAS stops scaling once the
/// memory bus saturates — visible in the paper's Fig. 5 as the flat
/// tail).
pub fn library_threads_time(
    serial_s: f64,
    parallel_fraction: f64,
    t: usize,
    machine: &MachineModel,
) -> f64 {
    let t = t.max(1).min(machine.cores) as f64;
    let p = parallel_fraction.clamp(0.0, 1.0);
    // bandwidth saturation: effective speedup of the parallel part
    // grows slightly sublinearly (t^0.95)
    let eff_t = t.powf(0.95);
    serial_s * ((1.0 - p) + p / eff_t) + machine.task_overhead_s * (t - 1.0)
}

/// Time of `ntasks` independent tasks (each `task_s` seconds serial)
/// scheduled over `omp_threads` OpenMP threads, each task itself using
/// `inner_threads` library threads.
///
/// Models the three §4.3 paradigms:
/// * `omp_threads = 1, inner_threads = t` — multi-threaded kernel,
/// * `omp_threads = t, inner_threads = 1` — parallel sequential kernels,
/// * both > 1 — the hybrid.
pub fn omp_tasks_time(
    task_s: f64,
    ntasks: usize,
    omp_threads: usize,
    inner_threads: usize,
    parallel_fraction: f64,
    machine: &MachineModel,
) -> f64 {
    if ntasks == 0 {
        return 0.0;
    }
    // an OpenMP runtime never spawns more workers than tasks — the
    // spare cores remain available to each task's internal threading
    // (this is what makes the paper's §4.3 hybrid win at low counts)
    let omp = omp_threads.max(1).min(machine.cores).min(ntasks);
    let avail_inner = (machine.cores / omp).max(1);
    let inner = inner_threads.max(1).min(avail_inner);
    let per_task = library_threads_time(task_s, parallel_fraction, inner, machine);
    // tasks run in waves of `omp`
    let waves = ntasks.div_ceil(omp);
    // cache interference: concurrent tasks evict each other's working
    // sets; mild penalty growing with concurrency
    let concurrency = omp.min(ntasks);
    let interference = 1.0 + 0.02 * (concurrency as f64 - 1.0).max(0.0);
    waves as f64 * per_task * interference + machine.task_overhead_s * ntasks as f64
}

/// Speedup helper: serial / threaded.
pub fn speedup(serial_s: f64, threaded_s: f64) -> f64 {
    serial_s / threaded_s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineModel {
        MachineModel::haswell_laptop()
    }

    #[test]
    fn monotone_in_threads_for_parallel_kernel() {
        let mm = m();
        let mut prev = f64::INFINITY;
        for t in 1..=8 {
            let time = library_threads_time(1.0, 0.95, t, &mm);
            assert!(time < prev, "t={t}: {time} !< {prev}");
            prev = time;
        }
    }

    #[test]
    fn amdahl_limits_speedup() {
        let mm = m();
        let s8 = speedup(1.0, library_threads_time(1.0, 0.60, 8, &mm));
        // 60% parallel ⇒ max speedup 1/(0.4 + 0.6/8) ≈ 2.1
        assert!(s8 < 2.3, "{s8}");
        assert!(s8 > 1.5, "{s8}");
    }

    #[test]
    fn thread_count_clamped_to_cores() {
        let mm = m();
        let t8 = library_threads_time(1.0, 0.9, 8, &mm);
        let t64 = library_threads_time(1.0, 0.9, 64, &mm);
        assert_eq!(t8, t64);
    }

    #[test]
    fn omp_beats_internal_threads_for_many_small_tasks() {
        // the paper's Fig. 13 crossover: > cores tasks ⇒ OpenMP with
        // sequential kernels beats one multi-threaded kernel at a time
        let mm = m();
        let ntasks = 16;
        let task_s = 0.01;
        let pf = 0.92; // dgetrf
        let t_mt = omp_tasks_time(task_s, ntasks, 1, 8, pf, &mm);
        let t_omp = omp_tasks_time(task_s, ntasks, 8, 1, pf, &mm);
        assert!(t_omp < t_mt, "omp {t_omp} vs mt {t_mt}");
    }

    #[test]
    fn hybrid_at_least_as_good_as_pure_omp_for_few_tasks() {
        let mm = m();
        // 2 tasks on 8 cores: hybrid (2 omp × 4 inner) must beat
        // 8-way omp (6 threads idle)
        let pf = 0.92;
        let t_omp8 = omp_tasks_time(0.01, 2, 8, 1, pf, &mm);
        let t_hybrid = omp_tasks_time(0.01, 2, 2, 4, pf, &mm);
        assert!(t_hybrid < t_omp8, "hybrid {t_hybrid} vs omp {t_omp8}");
    }

    #[test]
    fn zero_tasks_zero_time() {
        assert_eq!(omp_tasks_time(1.0, 0, 4, 1, 0.9, &m()), 0.0);
    }
}
