//! Machine and performance models.
//!
//! The paper's experiments run on five hardware platforms and read PAPI
//! hardware counters; neither is available here (single-core container,
//! no PMU access), so this module provides the substitutes described in
//! DESIGN.md §Substitutions 2–4:
//!
//! * [`machine::MachineModel`] — frequency, peak flops/cycle, core
//!   count and cache hierarchy for the platform ELAPS reports metrics
//!   against (cycles = wallclock × frequency; efficiency = attained /
//!   peak).
//! * [`cache::CacheSim`] — a deterministic segment-LRU multi-level
//!   cache simulator that stands in for PAPI cache-miss counters.
//! * [`scaling`] — Amdahl-style thread-scaling models used to produce
//!   the multi-threaded experiments (Figs. 5, 7, 13) from measured
//!   single-thread rates on this 1-core host.

pub mod machine;
pub mod cache;
pub mod profile;
pub mod scaling;

pub use cache::CacheSim;
pub use machine::MachineModel;
pub use profile::{resolve_machine, MachineProfile};
