//! Deterministic multi-level cache simulator — the PAPI substitute
//! (DESIGN.md §Substitutions 3).
//!
//! Granularity is *segments* (4 KiB spans of a buffer), not individual
//! lines: each level keeps an LRU list of segments. When a kernel call
//! touches an operand, the resident fraction of its segments hit; the
//! rest miss and are filled. This reproduces the qualitative signal the
//! paper reads from PAPI — warm operands (recently touched, fitting in
//! a level) produce few misses, cold/oversized operands stream.

use super::machine::MachineModel;
use std::collections::VecDeque;

const SEGMENT_BYTES: usize = 4096;

/// Identifier of a cached segment: (buffer id, segment index).
type SegId = (u64, usize);

/// One simulated cache level (segment-LRU).
#[derive(Debug, Clone)]
struct Level {
    name: &'static str,
    capacity_segments: usize,
    line_bytes: usize,
    lru: VecDeque<SegId>, // front = most recent
    misses: u64,
    accesses: u64,
}

impl Level {
    /// Touch a span of segments; returns the number of line misses.
    fn touch(&mut self, buf: u64, seg0: usize, nsegs: usize) -> u64 {
        let mut missed_lines = 0u64;
        let lines_per_seg = (SEGMENT_BYTES / self.line_bytes) as u64;
        for s in seg0..seg0 + nsegs {
            let id = (buf, s);
            self.accesses += lines_per_seg;
            if let Some(pos) = self.lru.iter().position(|&x| x == id) {
                // hit: move to front
                self.lru.remove(pos);
                self.lru.push_front(id);
            } else {
                missed_lines += lines_per_seg;
                self.lru.push_front(id);
                while self.lru.len() > self.capacity_segments {
                    self.lru.pop_back();
                }
            }
        }
        self.misses += missed_lines;
        missed_lines
    }

    fn flush(&mut self) {
        self.lru.clear();
    }
}

/// The cache simulator: one [`Level`] per level of the machine's
/// hierarchy. Counter names follow PAPI: `PAPI_L1_TCM`, `PAPI_L2_TCM`…
#[derive(Debug, Clone)]
pub struct CacheSim {
    levels: Vec<Level>,
    /// simulated branch mispredictions (a fixed tiny rate per access,
    /// so `PAPI_BR_MSP` reports something plausible)
    branch_msp: u64,
}

impl CacheSim {
    pub fn new(machine: &MachineModel) -> CacheSim {
        CacheSim {
            levels: machine
                .caches
                .iter()
                .map(|c| Level {
                    name: c.name,
                    capacity_segments: (c.size_bytes / SEGMENT_BYTES).max(1),
                    line_bytes: c.line_bytes,
                    lru: VecDeque::new(),
                    misses: 0,
                    accesses: 0,
                })
                .collect(),
            branch_msp: 0,
        }
    }

    /// Record that a kernel touched `bytes` of buffer `buf` starting at
    /// byte offset `off`, `sweeps` times.
    pub fn touch(&mut self, buf: u64, off: usize, bytes: usize, sweeps: usize) {
        if bytes == 0 {
            return;
        }
        let seg0 = off / SEGMENT_BYTES;
        let nsegs = (off + bytes).div_ceil(SEGMENT_BYTES) - seg0;
        for _ in 0..sweeps.max(1) {
            // inclusive hierarchy: an access misses L2 only if it
            // missed L1, etc. We approximate by touching each level
            // with the same span; the level's own LRU decides.
            for lvl in &mut self.levels {
                lvl.touch(buf, seg0, nsegs);
            }
            self.branch_msp += (nsegs as u64).max(1) / 8 + 1;
        }
    }

    /// Reset counters (but keep cache contents — "warm" state).
    pub fn reset_counters(&mut self) {
        for l in &mut self.levels {
            l.misses = 0;
            l.accesses = 0;
        }
        self.branch_msp = 0;
    }

    /// Drop all cached contents ("cold" caches).
    pub fn flush(&mut self) {
        for l in &mut self.levels {
            l.flush();
        }
    }

    /// Read a counter by PAPI-style name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        match name {
            "PAPI_BR_MSP" => Some(self.branch_msp),
            _ => {
                // PAPI_L<k>_TCM / PAPI_L<k>_TCA
                let lname = name.strip_prefix("PAPI_")?;
                let (lvl, what) = lname.split_once('_')?;
                let idx = self.levels.iter().position(|l| l.name == lvl)?;
                match what {
                    "TCM" => Some(self.levels[idx].misses),
                    "TCA" => Some(self.levels[idx].accesses),
                    _ => None,
                }
            }
        }
    }

    /// Per-level line-miss counts since the last
    /// [`CacheSim::reset_counters`], innermost level first. This is the
    /// memory-traffic input of [`super::MachineModel::modeled_seconds`].
    pub fn level_misses(&self) -> Vec<u64> {
        self.levels.iter().map(|l| l.misses).collect()
    }

    /// All supported counter names.
    pub fn counter_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .levels
            .iter()
            .flat_map(|l| vec![format!("PAPI_{}_TCM", l.name), format!("PAPI_{}_TCA", l.name)])
            .collect();
        v.push("PAPI_BR_MSP".to_string());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> CacheSim {
        CacheSim::new(&MachineModel::sandybridge())
    }

    #[test]
    fn cold_touch_misses_then_hits() {
        let mut s = sim();
        // 16 KiB fits in L1 (32 KiB)
        s.touch(1, 0, 16 * 1024, 1);
        let cold = s.counter("PAPI_L1_TCM").unwrap();
        assert!(cold > 0);
        s.reset_counters();
        s.touch(1, 0, 16 * 1024, 1);
        let warm = s.counter("PAPI_L1_TCM").unwrap();
        assert_eq!(warm, 0, "second touch should hit L1");
    }

    #[test]
    fn oversized_buffer_always_misses_l1() {
        let mut s = sim();
        // 8 MiB ≫ L1; sweeping twice should miss L1 both times
        s.touch(2, 0, 8 * 1024 * 1024, 1);
        s.reset_counters();
        s.touch(2, 0, 8 * 1024 * 1024, 1);
        assert!(s.counter("PAPI_L1_TCM").unwrap() > 0);
        // …but hit L3 (20 MiB) the second time
        assert_eq!(s.counter("PAPI_L3_TCM").unwrap(), 0);
    }

    #[test]
    fn distinct_buffers_do_not_alias() {
        let mut s = sim();
        s.touch(1, 0, 4096, 1);
        s.reset_counters();
        s.touch(2, 0, 4096, 1); // same offsets, different buffer
        assert!(s.counter("PAPI_L1_TCM").unwrap() > 0);
    }

    #[test]
    fn flush_makes_cold() {
        let mut s = sim();
        s.touch(1, 0, 4096, 1);
        s.flush();
        s.reset_counters();
        s.touch(1, 0, 4096, 1);
        assert!(s.counter("PAPI_L1_TCM").unwrap() > 0);
    }

    #[test]
    fn counter_names_exposed() {
        let s = sim();
        let names = s.counter_names();
        assert!(names.contains(&"PAPI_L1_TCM".to_string()));
        assert!(names.contains(&"PAPI_BR_MSP".to_string()));
        assert!(s.counter("PAPI_L9_TCM").is_none());
    }
}
