//! Machine descriptions: the virtual platforms ELAPS-RS reports
//! metrics against, modeled after the platforms in the paper.

/// One cache level of a machine description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub name: &'static str,
    pub size_bytes: usize,
    pub line_bytes: usize,
}

/// A (virtual) machine: the information the paper's metrics need —
/// "combined with additional information on the hardware … the raw
/// timing leads to a number of metrics" (§2).
#[derive(Debug, Clone)]
pub struct MachineModel {
    pub name: String,
    /// Nominal core frequency in Hz (cycles = seconds × freq).
    pub freq_hz: f64,
    /// Peak double-precision flops per cycle per core.
    pub flops_per_cycle: f64,
    /// Number of cores (for the simulated-threads experiments).
    pub cores: usize,
    /// Cache hierarchy, innermost first.
    pub caches: Vec<CacheLevel>,
    /// Overhead per OpenMP-style task spawn/join, in seconds (used by
    /// the thread-scaling model).
    pub task_overhead_s: f64,
    /// Latency charge per line miss at cache level i (cycles, innermost
    /// first): a miss at L1 that hits L2, a miss at L2 that hits L3,
    /// and a miss in the last level that goes to memory. Instance data
    /// so `elaps calibrate` can fit per-machine values; deeper-than-
    /// modeled levels reuse the last (memory) charge.
    pub miss_penalty_cycles: Vec<f64>,
}

/// The uncalibrated default per-level miss penalties (cycles). These
/// were the former global `LINE_MISS_PENALTY_CYCLES` constant; presets
/// whose instance vector differs model a machine whose memory system
/// the defaults mispredict — exactly what calibration must recover.
pub const DEFAULT_MISS_PENALTY_CYCLES: [f64; 3] = [12.0, 40.0, 200.0];

impl MachineModel {
    /// Peak flops/s of one core.
    pub fn peak_flops_core(&self) -> f64 {
        self.freq_hz * self.flops_per_cycle
    }

    /// Peak flops/s of `t` cores.
    pub fn peak_flops(&self, t: usize) -> f64 {
        self.peak_flops_core() * t as f64
    }

    /// Convert a duration in seconds into cycles on this machine.
    pub fn cycles(&self, seconds: f64) -> f64 {
        seconds * self.freq_hz
    }

    /// Deterministic wall-time prediction for one kernel call: compute
    /// time at per-core peak plus a memory term from the simulated
    /// cache misses (the cache-aware prediction approach of Peise &
    /// Bientinesi, arXiv:1409.8602 — warm operands make small problems
    /// much faster). `miss_lines` is the per-level line-miss vector of
    /// [`super::CacheSim::level_misses`], innermost first.
    ///
    /// Fixed-seed ("deterministic") sampler runs report this instead of
    /// measured wall time, which makes whole experiment campaigns
    /// bit-reproducible: the prediction is a pure function of the
    /// script and the (simulated) cache state it runs against.
    ///
    /// Like a measured time, this is the **serial** time of the call —
    /// on this 1-core host kernels always execute serially and the
    /// report layer applies the thread-scaling model
    /// ([`super::scaling`]) downstream, identically for measured and
    /// modeled records.
    pub fn modeled_seconds(&self, flops: f64, miss_lines: &[u64]) -> f64 {
        let penalties = &self.miss_penalty_cycles;
        let compute_cycles = flops / self.flops_per_cycle;
        let mem_cycles: f64 = miss_lines
            .iter()
            .enumerate()
            .map(|(i, &m)| m as f64 * penalties[i.min(penalties.len() - 1)])
            .sum();
        (compute_cycles + mem_cycles) / self.freq_hz
    }

    /// An Intel SandyBridge E5-2670-like node (the paper's §2 machine):
    /// 2.6 GHz, 8 DP flops/cycle (AVX), 8 cores.
    pub fn sandybridge() -> MachineModel {
        MachineModel {
            name: "SandyBridge-E5-2670".into(),
            freq_hz: 2.6e9,
            flops_per_cycle: 8.0,
            cores: 8,
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 32 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 256 * 1024, line_bytes: 64 },
                CacheLevel { name: "L3", size_bytes: 20 * 1024 * 1024, line_bytes: 64 },
            ],
            task_overhead_s: 5e-6,
            miss_penalty_cycles: DEFAULT_MISS_PENALTY_CYCLES.to_vec(),
        }
    }

    /// An Intel IvyBridge E5-2680 v2-like node (the paper's §4.2
    /// machine): 2.8 GHz, 8 DP flops/cycle, 10 cores.
    pub fn ivybridge() -> MachineModel {
        MachineModel {
            name: "IvyBridge-E5-2680v2".into(),
            freq_hz: 2.8e9,
            flops_per_cycle: 8.0,
            cores: 10,
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 32 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 256 * 1024, line_bytes: 64 },
                CacheLevel { name: "L3", size_bytes: 25 * 1024 * 1024, line_bytes: 64 },
            ],
            task_overhead_s: 5e-6,
            miss_penalty_cycles: vec![12.0, 38.0, 190.0],
        }
    }

    /// An IBM PowerPC A2 (BlueGene/Q) -like node (§4.1): 1.6 GHz,
    /// 8 DP flops/cycle (QPX), 16 cores.
    pub fn bluegene_a2() -> MachineModel {
        MachineModel {
            name: "BlueGeneQ-A2".into(),
            freq_hz: 1.6e9,
            flops_per_cycle: 8.0,
            cores: 16,
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 16 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 32 * 1024 * 1024, line_bytes: 128 },
            ],
            task_overhead_s: 8e-6,
            // two modeled levels: L1→L2 and L2→memory (the in-order A2
            // core eats a far larger memory charge than the defaults)
            miss_penalty_cycles: vec![14.0, 320.0],
        }
    }

    /// An Intel Haswell i7-4850HQ-like laptop CPU (§4.3): 2.3 GHz,
    /// 16 DP flops/cycle (AVX2+FMA), 4 cores (8 hardware threads).
    pub fn haswell_laptop() -> MachineModel {
        MachineModel {
            name: "Haswell-i7-4850HQ".into(),
            freq_hz: 2.3e9,
            flops_per_cycle: 16.0,
            cores: 8, // hardware threads; the paper's Fig. 13 scales to 8
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 32 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 256 * 1024, line_bytes: 64 },
                CacheLevel { name: "L3", size_bytes: 6 * 1024 * 1024, line_bytes: 64 },
            ],
            task_overhead_s: 3e-6,
            miss_penalty_cycles: vec![10.0, 34.0, 170.0],
        }
    }

    /// An Intel Xeon Phi KNC-like coprocessor (§4.4): 1.1 GHz,
    /// 16 DP flops/cycle, 60 cores.
    pub fn xeon_phi() -> MachineModel {
        MachineModel {
            name: "XeonPhi-KNC".into(),
            freq_hz: 1.1e9,
            flops_per_cycle: 16.0,
            cores: 60,
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 32 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 512 * 1024, line_bytes: 64 },
            ],
            task_overhead_s: 1e-5,
            // two modeled levels; KNC misses to GDDR are painful
            miss_penalty_cycles: vec![16.0, 420.0],
        }
    }

    /// The local host's built-in fallback description: a nominal
    /// 3 GHz scalar-FMA core with the uncalibrated default miss
    /// penalties. This constructor never calibrates anything — run
    /// `elaps calibrate` to fit a machine profile, which
    /// [`super::resolve_machine`] (and hence `--machine localhost` on
    /// the CLI) picks up from `ELAPS_MACHINE_PROFILE` or the default
    /// profile path in preference to these constants.
    pub fn localhost() -> MachineModel {
        MachineModel {
            name: "localhost".into(),
            freq_hz: 3.0e9,
            flops_per_cycle: 4.0, // 2-wide SIMD FMA assumed for autovec f64
            cores: 1,
            caches: vec![
                CacheLevel { name: "L1", size_bytes: 32 * 1024, line_bytes: 64 },
                CacheLevel { name: "L2", size_bytes: 1024 * 1024, line_bytes: 64 },
                CacheLevel { name: "L3", size_bytes: 32 * 1024 * 1024, line_bytes: 64 },
            ],
            task_overhead_s: 5e-6,
            miss_penalty_cycles: DEFAULT_MISS_PENALTY_CYCLES.to_vec(),
        }
    }

    /// The built-in registry names accepted by [`Self::by_name`].
    pub const REGISTRY_NAMES: [&'static str; 6] =
        ["sandybridge", "ivybridge", "bluegene", "haswell", "xeonphi", "localhost"];

    /// Look up a machine by (registry) name. Machine *specs* that may
    /// also be a `profile:PATH` or a profile-shadowed `localhost` go
    /// through [`super::resolve_machine`] instead.
    pub fn by_name(name: &str) -> Option<MachineModel> {
        match name {
            "sandybridge" => Some(Self::sandybridge()),
            "ivybridge" => Some(Self::ivybridge()),
            "bluegene" => Some(Self::bluegene_a2()),
            "haswell" => Some(Self::haswell_laptop()),
            "xeonphi" => Some(Self::xeon_phi()),
            "localhost" => Some(Self::localhost()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sandybridge_peak_matches_paper() {
        // The paper's §2 metrics table: 19.1 Gflops/s at 91.7%
        // efficiency ⇒ peak ≈ 20.8 Gflops/s = 2.6 GHz × 8.
        let m = MachineModel::sandybridge();
        assert!((m.peak_flops_core() - 20.8e9).abs() < 1e6);
    }

    #[test]
    fn cycles_conversion() {
        let m = MachineModel::sandybridge();
        // paper: 272551028 cycles ↔ 104.8 ms
        let cycles = m.cycles(0.1048);
        assert!((cycles - 272_480_000.0).abs() / cycles < 0.01);
    }

    #[test]
    fn modeled_seconds_is_deterministic_and_miss_sensitive() {
        let m = MachineModel::sandybridge();
        let flops = 2.0 * 64.0 * 64.0 * 64.0;
        let warm = m.modeled_seconds(flops, &[0, 0, 0]);
        let cold = m.modeled_seconds(flops, &[512, 512, 512]);
        assert!(warm > 0.0, "compute term must be non-zero");
        assert!(cold > warm, "misses must cost time");
        // pure function: identical inputs, identical output bits
        assert_eq!(cold.to_bits(), m.modeled_seconds(flops, &[512, 512, 512]).to_bits());
        // deeper-than-modeled levels reuse the last (memory) charge
        let two = m.modeled_seconds(flops, &[0, 0, 0, 7]);
        let last = m.modeled_seconds(flops, &[0, 0, 7]);
        assert_eq!(two.to_bits(), last.to_bits());
    }

    #[test]
    fn lookup_by_name() {
        for n in ["sandybridge", "ivybridge", "bluegene", "haswell", "xeonphi", "localhost"] {
            assert!(MachineModel::by_name(n).is_some());
        }
        assert!(MachineModel::by_name("cray").is_none());
    }
}
