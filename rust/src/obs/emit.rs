//! Crash-safe, never-failing event emission.
//!
//! An [`Emitter`] appends one event per call to its host's log file
//! `<spool>/events/<host>.jsonl` — a single `O_APPEND` write of one
//! newline-terminated line, so concurrent workers on one host
//! interleave whole lines and a crash mid-write leaves at most one
//! partial final line (which the reader ignores). Emission is
//! default-on, disabled by `ELAPS_EVENTS=0` or the CLI's `--no-events`,
//! and guaranteed never to fail a job: an I/O error degrades to a
//! one-time warning on stderr, after which emission errors are
//! silently dropped.

use super::events::{Event, EventKind};
use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Once;

/// Process-global emission counter behind [`Event::seq`]. Worker
/// identities embed the process id, so a per-process counter is
/// strictly increasing over any one `(host, worker)`'s events.
static SEQ: AtomicU64 = AtomicU64::new(0);

/// One warning for the whole process, then silence: event logging is
/// telemetry, and telemetry must never crash-loop or spam a worker.
static EMIT_WARN: Once = Once::new();

/// Is emission enabled by the environment? Default on; `ELAPS_EVENTS`
/// set to `0`/`false`/`no` (the same falsy spellings the engine's
/// config readers reject as truthy) turns it off.
pub fn env_enabled() -> bool {
    match std::env::var("ELAPS_EVENTS") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !(v == "0" || v == "false" || v == "no")
        }
        Err(_) => true,
    }
}

/// A handle for appending job-lifecycle events. Cheap to clone; clones
/// carry the identity fields (host, worker, campaign) independently,
/// so a worker pool's per-thread spooler clones each stamp their own
/// worker id while sharing the process-global [`Event::seq`] counter.
#[derive(Debug, Clone)]
pub struct Emitter {
    /// `<spool>/events`; empty for [`Emitter::disabled`].
    dir: PathBuf,
    host: String,
    worker: String,
    campaign: String,
    enabled: bool,
}

impl Emitter {
    /// An emitter for a spool directory, enabled unless the
    /// environment says otherwise ([`env_enabled`]).
    pub fn for_spool(spool: &Path, host: &str, worker: &str) -> Emitter {
        let enabled = env_enabled();
        let dir = spool.join("events");
        if enabled {
            let _ = std::fs::create_dir_all(&dir);
        }
        Emitter {
            dir,
            host: host.to_string(),
            worker: worker.to_string(),
            campaign: String::new(),
            enabled,
        }
    }

    /// An emitter that never writes (no spool in play at all).
    pub fn disabled() -> Emitter {
        Emitter {
            dir: PathBuf::new(),
            host: String::new(),
            worker: String::new(),
            campaign: String::new(),
            enabled: false,
        }
    }

    /// Re-target the host identity (and with it the per-host log file).
    pub fn with_host(mut self, host: &str) -> Emitter {
        self.host = host.to_string();
        self
    }

    pub fn with_worker(mut self, worker: &str) -> Emitter {
        self.worker = worker.to_string();
        self
    }

    /// Tag subsequent events with a campaign (the submitting client
    /// knows it; workers do not).
    pub fn with_campaign(mut self, tag: &str) -> Emitter {
        self.campaign = tag.to_string();
        self
    }

    /// Force emission on or off, overriding the environment — the
    /// CLI's `--no-events`, and the tests' way of pinning behavior
    /// regardless of an inherited `ELAPS_EVENTS`. Enabling an emitter
    /// constructed with [`Emitter::disabled`] (no spool) stays off.
    pub fn with_enabled(mut self, enabled: bool) -> Emitter {
        self.enabled = enabled && !self.dir.as_os_str().is_empty();
        if self.enabled {
            let _ = std::fs::create_dir_all(&self.dir);
        }
        self
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Append one event. Infallible by contract: any I/O error is
    /// reported once per process and otherwise swallowed — a job must
    /// never fail because its telemetry could not be written.
    pub fn emit(&self, kind: EventKind, job_id: &str, epoch: u64, extra: &[(&str, Json)]) {
        if !self.enabled {
            return;
        }
        let event = Event {
            kind,
            job_id: job_id.to_string(),
            campaign: self.campaign.clone(),
            host: self.host.clone(),
            worker: self.worker.clone(),
            epoch,
            t_unix_ns: now_unix_ns(),
            seq: SEQ.fetch_add(1, Ordering::Relaxed),
            extra: extra.iter().map(|(k, v)| ((*k).to_string(), v.clone())).collect(),
        };
        if let Err(e) = self.append(&event) {
            EMIT_WARN.call_once(|| {
                eprintln!(
                    "warning: event log write failed ({e}); \
                     further event-log errors will be suppressed"
                );
            });
        }
    }

    fn append(&self, event: &Event) -> std::io::Result<()> {
        use std::io::Write;
        // hosts come from the environment: keep the log name one flat
        // file per host even for a pathological hostname
        let file = format!("{}.jsonl", self.host.replace(['/', ' '], "_"));
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(self.dir.join(file))?;
        f.write_all(event.to_line().as_bytes())
    }
}

fn now_unix_ns() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0)
}

// ----------------------------------------------------- job context

/// The thread-local job context: which job (under which emitter) the
/// current thread is executing. The spooler sets it around payload
/// execution so layers with no spool handle — the engine's cache
/// probe — can attribute their events to the running job.
#[derive(Debug, Clone)]
pub struct JobContext {
    pub emitter: Emitter,
    pub job_id: String,
    pub epoch: u64,
}

thread_local! {
    static JOB_CTX: std::cell::RefCell<Option<JobContext>> =
        const { std::cell::RefCell::new(None) };
}

/// RAII guard restoring the previous job context on drop, so nested
/// serves (a job whose execution drives another spooler in-process)
/// unwind correctly.
pub struct JobCtxGuard {
    prev: Option<JobContext>,
}

impl Drop for JobCtxGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        JOB_CTX.with(|c| *c.borrow_mut() = prev);
    }
}

/// Enter a job context for the current thread; hold the guard for the
/// span of the job's execution.
pub fn enter_job(emitter: &Emitter, job_id: &str, epoch: u64) -> JobCtxGuard {
    let ctx = JobContext { emitter: emitter.clone(), job_id: job_id.to_string(), epoch };
    let prev = JOB_CTX.with(|c| c.replace(Some(ctx)));
    JobCtxGuard { prev }
}

/// The current thread's job context, if any.
pub fn current_job() -> Option<JobContext> {
    JOB_CTX.with(|c| c.borrow().clone())
}

/// Convenience used by the engine: emit aggregate cache-probe counts
/// (`class` = cold/warm/seeded, `count` = how many points) against the
/// current job context, if one is set. `count == 0` emits nothing.
pub fn emit_cache_counts(kind: EventKind, class: &str, count: usize) {
    if count == 0 {
        return;
    }
    if let Some(ctx) = current_job() {
        let extra: [(&str, Json); 2] = [("class", class.into()), ("count", count.into())];
        ctx.emitter.emit(kind, &ctx.job_id, ctx.epoch, &extra);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::events::read_events;
    use std::collections::BTreeMap;

    fn tmp_spool(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("elaps_obs_emit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn emit_appends_readable_events_with_increasing_seq() {
        let dir = tmp_spool("basic");
        let em = Emitter::for_spool(&dir, "hostA", "hostA#1-0")
            .with_enabled(true)
            .with_campaign("camp");
        em.emit(EventKind::Submitted, "job-1", 0, &[]);
        em.emit(EventKind::Claimed, "job-1", 1, &[]);
        em.emit(EventKind::Fenced, "job-1", 1, &[("reason", "expired".into())]);
        let scan = read_events(&dir);
        assert_eq!(scan.skipped, 0);
        assert_eq!(scan.events.len(), 3);
        assert!(dir.join("events").join("hostA.jsonl").is_file());
        for ev in &scan.events {
            assert_eq!(ev.host, "hostA");
            assert_eq!(ev.worker, "hostA#1-0");
            assert_eq!(ev.campaign, "camp");
        }
        assert!(scan.events.windows(2).all(|w| w[0].seq < w[1].seq), "seq strictly increasing");
        assert!(scan.events.windows(2).all(|w| w[0].t_unix_ns <= w[1].t_unix_ns));
        assert_eq!(scan.events[2].extra.get("reason"), Some(&Json::Str("expired".into())));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_emitters_write_nothing_and_never_error() {
        let dir = tmp_spool("off");
        let em = Emitter::for_spool(&dir, "hostA", "w").with_enabled(false);
        em.emit(EventKind::Submitted, "job-1", 0, &[]);
        assert!(read_events(&dir).events.is_empty());
        // a spool-less emitter cannot be enabled into writing nowhere
        let none = Emitter::disabled().with_enabled(true);
        assert!(!none.is_enabled());
        none.emit(EventKind::Submitted, "job-1", 0, &[]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_host_logs_are_separate_files() {
        let dir = tmp_spool("hosts");
        let a = Emitter::for_spool(&dir, "hA", "wa").with_enabled(true);
        let b = a.clone().with_host("hB").with_worker("wb");
        a.emit(EventKind::Submitted, "j", 0, &[]);
        b.emit(EventKind::Claimed, "j", 1, &[]);
        assert!(dir.join("events").join("hA.jsonl").is_file());
        assert!(dir.join("events").join("hB.jsonl").is_file());
        let scan = read_events(&dir);
        assert_eq!(scan.events.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_context_nests_and_restores() {
        let em = Emitter::disabled();
        assert!(current_job().is_none());
        {
            let _outer = enter_job(&em, "outer", 1);
            assert_eq!(current_job().unwrap().job_id, "outer");
            {
                let _inner = enter_job(&em, "inner", 2);
                assert_eq!(current_job().unwrap().job_id, "inner");
            }
            assert_eq!(current_job().unwrap().job_id, "outer");
        }
        assert!(current_job().is_none());
        // emit_cache_counts without a context is a no-op, not a panic
        emit_cache_counts(EventKind::CacheHit, "cold", 3);
    }

    #[test]
    fn cache_counts_attribute_to_the_context_job() {
        let dir = tmp_spool("cache");
        let em = Emitter::for_spool(&dir, "hC", "wc").with_enabled(true);
        let _ctx = enter_job(&em, "job-9", 4);
        emit_cache_counts(EventKind::CacheHit, "seeded", 5);
        emit_cache_counts(EventKind::CacheMiss, "seeded", 0); // dropped
        drop(_ctx);
        let scan = read_events(&dir);
        assert_eq!(scan.events.len(), 1);
        let ev = &scan.events[0];
        assert_eq!(ev.kind, EventKind::CacheHit);
        assert_eq!(ev.job_id, "job-9");
        assert_eq!(ev.epoch, 4);
        let mut want = BTreeMap::new();
        want.insert("class".to_string(), Json::Str("seeded".into()));
        want.insert("count".to_string(), Json::Num(5.0));
        assert_eq!(ev.extra, want);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
