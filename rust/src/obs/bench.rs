//! `elaps bench`: machine-readable micro-benchmarks of the framework's
//! *own* hot paths, sharing one timing/JSON harness with
//! `benches/perf_hotpath.rs`. The paper's discipline — performance
//! decisions rest on measured, reproducible numbers — applies to the
//! coordinator as much as to the kernels it measures, so every run
//! emits a `BENCH_<suite>.json` snapshot that can be diffed across
//! commits (see the README's Benchmarks section).
//!
//! Suites and the hot paths they cover:
//! - `cache`: content-fingerprint hashing, envelope read+parse, the
//!   pre-enqueue probe (hit and miss), entry store.
//! - `spool`: the per-claim queue scan the batched claim replaced
//!   (`queue_scan_sorted`, kept as the old-cost reference), the new
//!   batched claim (solo and under 4-thread contention, with an
//!   exactly-once check), the locked lease renewal, the lease / stamp
//!   directory scans, and the ledger-index campaign queries
//!   (`status_ledger`, `wait_ledger`) those scans are diffed against.
//! - `obs`: event-log append and read, plus the `LatencySummary`
//!   single-sort vs the triple `stats::percentile` sort it replaced.
//! - `sampler`: the sampler inner loop on a tiny kernel — per-call
//!   wall time and dispatch overhead above kernel time.
//!
//! Timings use batched inner loops (each sample times `batch`
//! operations and divides) so nanosecond-scale operations are not
//! swamped by timer overhead; reported numbers are the p50 and best of
//! the per-operation samples.

use crate::coordinator::campaign::{self, Stamp, StampOutcome};
use crate::coordinator::experiment::{Call, CallArg, Experiment};
use crate::coordinator::lease;
use crate::coordinator::stats::{percentile, percentile_of_sorted};
use crate::coordinator::submit::{ClaimOutcome, Spooler};
use crate::engine::cache::ResultCache;
use crate::obs::analyze::LatencySummary;
use crate::obs::emit::Emitter;
use crate::obs::events::{read_events, EventKind};
use crate::perfmodel::MachineModel;
use crate::sampler::Sampler;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::hint::black_box;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// The available suites, in their default execution order.
pub const ALL_SUITES: &[&str] = &["cache", "spool", "obs", "sampler"];

/// One measured metric, as serialized into `BENCH_<suite>.json`.
#[derive(Debug, Clone)]
pub struct MetricRecord {
    /// Stable metric name — identical between `--quick` and full runs
    /// so two BENCH files are always diffable by name.
    pub name: String,
    /// Total operations timed (samples × batch).
    pub n: usize,
    /// Median per-operation nanoseconds.
    pub p50_ns: f64,
    /// Fastest per-operation nanoseconds observed.
    pub best_ns: f64,
    /// Operations per second at the median (`1e9 / p50_ns`).
    pub throughput: f64,
    /// Workload size behind each operation where one exists (queued
    /// jobs scanned, live leases counted, …); scales with `--quick`,
    /// which is why it is recorded next to the timing.
    pub items: Option<usize>,
}

/// One suite's measurements.
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub suite: String,
    pub metrics: Vec<MetricRecord>,
}

/// Run the selected suites (all of [`ALL_SUITES`] when `suites` is
/// empty), write one `BENCH_<suite>.json` per suite into `out_dir`,
/// and return the written paths. `quick` scales workload sizes down
/// (~10×) for CI smoke runs; metric *names* are unaffected.
pub fn run_bench(out_dir: &Path, quick: bool, suites: &[String]) -> Result<Vec<PathBuf>> {
    for s in suites {
        if !ALL_SUITES.contains(&s.as_str()) {
            bail!("unknown bench suite '{s}' (available: {})", ALL_SUITES.join(", "));
        }
    }
    let chosen: Vec<String> = if suites.is_empty() {
        ALL_SUITES.iter().map(|s| s.to_string()).collect()
    } else {
        suites.to_vec()
    };
    let mut written = Vec::new();
    for name in &chosen {
        println!("== bench suite {name}{} ==", if quick { " (quick)" } else { "" });
        let suite = match name.as_str() {
            "cache" => suite_cache(quick)?,
            "spool" => suite_spool(quick)?,
            "obs" => suite_obs(quick)?,
            "sampler" => suite_sampler(quick)?,
            _ => unreachable!("validated above"),
        };
        let path = write_report(out_dir, &suite)?;
        println!("   -> {}", path.display());
        written.push(path);
    }
    Ok(written)
}

/// Serialize one suite to `<out_dir>/BENCH_<suite>.json`.
pub fn write_report(out_dir: &Path, suite: &SuiteResult) -> Result<PathBuf> {
    let metrics: Vec<Json> = suite
        .metrics
        .iter()
        .map(|m| {
            let mut j = Json::obj();
            j.set("name", m.name.as_str())
                .set("n", m.n)
                .set("p50_ns", m.p50_ns)
                .set("best_ns", m.best_ns)
                .set("throughput", m.throughput);
            if let Some(items) = m.items {
                j.set("items", items);
            }
            j
        })
        .collect();
    let mut root = Json::obj();
    root.set("suite", suite.suite.as_str())
        .set("host", crate::util::hostid::hostname())
        .set("git_rev", git_rev().as_str())
        .set("metrics", Json::Arr(metrics));
    let path = out_dir.join(format!("BENCH_{}.json", suite.suite));
    std::fs::write(&path, root.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// `git rev-parse --short HEAD` of the working directory, `"unknown"`
/// outside a git checkout (or without a git binary).
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

// ------------------------------------------------------ timing harness

/// Time `samples` invocations of a loop of `batch` calls to `op`;
/// returns per-operation nanoseconds, one entry per sample.
fn sample_ns(samples: usize, batch: usize, mut op: impl FnMut()) -> Vec<f64> {
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            op();
        }
        out.push(t0.elapsed().as_secs_f64() * 1e9 / batch as f64);
    }
    out
}

/// Reduce per-operation samples to a [`MetricRecord`].
fn metric_from(name: &str, per_op_ns: &[f64], n: usize) -> MetricRecord {
    let mut sorted = per_op_ns.to_vec();
    sorted.sort_by(f64::total_cmp);
    let p50 = percentile_of_sorted(&sorted, 0.5);
    MetricRecord {
        name: name.to_string(),
        n,
        p50_ns: p50,
        best_ns: sorted.first().copied().unwrap_or(f64::NAN),
        throughput: if p50 > 0.0 { 1e9 / p50 } else { f64::NAN },
        items: None,
    }
}

/// Print one metric's human-readable line (the JSON file carries the
/// machine-readable truth).
fn note(m: &MetricRecord) {
    println!(
        "   {:<28} p50 {:>12.0} ns   best {:>12.0} ns   {:>14.0} ops/s",
        m.name, m.p50_ns, m.best_ns, m.throughput
    );
}

/// A fresh scratch directory under the system temp dir.
fn bench_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("elaps_bench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A minimal single-call dgemm-16 experiment (2 repetitions), the
/// standard tiny workload behind the cache and spool suites.
fn dgemm16() -> Experiment {
    Experiment {
        name: "bench-dgemm16".into(),
        nreps: 2,
        calls: vec![Call::new(
            "dgemm",
            vec![
                CallArg::Flag('N'),
                CallArg::Flag('N'),
                CallArg::expr("16"),
                CallArg::expr("16"),
                CallArg::expr("16"),
                CallArg::Scalar(1.0),
                CallArg::Data("A".into()),
                CallArg::expr("16"),
                CallArg::Data("B".into()),
                CallArg::expr("16"),
                CallArg::Scalar(0.0),
                CallArg::Data("C".into()),
                CallArg::expr("16"),
            ],
        )
        .expect("static dgemm call")],
        ..Default::default()
    }
}

// ------------------------------------------------------------- suites

/// Cache hot paths: key hashing, envelope read+parse, probe, store.
fn suite_cache(quick: bool) -> Result<SuiteResult> {
    let dir = bench_dir("cache");
    std::fs::create_dir_all(&dir)?;
    let cache = ResultCache::open(&dir)?;
    let exp = dgemm16();
    let point = exp.unroll()?.remove(0);
    let lib = crate::libraries::by_name(&exp.library)
        .ok_or_else(|| anyhow!("unknown library {}", exp.library))?;
    let mut sampler = Sampler::new(lib, MachineModel::localhost()).deterministic(7);
    let stored = crate::engine::execute_point_on(&mut sampler, &exp, &point)?;
    let expected = stored.records.len();
    let key = ResultCache::fingerprint_with(&exp.library, &exp.machine, exp.nreps, &point, Some(7));
    cache.store(&key, &stored)?;
    if cache.lookup(&key, expected).is_none() {
        bail!("bench cache entry failed to round-trip");
    }

    let samples = if quick { 50 } else { 300 };
    let mut metrics = Vec::new();

    let s = sample_ns(samples, 10, || {
        black_box(ResultCache::fingerprint_with(
            &exp.library,
            &exp.machine,
            exp.nreps,
            &point,
            Some(7),
        ));
    });
    let m = metric_from("fingerprint_dgemm16", &s, samples * 10);
    note(&m);
    metrics.push(m);

    let s = sample_ns(samples, 10, || {
        black_box(cache.lookup_entry(&key).is_some());
    });
    let m = metric_from("envelope_read_parse", &s, samples * 10);
    note(&m);
    metrics.push(m);

    let s = sample_ns(samples, 10, || {
        black_box(cache.lookup(&key, expected).is_some());
    });
    let m = metric_from("probe_hit", &s, samples * 10);
    note(&m);
    metrics.push(m);

    let s = sample_ns(samples, 10, || {
        black_box(cache.lookup("bench-absent-key", expected).is_some());
    });
    let m = metric_from("probe_miss", &s, samples * 10);
    note(&m);
    metrics.push(m);

    let s = sample_ns(samples, 1, || {
        cache.store(&key, &stored).expect("bench cache store");
    });
    let m = metric_from("cache_store", &s, samples);
    note(&m);
    metrics.push(m);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(SuiteResult { suite: "cache".into(), metrics })
}

/// Spooler hot paths: the old per-claim queue scan vs the batched
/// claim, claims under contention (with an exactly-once check), the
/// locked lease renewal, the lease / stamp directory scans, and the
/// ledger-index campaign queries the scans are diffed against.
fn suite_spool(quick: bool) -> Result<SuiteResult> {
    let dir = bench_dir("spool");
    let spool = Spooler::new(&dir)?.with_ttl(Duration::from_secs(600)).with_events(false);
    let exp = dgemm16();
    let jobs = if quick { 64 } else { 512 };
    for _ in 0..jobs {
        spool.submit(&exp)?;
    }
    let mut metrics = Vec::new();

    // The cost the pre-batching claim paid on *every* try_claim: a full
    // read_dir of the queue plus a sort — kept as the old-cost
    // reference the batched numbers are compared against.
    let scan_samples = if quick { 10 } else { 30 };
    let queue = dir.join("queue");
    let s = sample_ns(scan_samples, 1, || {
        let mut entries: Vec<_> = std::fs::read_dir(&queue)
            .expect("queue dir")
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort_by_key(|e| e.file_name());
        black_box(entries.len());
    });
    let mut m = metric_from("queue_scan_sorted", &s, scan_samples);
    m.items = Some(jobs);
    note(&m);
    metrics.push(m);

    // The real per-claim cost of the batched try_claim, draining the
    // same queue (includes the per-job lock, lease write and rename;
    // the scan is amortized over the whole batch).
    let mut claims = Vec::with_capacity(jobs);
    let s = sample_ns(jobs, 1, || match spool.try_claim().expect("bench claim") {
        ClaimOutcome::Claimed(c) => claims.push(c),
        other => panic!("queue drained early: {other:?}"),
    });
    let mut m = metric_from("claim_batched", &s, jobs);
    m.items = Some(jobs);
    note(&m);
    metrics.push(m);

    // The fence-safe (per-job flock + re-verify) heartbeat renewal.
    let claim = claims.last().expect("at least one claim");
    if !spool.renew(claim)? {
        bail!("bench renewal lost its lease");
    }
    let renew_samples = if quick { 40 } else { 200 };
    let s = sample_ns(renew_samples, 1, || {
        black_box(spool.renew(claim).expect("bench renew"));
    });
    let m = metric_from("renew_locked", &s, renew_samples);
    note(&m);
    metrics.push(m);

    // Contended claims: four claimers sharing one candidate batch.
    // Doubles as a stress check — every job must be claimed exactly
    // once across the threads.
    for _ in 0..jobs {
        spool.submit(&exp)?;
    }
    let nthreads = 4;
    let t0 = Instant::now();
    let total: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..nthreads)
            .map(|i| {
                let w = spool.clone().with_worker(format!("bench#{i}"));
                s.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        match w.try_claim().expect("bench contended claim") {
                            ClaimOutcome::Claimed(c) => mine.push(c),
                            ClaimOutcome::Empty => break,
                            ClaimOutcome::Backpressured => unreachable!("no cap set"),
                        }
                    }
                    mine.len()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("claimer thread")).sum()
    });
    let elapsed_ns = t0.elapsed().as_secs_f64() * 1e9;
    if total != jobs {
        bail!("contended claims broke exactly-once: {total} claims for {jobs} jobs");
    }
    let per = elapsed_ns / jobs as f64;
    let m = MetricRecord {
        name: "claim_contended_4x".into(),
        n: jobs,
        p50_ns: per,
        best_ns: per,
        throughput: if per > 0.0 { 1e9 / per } else { f64::NAN },
        items: Some(jobs),
    };
    note(&m);
    metrics.push(m);

    // Live-lease scan (the backpressure check's slow path): both claim
    // rounds above left their leases in place, all unexpired.
    let leases_live = 2 * jobs;
    let s = sample_ns(scan_samples, 1, || {
        black_box(lease::live_leases_for_host(&dir, spool.host()).expect("lease scan"));
    });
    let mut m = metric_from("lease_scan_live", &s, scan_samples);
    m.items = Some(leases_live);
    note(&m);
    metrics.push(m);

    // Stamp-sidecar scan (`spool status` / campaign wait).
    for i in 0..jobs {
        campaign::write_stamp(
            &dir,
            &Stamp {
                job_id: format!("bench-stamp-{i}"),
                host: spool.host().to_string(),
                worker: "bench#0".to_string(),
                epoch: 1,
                outcome: StampOutcome::Ok,
            },
        )?;
    }
    let s = sample_ns(scan_samples, 1, || {
        black_box(campaign::read_stamps(&dir).stamps.len());
    });
    let mut m = metric_from("stamp_scan", &s, scan_samples);
    m.items = Some(jobs);
    note(&m);
    metrics.push(m);

    // Ledger-index campaign queries, next to the scan metrics above
    // for before/after diffs: a fully drained ledger campaign of the
    // same size, folded into its snapshot once; `status_ledger` is the
    // snapshot-path status (load + refresh + fold — zero per-job I/O
    // for done jobs) and `wait_ledger` the pending-set computation a
    // campaign wait polls with (instant when everything is done).
    {
        use crate::coordinator::ledger;
        use crate::obs::events::Event;
        let facts: Vec<Event> = (0..jobs)
            .map(|i| {
                let job_id = format!("bench-ledger-{i:06}");
                let mut ev = Event {
                    kind: EventKind::Submitted,
                    job_id: job_id.clone(),
                    campaign: "bench".into(),
                    host: spool.host().to_string(),
                    worker: "bench#0".into(),
                    epoch: 0,
                    t_unix_ns: 0,
                    seq: i as u64,
                    extra: Default::default(),
                };
                ev.extra.insert("attempt".into(), 1u64.into());
                ev
            })
            .collect();
        ledger::append(&dir, "bench", &facts)?;
        for i in 0..jobs {
            let job_id = format!("bench-ledger-{i:06}");
            std::fs::write(dir.join("done").join(format!("{job_id}.report.json")), "{}")?;
            campaign::write_stamp(
                &dir,
                &Stamp {
                    job_id,
                    host: spool.host().to_string(),
                    worker: "bench#0".to_string(),
                    epoch: 1,
                    outcome: StampOutcome::Ok,
                },
            )?;
        }
        let mut idx = ledger::CampaignIndex::load(&dir, "bench")?;
        idx.refresh(&dir)?;
        idx.save(&dir)?;
        let s = sample_ns(scan_samples, 1, || {
            let mut idx = ledger::CampaignIndex::load(&dir, "bench").expect("index load");
            idx.refresh(&dir).expect("index refresh");
            black_box(idx.status(&dir).done());
        });
        let mut m = metric_from("status_ledger", &s, scan_samples);
        m.items = Some(jobs);
        note(&m);
        metrics.push(m);
        let s = sample_ns(scan_samples, 1, || {
            let idx = ledger::CampaignIndex::load(&dir, "bench").expect("index load");
            black_box(idx.pending_ids().len());
        });
        let mut m = metric_from("wait_ledger", &s, scan_samples);
        m.items = Some(jobs);
        note(&m);
        metrics.push(m);
    }

    drop(claims);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(SuiteResult { suite: "spool".into(), metrics })
}

/// Observability hot paths: event append + read, and the
/// LatencySummary single-sort vs the triple-sort it replaced.
fn suite_obs(quick: bool) -> Result<SuiteResult> {
    let dir = bench_dir("obs");
    std::fs::create_dir_all(&dir)?;
    let emitter = Emitter::for_spool(&dir, "benchhost", "bench#0").with_enabled(true);
    let mut metrics = Vec::new();

    let append_samples = if quick { 200 } else { 2000 };
    let s = sample_ns(append_samples, 1, || {
        emitter.emit(EventKind::Heartbeat, "bench-job", 1, &[]);
    });
    let m = metric_from("event_append", &s, append_samples);
    note(&m);
    metrics.push(m);

    let n_events = read_events(&dir).events.len();
    if n_events == 0 {
        bail!("bench event log is empty — emitter disabled?");
    }
    let read_samples = if quick { 10 } else { 30 };
    let s: Vec<f64> = sample_ns(read_samples, 1, || {
        black_box(read_events(&dir).events.len());
    })
    .iter()
    .map(|ns| ns / n_events as f64)
    .collect();
    let mut m = metric_from("event_read_per_event", &s, read_samples * n_events);
    m.items = Some(n_events);
    note(&m);
    metrics.push(m);

    // LatencySummary::of used to call stats::percentile three times —
    // three clones + three sorts of the same sample. The pair below
    // tracks the replaced cost next to the single-sort rewrite.
    let sample: Vec<f64> =
        (0..10_000u64).map(|i| (i.wrapping_mul(2_654_435_761) % 100_000) as f64 / 7.0).collect();
    let psamples = if quick { 10 } else { 30 };
    let s = sample_ns(psamples, 1, || {
        black_box(percentile(&sample, 0.50));
        black_box(percentile(&sample, 0.90));
        black_box(percentile(&sample, 0.99));
    });
    let mut m = metric_from("percentile_three_sorts", &s, psamples);
    m.items = Some(sample.len());
    note(&m);
    metrics.push(m);

    let s = sample_ns(psamples, 1, || {
        black_box(LatencySummary::of(&sample));
    });
    let mut m = metric_from("latency_summary_single_sort", &s, psamples);
    m.items = Some(sample.len());
    note(&m);
    metrics.push(m);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(SuiteResult { suite: "obs".into(), metrics })
}

/// Sampler inner loop on a tiny kernel: per-call wall time, and the
/// dispatch/bookkeeping overhead above the kernel's own time.
fn suite_sampler(quick: bool) -> Result<SuiteResult> {
    let lib =
        crate::libraries::by_name("rustblocked").ok_or_else(|| anyhow!("rustblocked missing"))?;
    let mut sampler = Sampler::new(lib, MachineModel::localhost());
    sampler.run_script("dmalloc A 16\ndmalloc B 16\ndmalloc C 16\ndgerand A\ndgerand B")?;
    let ncalls = if quick { 200 } else { 2000 };
    let mut script = String::new();
    for _ in 0..ncalls {
        script.push_str("dgemm N N 4 4 4 1.0 A 4 B 4 0.0 C 4\n");
    }
    script.push_str("go\n");
    let t0 = Instant::now();
    let recs = sampler.run_script(&script)?;
    let total_ns = t0.elapsed().as_secs_f64() * 1e9;
    if recs.is_empty() {
        bail!("sampler produced no records");
    }
    let kernel_ns: f64 = recs.iter().map(|r| r.seconds * 1e9).sum();
    let per_call = total_ns / recs.len() as f64;
    let overhead = (total_ns - kernel_ns).max(0.0) / recs.len() as f64;
    let mut metrics = Vec::new();
    for (name, ns) in [("tiny_dgemm_call", per_call), ("dispatch_overhead", overhead)] {
        let m = MetricRecord {
            name: name.into(),
            n: recs.len(),
            p50_ns: ns,
            best_ns: ns,
            throughput: if ns > 0.0 { 1e9 / ns } else { f64::NAN },
            items: Some(ncalls),
        };
        note(&m);
        metrics.push(m);
    }
    Ok(SuiteResult { suite: "sampler".into(), metrics })
}
