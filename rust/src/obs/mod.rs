//! Observability: structured job-lifecycle events and campaign
//! analysis.
//!
//! ELAPS reports "can be analyzed both numerically and visually"
//! (PAPER.md) — this module extends that promise from single
//! experiments to whole multi-host campaigns. [`events`] defines the
//! versioned JSON event schema and the crash-tolerant reader,
//! [`emit`] the never-failing per-host JSONL appender the spooler and
//! engine are instrumented with, and [`analyze`] the `elaps analyze`
//! verb that merges events, stamps and reports into latency
//! percentiles, per-host throughput, cache hit rates, the
//! exactly-once audit and straggler detection.

pub mod analyze;
pub mod bench;
pub mod emit;
pub mod events;

pub use analyze::{analyze, Analysis};
pub use bench::{run_bench, MetricRecord, SuiteResult};
pub use emit::{current_job, enter_job, Emitter, JobContext};
pub use events::{
    parse_events_text, read_events, Event, EventKind, EventScan, EVENT_SCHEMA_VERSION,
};
