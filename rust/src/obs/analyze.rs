//! `elaps analyze`: merge a spool's event logs, stamp sidecars and
//! done reports into a campaign-level performance report — where time
//! goes between submit and fetch, which hosts straggle, how the cache
//! behaves, and whether the exactly-once publish guarantee held. The
//! measured per-job timings here are the calibration substrate the
//! modeling roadmap (ROADMAP items on `calibrate`/`rank`) builds on.

use super::events::{read_events, Event, EventKind};
use crate::coordinator::stats::percentile_of_sorted;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Straggler threshold factor: a job is a straggler when its service
/// time exceeds `k · p90(service)`.
pub const STRAGGLER_FACTOR: f64 = 3.0;

/// p50/p90/p99 over one latency sample set, in seconds. All NaN when
/// `n == 0` (rendered as `-` / JSON `null`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl LatencySummary {
    /// Summarize one sample set. Sorts the sample once and reads the
    /// three ranks from it — not three `stats::percentile` calls, each
    /// of which would clone and sort the whole sample again (measured
    /// in the `obs` bench suite as `percentile_three_sorts` vs
    /// `latency_summary_single_sort`). NaN-poisoned samples yield
    /// all-NaN percentiles, exactly like `stats::percentile`.
    pub fn of(samples: &[f64]) -> LatencySummary {
        if samples.is_empty() || samples.iter().any(|v| v.is_nan()) {
            let nan = f64::NAN;
            return LatencySummary { n: samples.len(), p50: nan, p90: nan, p99: nan };
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        LatencySummary {
            n: samples.len(),
            p50: percentile_of_sorted(&sorted, 0.50),
            p90: percentile_of_sorted(&sorted, 0.90),
            p99: percentile_of_sorted(&sorted, 0.99),
        }
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        j.set("n", self.n)
            .set("p50", num_or_null(self.p50))
            .set("p90", num_or_null(self.p90))
            .set("p99", num_or_null(self.p99));
        j
    }
}

/// Per-host activity: successful and fenced publishes, total
/// lease-backpressure stall, and throughput over the host's active
/// span (first to last event).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HostSummary {
    pub published: usize,
    pub fenced: usize,
    pub stall_s: f64,
    pub span_s: f64,
}

impl HostSummary {
    /// Published jobs per second of active span; NaN for a host whose
    /// span is empty (a single instantaneous event).
    pub fn throughput(&self) -> f64 {
        if self.span_s > 0.0 {
            self.published as f64 / self.span_s
        } else {
            f64::NAN
        }
    }
}

/// Aggregated cache-probe counts for one class (cold/warm/seeded).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheClassSummary {
    pub hits: u64,
    pub misses: u64,
    pub skips: u64,
}

impl CacheClassSummary {
    /// hits / (hits + misses); NaN when the cache was never probed.
    pub fn hit_rate(&self) -> f64 {
        let probed = self.hits + self.misses;
        if probed == 0 {
            f64::NAN
        } else {
            self.hits as f64 / probed as f64
        }
    }
}

/// The exactly-once audit: every done job must have exactly one
/// (non-fenced) `published` event. Fenced publishes alongside are
/// expected — that is the lease protocol working.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Audit {
    pub done: usize,
    pub published_once: usize,
    /// Done jobs violating the rule, as `"<job>: N published event(s)"`.
    pub violations: Vec<String>,
}

impl Audit {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Everything `elaps analyze` computes, renderable as a human table
/// ([`Analysis::render`]) or machine-readable JSON
/// ([`Analysis::to_json`]).
#[derive(Debug, Clone)]
pub struct Analysis {
    pub campaign: Option<String>,
    /// Events considered (after the campaign filter).
    pub events: usize,
    /// Complete-but-unreadable log lines skipped by the reader.
    pub skipped_events: usize,
    /// Event counts by kind name, over the considered events.
    pub counts: BTreeMap<String, usize>,
    /// submit → first claim.
    pub queue_wait: LatencySummary,
    /// serve start → serve finish, one sample per completed serve.
    pub service: LatencySummary,
    /// serve finish → published report, per successful publish.
    pub publish: LatencySummary,
    pub hosts: BTreeMap<String, HostSummary>,
    pub cache: BTreeMap<String, CacheClassSummary>,
    pub audit: Audit,
    pub straggler_threshold_s: f64,
    pub stragglers: Vec<String>,
}

/// `Json::Num(NaN)` would serialize as the non-JSON token `NaN`:
/// absent measurements become `null` instead.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn ns_delta_s(later: u128, earlier: u128) -> f64 {
    // saturating: cross-host clock skew must not produce negative
    // latencies (or a u128 underflow panic)
    later.saturating_sub(earlier) as f64 / 1e9
}

/// Timing milestones reconstructed for one job from its events.
#[derive(Debug, Default)]
struct Timeline {
    submitted: Option<u128>,
    first_claimed: Option<u128>,
    published: Vec<u128>,
    /// serve spans by (worker, epoch): started / finished timestamps.
    serve: BTreeMap<(String, u64), (Option<u128>, Option<u128>)>,
}

/// Analyze a spool directory, optionally restricted to one campaign's
/// jobs (host-scoped events like `backpressured` are always kept).
pub fn analyze(spool: &Path, campaign_tag: Option<&str>) -> Result<Analysis> {
    if !spool.join("queue").is_dir() {
        bail!("{} is not a spool directory (no queue/)", spool.display());
    }
    let scan = read_events(spool);
    // campaign membership from the ledger index when the campaign has
    // one (O(changed-since-snapshot)), else from the record file
    let job_filter: Option<BTreeSet<String>> = match campaign_tag {
        Some(tag) => Some(
            crate::coordinator::ledger::campaign_jobs_resolved(spool, tag, true)?
                .into_iter()
                .collect(),
        ),
        None => None,
    };
    let in_scope = |ev: &Event| match &job_filter {
        None => true,
        Some(set) => ev.job_id.is_empty() || set.contains(&ev.job_id),
    };
    let events: Vec<&Event> = scan.events.iter().filter(|e| in_scope(e)).collect();

    // ---- done jobs (the audit's ground truth), campaign-filtered
    let mut done_jobs: Vec<String> = Vec::new();
    if let Ok(rd) = std::fs::read_dir(spool.join("done")) {
        for entry in rd.filter_map(|e| e.ok()) {
            let name = entry.file_name();
            let Some(id) = name.to_str().and_then(|n| n.strip_suffix(".report.json")) else {
                continue;
            };
            if job_filter.as_ref().is_none_or(|set| set.contains(id)) {
                done_jobs.push(id.to_string());
            }
        }
    }
    done_jobs.sort();

    // ---- single pass over the events
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    let mut timelines: BTreeMap<String, Timeline> = BTreeMap::new();
    let mut hosts: BTreeMap<String, HostSummary> = BTreeMap::new();
    let mut host_spans: BTreeMap<String, (u128, u128)> = BTreeMap::new();
    let mut cache: BTreeMap<String, CacheClassSummary> = BTreeMap::new();
    for ev in &events {
        *counts.entry(ev.kind.as_str().to_string()).or_default() += 1;
        let span = host_spans.entry(ev.host.clone()).or_insert((ev.t_unix_ns, ev.t_unix_ns));
        span.0 = span.0.min(ev.t_unix_ns);
        span.1 = span.1.max(ev.t_unix_ns);
        if !ev.job_id.is_empty() {
            let tl = timelines.entry(ev.job_id.clone()).or_default();
            match ev.kind {
                EventKind::Submitted => {
                    tl.submitted = Some(tl.submitted.map_or(ev.t_unix_ns, |t| t.min(ev.t_unix_ns)))
                }
                EventKind::Claimed => {
                    tl.first_claimed =
                        Some(tl.first_claimed.map_or(ev.t_unix_ns, |t| t.min(ev.t_unix_ns)))
                }
                EventKind::ServeStarted => {
                    let slot = tl.serve.entry((ev.worker.clone(), ev.epoch)).or_default();
                    slot.0 = Some(ev.t_unix_ns);
                }
                EventKind::ServeFinished => {
                    let slot = tl.serve.entry((ev.worker.clone(), ev.epoch)).or_default();
                    slot.1 = Some(ev.t_unix_ns);
                }
                EventKind::Published => tl.published.push(ev.t_unix_ns),
                _ => {}
            }
        }
        match ev.kind {
            EventKind::Published => hosts.entry(ev.host.clone()).or_default().published += 1,
            EventKind::Fenced => hosts.entry(ev.host.clone()).or_default().fenced += 1,
            EventKind::Backpressured => {
                let stall = ev.extra.get("stall_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
                hosts.entry(ev.host.clone()).or_default().stall_s += stall / 1e9;
            }
            EventKind::CacheHit | EventKind::CacheMiss | EventKind::CacheSkip => {
                let class =
                    ev.extra.get("class").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let count = ev.extra.get("count").and_then(|v| v.as_u64()).unwrap_or(1);
                let entry = cache.entry(class).or_default();
                match ev.kind {
                    EventKind::CacheHit => entry.hits += count,
                    EventKind::CacheMiss => entry.misses += count,
                    _ => entry.skips += count,
                }
            }
            _ => {}
        }
    }
    for (host, summary) in &mut hosts {
        if let Some((lo, hi)) = host_spans.get(host) {
            summary.span_s = ns_delta_s(*hi, *lo);
        }
    }

    // ---- latency samples from the timelines
    let mut queue_wait = Vec::new();
    let mut service = Vec::new();
    let mut publish = Vec::new();
    let mut service_by_job: BTreeMap<&str, f64> = BTreeMap::new();
    for (job, tl) in &timelines {
        if let (Some(s), Some(c)) = (tl.submitted, tl.first_claimed) {
            queue_wait.push(ns_delta_s(c, s));
        }
        let mut last_finished: Option<u128> = None;
        for (start, finish) in tl.serve.values() {
            if let (Some(a), Some(b)) = (start, finish) {
                let d = ns_delta_s(*b, *a);
                service.push(d);
                let worst = service_by_job.entry(job.as_str()).or_insert(0.0);
                *worst = worst.max(d);
            }
            if let Some(b) = finish {
                last_finished = Some(last_finished.map_or(*b, |t| t.max(*b)));
            }
        }
        if let Some(f) = last_finished {
            for p in &tl.published {
                publish.push(ns_delta_s(*p, f));
            }
        }
    }
    let service_summary = LatencySummary::of(&service);

    // ---- stragglers: service time beyond k·p90
    let straggler_threshold_s = STRAGGLER_FACTOR * service_summary.p90;
    let mut stragglers: Vec<String> = Vec::new();
    if straggler_threshold_s.is_finite() {
        for (job, worst) in &service_by_job {
            if *worst > straggler_threshold_s {
                stragglers.push((*job).to_string());
            }
        }
    }

    // ---- exactly-once audit over the done jobs
    let mut audit = Audit { done: done_jobs.len(), ..Default::default() };
    for job in &done_jobs {
        let n = timelines.get(job).map_or(0, |tl| tl.published.len());
        if n == 1 {
            audit.published_once += 1;
        } else {
            audit.violations.push(format!("{job}: {n} published event(s)"));
        }
    }

    Ok(Analysis {
        campaign: campaign_tag.map(str::to_string),
        events: events.len(),
        skipped_events: scan.skipped,
        counts,
        queue_wait: LatencySummary::of(&queue_wait),
        service: service_summary,
        publish: LatencySummary::of(&publish),
        hosts,
        cache,
        audit,
        straggler_threshold_s,
        stragglers,
    })
}

impl Analysis {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("v", 1u64);
        match &self.campaign {
            Some(tag) => j.set("campaign", tag.as_str()),
            None => j.set("campaign", Json::Null),
        };
        let mut ev = Json::obj();
        ev.set("total", self.events).set("skipped", self.skipped_events);
        let mut by_kind = Json::obj();
        for (kind, n) in &self.counts {
            by_kind.set(kind, *n);
        }
        ev.set("by_kind", by_kind);
        j.set("events", ev);
        let mut lat = Json::obj();
        lat.set("queue_wait_s", self.queue_wait.to_json())
            .set("service_s", self.service.to_json())
            .set("publish_s", self.publish.to_json());
        j.set("latency", lat);
        let mut hosts = Json::obj();
        for (host, h) in &self.hosts {
            let mut o = Json::obj();
            o.set("published", h.published)
                .set("fenced", h.fenced)
                .set("stall_s", num_or_null(h.stall_s))
                .set("span_s", num_or_null(h.span_s))
                .set("throughput_jobs_per_s", num_or_null(h.throughput()));
            hosts.set(host, o);
        }
        j.set("hosts", hosts);
        let mut cache = Json::obj();
        for (class, c) in &self.cache {
            let mut o = Json::obj();
            o.set("hits", c.hits)
                .set("misses", c.misses)
                .set("skips", c.skips)
                .set("hit_rate", num_or_null(c.hit_rate()));
            cache.set(class, o);
        }
        j.set("cache", cache);
        let mut audit = Json::obj();
        audit
            .set("done", self.audit.done)
            .set("published_once", self.audit.published_once)
            .set("ok", self.audit.ok())
            .set(
                "violations",
                Json::Arr(self.audit.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            );
        j.set("audit", audit);
        let mut stragglers = Json::obj();
        stragglers.set("threshold_s", num_or_null(self.straggler_threshold_s)).set(
            "jobs",
            Json::Arr(self.stragglers.iter().map(|v| Json::Str(v.clone())).collect()),
        );
        j.set("stragglers", stragglers);
        j
    }

    /// The human table.
    pub fn render(&self) -> String {
        let fmt_s = |x: f64| {
            if x.is_finite() {
                format!("{x:>9.4}")
            } else {
                format!("{:>9}", "-")
            }
        };
        let mut out = String::new();
        match &self.campaign {
            Some(tag) => out.push_str(&format!("campaign '{tag}': ")),
            None => out.push_str("spool: "),
        }
        out.push_str(&format!(
            "{} done job(s), {} event(s), {} skipped line(s)\n",
            self.audit.done, self.events, self.skipped_events
        ));
        if self.events == 0 {
            out.push_str("  no events recorded (run without --no-events to analyze latency)\n");
        }
        out.push_str(&format!(
            "  latency (s)      {:>9} {:>9} {:>9} {:>6}\n",
            "p50", "p90", "p99", "n"
        ));
        for (label, l) in [
            ("queue-wait", &self.queue_wait),
            ("service", &self.service),
            ("publish", &self.publish),
        ] {
            out.push_str(&format!(
                "    {label:<12} {} {} {} {:>6}\n",
                fmt_s(l.p50),
                fmt_s(l.p90),
                fmt_s(l.p99),
                l.n
            ));
        }
        if !self.hosts.is_empty() {
            out.push_str("  hosts:\n");
            for (host, h) in &self.hosts {
                let rate = h.throughput();
                let rate = if rate.is_finite() {
                    format!("{rate:.2} job/s")
                } else {
                    "- job/s".to_string()
                };
                out.push_str(&format!(
                    "    {host:<16} {} published, {} fenced, stall {:.3}s, {rate}\n",
                    h.published, h.fenced, h.stall_s
                ));
            }
        }
        if !self.cache.is_empty() {
            out.push_str("  cache:\n");
            for (class, c) in &self.cache {
                let rate = c.hit_rate();
                let rate = if rate.is_finite() {
                    format!("{:.1}%", 100.0 * rate)
                } else {
                    "-".to_string()
                };
                out.push_str(&format!(
                    "    {class:<8} {}/{} hits ({rate}), {} uncached\n",
                    c.hits,
                    c.hits + c.misses,
                    c.skips
                ));
            }
        }
        if self.audit.ok() {
            out.push_str(&format!(
                "  exactly-once audit: PASS ({}/{} done jobs published exactly once)\n",
                self.audit.published_once, self.audit.done
            ));
        } else {
            out.push_str(&format!(
                "  exactly-once audit: FAIL ({} violation(s))\n",
                self.audit.violations.len()
            ));
            for v in &self.audit.violations {
                out.push_str(&format!("    {v}\n"));
            }
        }
        if self.straggler_threshold_s.is_finite() {
            if self.stragglers.is_empty() {
                out.push_str(&format!(
                    "  stragglers (> {STRAGGLER_FACTOR:.1}×p90 service): none\n"
                ));
            } else {
                out.push_str(&format!(
                    "  stragglers (> {:.4}s service):\n",
                    self.straggler_threshold_s
                ));
                for job in &self.stragglers {
                    out.push_str(&format!("    {job}\n"));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::emit::Emitter;
    use std::path::PathBuf;

    fn spool_skeleton(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("elaps_obs_analyze_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for sub in ["queue", "running", "done", "leases", "stamps", "events"] {
            std::fs::create_dir_all(dir.join(sub)).unwrap();
        }
        dir
    }

    fn mark_done(dir: &Path, job: &str) {
        std::fs::write(dir.join("done").join(format!("{job}.report.json")), "{}").unwrap();
    }

    #[test]
    fn analyze_rejects_non_spool_dirs() {
        let dir = std::env::temp_dir().join(format!("elaps_obs_nospool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(analyze(&dir, None).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_lifecycle_produces_ordered_percentiles_and_passing_audit() {
        let dir = spool_skeleton("ok");
        let client = Emitter::for_spool(&dir, "laptop", "laptop#1-0")
            .with_enabled(true)
            .with_campaign("camp");
        let worker = Emitter::for_spool(&dir, "hA", "hA#1-1").with_enabled(true);
        for (i, job) in ["job-a", "job-b", "job-c"].iter().enumerate() {
            client.emit(EventKind::Submitted, job, 0, &[]);
            worker.emit(EventKind::Claimed, job, 1, &[]);
            worker.emit(EventKind::ServeStarted, job, 1, &[]);
            if i == 0 {
                crate::obs::emit::emit_cache_counts(EventKind::CacheHit, "cold", 2);
            }
            worker.emit(EventKind::ServeFinished, job, 1, &[("outcome", "ok".into())]);
            worker.emit(EventKind::Published, job, 1, &[]);
            mark_done(&dir, job);
        }
        // register the campaign so --campaign filtering can join
        let ids: Vec<String> = ["job-a", "job-b", "job-c"].iter().map(|s| s.to_string()).collect();
        crate::coordinator::campaign::record_jobs(&dir, "camp", &ids).unwrap();
        let a = analyze(&dir, Some("camp")).unwrap();
        assert_eq!(a.audit.done, 3);
        assert!(a.audit.ok(), "{:?}", a.audit.violations);
        assert_eq!(a.counts.get("submitted"), Some(&3));
        assert_eq!(a.counts.get("published"), Some(&3));
        for l in [&a.queue_wait, &a.service, &a.publish] {
            assert_eq!(l.n, 3);
            assert!(l.p50.is_finite() && l.p90.is_finite() && l.p99.is_finite());
            assert!(l.p50 <= l.p90 && l.p90 <= l.p99, "{l:?}");
            assert!(l.p50 >= 0.0);
        }
        assert_eq!(a.hosts.get("hA").map(|h| h.published), Some(3));
        // the unfiltered view sees the same spool
        let all = analyze(&dir, None).unwrap();
        assert_eq!(all.audit.done, 3);
        assert!(all.events >= a.events);
        // JSON stays parseable (NaN-free) and carries the audit
        let j = Json::parse(&a.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("audit").get("ok").as_bool(), Some(true));
        assert_eq!(j.get("cache").get("cold").get("hits").as_u64(), None, "no job ctx, no event");
        assert!(a.render().contains("PASS"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn audit_flags_missing_and_duplicate_publishes() {
        let dir = spool_skeleton("audit");
        let worker = Emitter::for_spool(&dir, "hB", "hB#1-0").with_enabled(true);
        // done without any published event
        mark_done(&dir, "silent");
        // done with two published events
        worker.emit(EventKind::Published, "twice", 1, &[]);
        worker.emit(EventKind::Published, "twice", 2, &[]);
        mark_done(&dir, "twice");
        // fenced alongside a single publish is fine
        worker.emit(EventKind::Fenced, "fenced-ok", 1, &[("reason", "superseded".into())]);
        worker.emit(EventKind::Published, "fenced-ok", 2, &[]);
        mark_done(&dir, "fenced-ok");
        let a = analyze(&dir, None).unwrap();
        assert_eq!(a.audit.done, 3);
        assert_eq!(a.audit.published_once, 1);
        assert!(!a.audit.ok());
        assert_eq!(a.audit.violations.len(), 2);
        assert_eq!(a.hosts.get("hB").map(|h| h.fenced), Some(1));
        assert!(a.render().contains("FAIL"));
        let j = a.to_json();
        assert_eq!(j.get("audit").get("ok").as_bool(), Some(false));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backpressure_stall_and_cache_classes_aggregate() {
        let dir = spool_skeleton("stall");
        let worker = Emitter::for_spool(&dir, "hC", "hC#1-0").with_enabled(true);
        worker.emit(EventKind::Backpressured, "", 0, &[("stall_ns", 2_000_000_000u64.into())]);
        worker.emit(EventKind::Backpressured, "", 0, &[("stall_ns", 500_000_000u64.into())]);
        worker.emit(
            EventKind::CacheHit,
            "j1",
            1,
            &[("class", "warm".into()), ("count", 3u64.into())],
        );
        worker.emit(
            EventKind::CacheMiss,
            "j1",
            1,
            &[("class", "warm".into()), ("count", 1u64.into())],
        );
        let a = analyze(&dir, None).unwrap();
        let h = a.hosts.get("hC").unwrap();
        assert!((h.stall_s - 2.5).abs() < 1e-9, "{}", h.stall_s);
        let warm = a.cache.get("warm").unwrap();
        assert_eq!((warm.hits, warm.misses, warm.skips), (3, 1, 0));
        assert!((warm.hit_rate() - 0.75).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
