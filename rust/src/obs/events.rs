//! The job-lifecycle event schema: versioned, serde-free JSON records
//! of everything that happens to a job between `elaps submit` and its
//! published report. One event per line in per-host JSONL logs under
//! `<spool>/events/<host>.jsonl`, written crash-safely by
//! [`crate::obs::emit::Emitter`] and merged by `elaps analyze`
//! ([`crate::obs::analyze`]) into the campaign-level timings the
//! modeling work (ROADMAP) needs as calibration input.
//!
//! # Compatibility rule
//!
//! Every event carries a schema version `v`. A reader accepts events
//! with `v <= EVENT_SCHEMA_VERSION` and a kind it knows, ignoring any
//! fields it does not understand; events from a *newer* schema or with
//! an unknown kind are skipped (and counted), never an error. Writers
//! may add new kinds and new fields without a version bump; removing
//! or re-typing a core field requires one.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Version stamped into every emitted event (the `v` field).
pub const EVENT_SCHEMA_VERSION: u64 = 1;

/// The core fields every event carries; anything else round-trips
/// through [`Event::extra`].
const CORE_KEYS: [&str; 9] =
    ["v", "kind", "job_id", "campaign", "host", "worker", "epoch", "t_unix_ns", "seq"];

/// What happened. The taxonomy covers the spooler's whole job
/// lifecycle plus the engine's cache probe:
///
/// | kind             | emitted by                  | extra fields        |
/// |------------------|-----------------------------|---------------------|
/// | `submitted`      | client (`elaps submit`)     | —                   |
/// | `claimed`        | worker claim                | —                   |
/// | `heartbeat`      | worker lease renewal        | —                   |
/// | `serve_started`  | worker, before execution    | —                   |
/// | `serve_finished` | worker, after execution     | `outcome`           |
/// | `published`      | worker, report landed       | —                   |
/// | `fenced`         | worker, publish refused     | `reason`            |
/// | `backpressured`  | worker daemon, at lease cap | `stall_ns`          |
/// | `cache_hit`      | engine cache probe          | `class`, `count`    |
/// | `cache_miss`     | engine cache probe          | `class`, `count`    |
/// | `cache_skip`     | engine, no cache configured | `class`, `count`    |
/// | `retried`        | client (`elaps retry`)      | `of`, `attempt`     |
/// | `dead_lettered`  | client (`elaps retry`)      | `attempts`          |
///
/// `retried` and `dead_lettered` are ledger facts (`elaps retry`
/// records them in the campaign ledger, not the per-host event logs):
/// `retried` marks the *new* job id with `of` naming the failed job it
/// replaces; `dead_lettered` marks a job whose retry chain exhausted
/// its attempt budget. Both are additions under the compatibility rule
/// — older readers skip them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    Submitted,
    Claimed,
    Heartbeat,
    ServeStarted,
    ServeFinished,
    Published,
    Fenced,
    Backpressured,
    CacheHit,
    CacheMiss,
    CacheSkip,
    Retried,
    DeadLettered,
}

/// Every kind, in lifecycle order.
pub const ALL_EVENT_KINDS: &[EventKind] = &[
    EventKind::Submitted,
    EventKind::Claimed,
    EventKind::Heartbeat,
    EventKind::ServeStarted,
    EventKind::ServeFinished,
    EventKind::Published,
    EventKind::Fenced,
    EventKind::Backpressured,
    EventKind::CacheHit,
    EventKind::CacheMiss,
    EventKind::CacheSkip,
    EventKind::Retried,
    EventKind::DeadLettered,
];

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Submitted => "submitted",
            EventKind::Claimed => "claimed",
            EventKind::Heartbeat => "heartbeat",
            EventKind::ServeStarted => "serve_started",
            EventKind::ServeFinished => "serve_finished",
            EventKind::Published => "published",
            EventKind::Fenced => "fenced",
            EventKind::Backpressured => "backpressured",
            EventKind::CacheHit => "cache_hit",
            EventKind::CacheMiss => "cache_miss",
            EventKind::CacheSkip => "cache_skip",
            EventKind::Retried => "retried",
            EventKind::DeadLettered => "dead_lettered",
        }
    }

    /// Inverse of [`EventKind::as_str`]; `None` for kinds this reader
    /// does not know (the compatibility rule says: skip them).
    pub fn by_name(name: &str) -> Option<EventKind> {
        ALL_EVENT_KINDS.iter().copied().find(|k| k.as_str() == name)
    }
}

/// One job-lifecycle event. `campaign` is known only on the submitting
/// client (workers see bare job ids — `elaps analyze --campaign` joins
/// their events via the campaign record); `job_id` is empty for
/// host-scoped events (`backpressured`). `t_unix_ns` is serialized as
/// a decimal *string*: nanosecond epoch timestamps (~1.7e18) exceed
/// the f64-exact integer range, and our JSON numbers are f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    pub job_id: String,
    pub campaign: String,
    pub host: String,
    pub worker: String,
    pub epoch: u64,
    pub t_unix_ns: u128,
    /// Process-global emission counter: strictly increasing over the
    /// events any one `(host, worker)` writes, which is what lets a
    /// reader order one worker's events without trusting clocks.
    pub seq: u64,
    /// Kind-specific payload (`reason`, `outcome`, `class`, `count`,
    /// `stall_ns`) plus any field a newer writer added.
    pub extra: BTreeMap<String, Json>,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("v", EVENT_SCHEMA_VERSION)
            .set("kind", self.kind.as_str())
            .set("job_id", self.job_id.as_str())
            .set("campaign", self.campaign.as_str())
            .set("host", self.host.as_str())
            .set("worker", self.worker.as_str())
            .set("epoch", self.epoch)
            .set("t_unix_ns", self.t_unix_ns.to_string())
            .set("seq", self.seq);
        for (k, v) in &self.extra {
            if !CORE_KEYS.contains(&k.as_str()) {
                j.set(k, v.clone());
            }
        }
        j
    }

    /// The log-file form: one compact line, newline-terminated (the
    /// unit of the emitter's single `O_APPEND` write).
    pub fn to_line(&self) -> String {
        format!("{}\n", self.to_json().to_string_compact())
    }

    /// Parse one event. `None` — never a panic — for anything a
    /// same-or-older reader cannot interpret: missing/mistyped core
    /// fields, an unknown kind, or a newer schema version. Unknown
    /// non-core fields are preserved in [`Event::extra`].
    pub fn from_json(j: &Json) -> Option<Event> {
        let v = j.get("v").as_u64()?;
        if v > EVENT_SCHEMA_VERSION {
            return None;
        }
        let kind = EventKind::by_name(j.get("kind").as_str()?)?;
        // accept both the string form we write and a plain number (a
        // small-timestamp writer is within f64-exact range anyway)
        let t_unix_ns = match j.get("t_unix_ns") {
            Json::Str(s) => s.parse::<u128>().ok()?,
            other => other.as_u64()? as u128,
        };
        let mut extra = BTreeMap::new();
        for (k, val) in j.as_obj()? {
            if !CORE_KEYS.contains(&k.as_str()) {
                extra.insert(k.clone(), val.clone());
            }
        }
        Some(Event {
            kind,
            job_id: j.get("job_id").as_str()?.to_string(),
            campaign: j.get("campaign").as_str()?.to_string(),
            host: j.get("host").as_str()?.to_string(),
            worker: j.get("worker").as_str()?.to_string(),
            epoch: j.get("epoch").as_u64()?,
            t_unix_ns,
            seq: j.get("seq").as_u64()?,
            extra,
        })
    }
}

/// The result of reading an event log: every recoverable event in file
/// order, plus how many complete-but-unreadable lines were skipped
/// under the compatibility rule. A trailing line without its newline
/// (a writer crashed or is still mid-append) is ignored silently — it
/// is an in-flight write, not a malformed record.
#[derive(Debug, Clone, Default)]
pub struct EventScan {
    pub events: Vec<Event>,
    pub skipped: usize,
}

/// Parse event-log text: one event per `\n`-terminated line. The
/// partial-line tolerance that makes single-write `O_APPEND` logging
/// crash-safe lives here — everything after the last newline is
/// ignored, and any complete line that fails to parse is counted in
/// [`EventScan::skipped`] instead of aborting the scan.
pub fn parse_events_text(text: &str) -> EventScan {
    let mut scan = EventScan::default();
    let complete = match text.rfind('\n') {
        Some(i) => &text[..i + 1],
        None => "",
    };
    for line in complete.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line).ok().and_then(|j| Event::from_json(&j)) {
            Some(ev) => scan.events.push(ev),
            None => scan.skipped += 1,
        }
    }
    scan
}

/// Read every per-host event log under `<spool>/events/`, in file-name
/// order (deterministic across runs). A spool without an events
/// directory — pre-observability, or run with `--no-events` — scans as
/// empty; an unreadable file is skipped.
pub fn read_events(spool: &Path) -> EventScan {
    let mut scan = EventScan::default();
    let Ok(rd) = std::fs::read_dir(spool.join("events")) else {
        return scan;
    };
    let mut files: Vec<PathBuf> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
        .collect();
    files.sort();
    for file in files {
        if let Ok(text) = std::fs::read_to_string(&file) {
            let s = parse_events_text(&text);
            scan.events.extend(s.events);
            scan.skipped += s.skipped;
        }
    }
    scan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(kind: EventKind, seq: u64) -> Event {
        Event {
            kind,
            job_id: "job-1".into(),
            campaign: "camp".into(),
            host: "hostA".into(),
            worker: "hostA#7-0".into(),
            epoch: 2,
            t_unix_ns: 1_700_000_000_123_456_789,
            seq,
            extra: BTreeMap::new(),
        }
    }

    #[test]
    fn kind_names_roundtrip() {
        for &k in ALL_EVENT_KINDS {
            assert_eq!(EventKind::by_name(k.as_str()), Some(k));
        }
        assert_eq!(EventKind::by_name("job_teleported"), None);
    }

    #[test]
    fn event_roundtrip_preserves_nanosecond_timestamps() {
        // 1.7e18 ns is beyond f64-exact integers (2^53 ≈ 9e15): the
        // string form must survive a JSON round trip bit-for-bit
        let mut ev = sample(EventKind::ServeFinished, 41);
        ev.extra.insert("outcome".into(), Json::Str("ok".into()));
        let line = ev.to_line();
        assert!(line.ends_with('\n'));
        assert!(line.contains("\"1700000000123456789\""), "{line}");
        let back = Event::from_json(&Json::parse(line.trim()).unwrap()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn compatibility_rule_skips_unknown_and_newer() {
        // unknown kind: skipped
        let mut j = sample(EventKind::Claimed, 0).to_json();
        j.set("kind", "job_teleported");
        assert_eq!(Event::from_json(&j), None);
        // newer schema version: skipped
        let mut j = sample(EventKind::Claimed, 0).to_json();
        j.set("v", EVENT_SCHEMA_VERSION + 1);
        assert_eq!(Event::from_json(&j), None);
        // unknown *fields* from a same-version writer: preserved
        let mut j = sample(EventKind::Claimed, 0).to_json();
        j.set("future_field", 7u64);
        let ev = Event::from_json(&j).unwrap();
        assert_eq!(ev.extra.get("future_field"), Some(&Json::Num(7.0)));
    }

    #[test]
    fn parse_tolerates_truncated_final_line_and_garbage() {
        let a = sample(EventKind::Submitted, 0);
        let b = sample(EventKind::Claimed, 1);
        let c = sample(EventKind::Published, 2);
        let mut text = a.to_line();
        text.push_str("{ this is not json }\n");
        text.push_str(&b.to_line());
        // c's write was cut mid-line by a crash: no trailing newline
        let cut = c.to_line();
        text.push_str(&cut[..cut.len() / 2]);
        let scan = parse_events_text(&text);
        assert_eq!(scan.events, vec![a, b]);
        assert_eq!(scan.skipped, 1, "only the complete garbage line counts");
        // an empty or newline-free buffer scans as empty
        assert!(parse_events_text("").events.is_empty());
        assert!(parse_events_text("{\"v\":1").events.is_empty());
        assert_eq!(parse_events_text("{\"v\":1").skipped, 0);
    }

    #[test]
    fn read_events_scans_all_hosts_and_missing_dir_is_empty() {
        let dir = std::env::temp_dir().join(format!("elaps_obs_events_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(read_events(&dir).events.is_empty());
        std::fs::create_dir_all(dir.join("events")).unwrap();
        let a = sample(EventKind::Submitted, 0);
        let mut b = sample(EventKind::Claimed, 1);
        b.host = "hostB".into();
        std::fs::write(dir.join("events").join("hostA.jsonl"), a.to_line()).unwrap();
        std::fs::write(dir.join("events").join("hostB.jsonl"), b.to_line()).unwrap();
        std::fs::write(dir.join("events").join("notes.txt"), "ignored").unwrap();
        let scan = read_events(&dir);
        assert_eq!(scan.events, vec![a, b], "file-name order");
        assert_eq!(scan.skipped, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
