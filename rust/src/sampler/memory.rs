//! Sampler memory management (§3.1): named variables, derived offset
//! variables, and dynamic (unnamed) scratch memory.
//!
//! Named variables are f64 buffers created by `dmalloc`; `doffset`
//! creates aliases at an element offset inside an existing buffer
//! (the paper's `xoffset`, which the coordinator uses to lay out
//! varying operands inside one large allocation); `free` releases a
//! buffer and its aliases. Dynamic memory (`[n]` operand tokens) is a
//! bump allocator reset per call — disjoint within one call, reused
//! across calls, exactly as the paper specifies.

use crate::util::rng::Xoshiro256;
use std::collections::BTreeMap;

/// A resolved operand location.
#[derive(Debug, Clone, Copy)]
pub struct Resolved {
    /// Stable buffer identity (for the cache simulator).
    pub buf_id: u64,
    /// Pointer to the first element.
    pub ptr: *mut f64,
    /// Elements available from `ptr` to the end of the buffer.
    pub len: usize,
    /// Byte offset of `ptr` within the buffer (for the cache sim).
    pub byte_off: usize,
}

#[derive(Debug)]
struct Variable {
    id: u64,
    data: Vec<f64>,
}

#[derive(Debug, Clone)]
struct Alias {
    base: String,
    offset_elems: usize,
}

/// The sampler's memory arena.
#[derive(Debug, Default)]
pub struct Memory {
    vars: BTreeMap<String, Variable>,
    aliases: BTreeMap<String, Alias>,
    scratch: Vec<f64>,
    scratch_used: usize,
    next_id: u64,
}

impl Memory {
    pub fn new() -> Memory {
        Memory::default()
    }

    /// `dmalloc name elems` — allocate a named variable (zeroed).
    pub fn malloc(&mut self, name: &str, elems: usize) -> Result<(), String> {
        if self.aliases.contains_key(name) {
            return Err(format!("'{name}' already exists as an offset alias"));
        }
        self.next_id += 1;
        let id = self.next_id;
        self.vars.insert(name.to_string(), Variable { id, data: vec![0.0; elems] });
        Ok(())
    }

    /// `doffset new base elems` — create an alias into `base` at an
    /// element offset. Chained offsets (alias of alias) accumulate.
    pub fn offset(&mut self, new: &str, base: &str, elems: usize) -> Result<(), String> {
        let (root, base_off) = self.root_of(base)?;
        if self.vars.contains_key(new) {
            return Err(format!("'{new}' already exists as a variable"));
        }
        self.aliases
            .insert(new.to_string(), Alias { base: root, offset_elems: base_off + elems });
        Ok(())
    }

    /// `free name` — release a variable (and any aliases into it).
    pub fn free(&mut self, name: &str) -> Result<(), String> {
        if self.vars.remove(name).is_some() {
            let base = name.to_string();
            self.aliases.retain(|_, a| a.base != base);
            Ok(())
        } else if self.aliases.remove(name).is_some() {
            Ok(())
        } else {
            Err(format!("unknown variable '{name}'"))
        }
    }

    fn root_of(&self, name: &str) -> Result<(String, usize), String> {
        if self.vars.contains_key(name) {
            return Ok((name.to_string(), 0));
        }
        match self.aliases.get(name) {
            Some(a) => Ok((a.base.clone(), a.offset_elems)),
            None => Err(format!("unknown variable '{name}'")),
        }
    }

    /// Resolve a named operand to its location.
    pub fn resolve(&mut self, name: &str) -> Result<Resolved, String> {
        let (root, off) = self.root_of(name)?;
        let var = self.vars.get_mut(&root).unwrap();
        if off > var.data.len() {
            return Err(format!("offset of '{name}' exceeds buffer '{root}'"));
        }
        Ok(Resolved {
            buf_id: var.id,
            ptr: unsafe { var.data.as_mut_ptr().add(off) },
            len: var.data.len() - off,
            byte_off: off * 8,
        })
    }

    /// Ensure the dynamic pool holds at least `elems` elements.
    /// MUST be called before handing out [`Self::dynamic`] pointers for
    /// a call (growing the pool mid-call would reallocate and dangle
    /// earlier pointers).
    pub fn reserve_dynamic(&mut self, elems: usize) {
        if self.scratch.len() < elems {
            self.scratch.resize(elems, 0.0);
        }
    }

    /// Allocate `elems` of dynamic (unnamed) memory for the current
    /// call. Regions are disjoint within a call; [`Self::reset_dynamic`]
    /// recycles them for the next call. Call [`Self::reserve_dynamic`]
    /// with the call's total first.
    pub fn dynamic(&mut self, elems: usize) -> Resolved {
        let need = self.scratch_used + elems;
        if self.scratch.len() < need {
            self.scratch.resize(need, 0.0);
        }
        let off = self.scratch_used;
        self.scratch_used += elems;
        Resolved {
            buf_id: u64::MAX, // single scratch identity
            ptr: unsafe { self.scratch.as_mut_ptr().add(off) },
            len: elems,
            byte_off: off * 8,
        }
    }

    /// Recycle dynamic memory (call boundary).
    pub fn reset_dynamic(&mut self) {
        self.scratch_used = 0;
    }

    /// `dmemset name value` — fill a variable (from its offset to the
    /// end of its buffer view) with a constant.
    pub fn memset(&mut self, name: &str, value: f64) -> Result<(), String> {
        let r = self.resolve(name)?;
        let s = unsafe { std::slice::from_raw_parts_mut(r.ptr, r.len) };
        s.fill(value);
        Ok(())
    }

    /// `dgerand name [elems]` — fill with uniform ]0,1[ values.
    pub fn gerand(&mut self, name: &str, elems: Option<usize>, rng: &mut Xoshiro256) -> Result<(), String> {
        let r = self.resolve(name)?;
        let n = elems.unwrap_or(r.len).min(r.len);
        let s = unsafe { std::slice::from_raw_parts_mut(r.ptr, n) };
        rng.fill_open01(s);
        Ok(())
    }

    /// `dporand name n` — write a random n×n SPD matrix (ld = n).
    pub fn porand(&mut self, name: &str, n: usize, rng: &mut Xoshiro256) -> Result<(), String> {
        let r = self.resolve(name)?;
        if r.len < n * n {
            return Err(format!("'{name}' too small for {n}x{n} SPD matrix"));
        }
        let m = crate::linalg::Matrix::random_spd(n, rng);
        let s = unsafe { std::slice::from_raw_parts_mut(r.ptr, n * n) };
        s.copy_from_slice(&m.data);
        Ok(())
    }

    /// `dtrrand name n uplo` — random well-conditioned triangular n×n.
    pub fn trrand(
        &mut self,
        name: &str,
        n: usize,
        uplo: crate::linalg::Uplo,
        rng: &mut Xoshiro256,
    ) -> Result<(), String> {
        let r = self.resolve(name)?;
        if r.len < n * n {
            return Err(format!("'{name}' too small for {n}x{n} triangular matrix"));
        }
        let m = crate::linalg::Matrix::random_triangular(n, uplo, rng);
        let s = unsafe { std::slice::from_raw_parts_mut(r.ptr, n * n) };
        s.copy_from_slice(&m.data);
        Ok(())
    }

    /// `dwritefile name path` — dump a variable to a little-endian
    /// binary file of f64.
    pub fn writefile(&mut self, name: &str, path: &str) -> Result<(), String> {
        let r = self.resolve(name)?;
        let s = unsafe { std::slice::from_raw_parts(r.ptr, r.len) };
        let bytes: Vec<u8> = s.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(path, bytes).map_err(|e| e.to_string())
    }

    /// `dreadfile name path` — load a binary f64 file into a variable.
    pub fn readfile(&mut self, name: &str, path: &str) -> Result<(), String> {
        let bytes = std::fs::read(path).map_err(|e| e.to_string())?;
        let r = self.resolve(name)?;
        let n = (bytes.len() / 8).min(r.len);
        let s = unsafe { std::slice::from_raw_parts_mut(r.ptr, n) };
        for (i, chunk) in bytes.chunks_exact(8).take(n).enumerate() {
            s[i] = f64::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }

    pub fn exists(&self, name: &str) -> bool {
        self.vars.contains_key(name) || self.aliases.contains_key(name)
    }

    /// Total allocated elements (named variables only).
    pub fn allocated_elems(&self) -> usize {
        self.vars.values().map(|v| v.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malloc_resolve_free() {
        let mut m = Memory::new();
        m.malloc("A", 100).unwrap();
        let r = m.resolve("A").unwrap();
        assert_eq!(r.len, 100);
        assert_eq!(r.byte_off, 0);
        m.free("A").unwrap();
        assert!(m.resolve("A").is_err());
    }

    #[test]
    fn offsets_share_buffer_identity() {
        let mut m = Memory::new();
        m.malloc("big", 1000).unwrap();
        m.offset("B1", "big", 100).unwrap();
        m.offset("B2", "B1", 200).unwrap(); // chained: offset 300
        let rb = m.resolve("big").unwrap();
        let r1 = m.resolve("B1").unwrap();
        let r2 = m.resolve("B2").unwrap();
        assert_eq!(rb.buf_id, r1.buf_id);
        assert_eq!(r1.byte_off, 800);
        assert_eq!(r2.byte_off, 2400);
        assert_eq!(r2.len, 700);
        assert_eq!(unsafe { r1.ptr.offset_from(rb.ptr) }, 100);
    }

    #[test]
    fn free_base_removes_aliases() {
        let mut m = Memory::new();
        m.malloc("big", 10).unwrap();
        m.offset("x", "big", 2).unwrap();
        m.free("big").unwrap();
        assert!(!m.exists("x"));
    }

    #[test]
    fn memset_and_gerand() {
        let mut m = Memory::new();
        let mut rng = Xoshiro256::seeded(1);
        m.malloc("A", 50).unwrap();
        m.memset("A", 2.5).unwrap();
        let r = m.resolve("A").unwrap();
        let s = unsafe { std::slice::from_raw_parts(r.ptr, r.len) };
        assert!(s.iter().all(|&v| v == 2.5));
        m.gerand("A", None, &mut rng).unwrap();
        let s = unsafe { std::slice::from_raw_parts(r.ptr, r.len) };
        assert!(s.iter().all(|&v| v > 0.0 && v < 1.0));
    }

    #[test]
    fn porand_is_spd_shaped() {
        let mut m = Memory::new();
        let mut rng = Xoshiro256::seeded(2);
        m.malloc("M", 16).unwrap();
        m.porand("M", 4, &mut rng).unwrap();
        let r = m.resolve("M").unwrap();
        let s = unsafe { std::slice::from_raw_parts(r.ptr, 16) };
        for i in 0..4 {
            for j in 0..4 {
                assert!((s[i + 4 * j] - s[j + 4 * i]).abs() < 1e-12);
            }
            assert!(s[i + 4 * i] > 4.0);
        }
        assert!(m.porand("M", 5, &mut rng).is_err());
    }

    #[test]
    fn dynamic_memory_disjoint_within_call() {
        let mut m = Memory::new();
        m.reserve_dynamic(150);
        let a = m.dynamic(100);
        let b = m.dynamic(50);
        assert_ne!(a.ptr, b.ptr);
        assert_eq!(unsafe { b.ptr.offset_from(a.ptr) }, 100);
        m.reset_dynamic();
        let c = m.dynamic(10);
        assert_eq!(c.ptr, a.ptr); // reused
    }

    #[test]
    fn file_roundtrip() {
        let mut m = Memory::new();
        let mut rng = Xoshiro256::seeded(3);
        m.malloc("A", 20).unwrap();
        m.gerand("A", None, &mut rng).unwrap();
        let path = std::env::temp_dir().join("elaps_mem_test.bin");
        let path = path.to_str().unwrap();
        m.writefile("A", path).unwrap();
        m.malloc("B", 20).unwrap();
        m.readfile("B", path).unwrap();
        let ra = m.resolve("A").unwrap();
        let rb = m.resolve("B").unwrap();
        let sa = unsafe { std::slice::from_raw_parts(ra.ptr, 20) };
        let sb = unsafe { std::slice::from_raw_parts(rb.ptr, 20) };
        assert_eq!(sa, sb);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn name_collisions_rejected() {
        let mut m = Memory::new();
        m.malloc("A", 10).unwrap();
        m.offset("B", "A", 1).unwrap();
        assert!(m.malloc("B", 5).is_err());
        assert!(m.offset("A", "A", 1).is_err());
        assert!(m.offset("C", "nope", 0).is_err());
        assert!(m.free("nope").is_err());
    }
}
