//! The Sampler — the paper's bottom layer (§3.1): a low-level tool
//! that reads a list of kernel calls plus special commands, executes
//! and times them, and reports raw measurements.
//!
//! Workflow (exactly the paper's):
//! 1. read calls (and `dmalloc`/`doffset`/`free`/utility commands) from
//!    the input;
//! 2. on `go`, execute all queued calls, timing each in CPU cycles and
//!    sampling the (simulated) PAPI counters selected by
//!    `set_counters`;
//! 3. report one result line per call.
//!
//! `{omp` … `}` brackets a group of calls to be treated as parallel
//! OpenMP tasks (executed sequentially on this 1-core host; the
//! measured serial task times are reported with the group id so the
//! coordinator can apply the thread-scaling model — DESIGN.md
//! §Substitutions 4).
//!
//! One Sampler is bound to one kernel library (the paper compiles one
//! sampler binary per library) and one machine model.

pub mod memory;

use crate::kernels::{ArgRole, ArgValue, ArgValues};
use crate::libraries::{KernelLibrary, OperandSet, RawOperand};
use crate::perfmodel::{CacheSim, MachineModel};
use crate::util::rng::Xoshiro256;
use anyhow::{anyhow, bail, Result};
use memory::Memory;
use std::sync::Arc;
use std::time::Instant;

/// One queued kernel call.
#[derive(Debug)]
struct QueuedCall {
    av: ArgValues,
    omp_group: Option<usize>,
}

/// One measurement record, as printed on the sampler's stdout.
#[derive(Debug, Clone)]
pub struct Record {
    pub kernel: String,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Cycles on the bound machine model (seconds × frequency).
    pub cycles: f64,
    /// Values of the counters selected via `set_counters`, in order.
    pub counters: Vec<u64>,
    /// OpenMP task-group id, if the call was inside `{omp … }`.
    pub omp_group: Option<usize>,
    /// Flops of the call (from the signature) — convenience for
    /// metrics.
    pub flops: f64,
}

impl Record {
    /// Render as the sampler's stdout line.
    pub fn to_line(&self) -> String {
        let mut s = format!("{} {:.0}", self.kernel, self.cycles);
        for c in &self.counters {
            s.push_str(&format!(" {c}"));
        }
        if let Some(g) = self.omp_group {
            s.push_str(&format!(" #omp{g}"));
        }
        s
    }
}

/// The RNG seed of a default-constructed sampler (operand data from
/// `dgerand` & co. is always deterministic; [`Sampler::deterministic`]
/// additionally makes the *timing* deterministic).
pub const DEFAULT_RNG_SEED: u64 = 0xE1A5;

/// The sampler.
pub struct Sampler {
    pub library: Arc<dyn KernelLibrary>,
    pub machine: MachineModel,
    mem: Memory,
    cache: CacheSim,
    counters: Vec<String>,
    queue: Vec<QueuedCall>,
    omp_depth: Option<usize>,
    next_group: usize,
    rng: Xoshiro256,
    /// Seed the RNG stream restarts from at every script boundary
    /// ([`Sampler::reset_warm`]).
    rng_seed: u64,
    /// When set, `seconds` is the machine model's deterministic
    /// prediction ([`MachineModel::modeled_seconds`]) instead of
    /// measured wall time.
    modeled_time: bool,
    /// When set, queued kernels are *not* executed: records carry the
    /// modeled time and simulated counters only (`elaps rank`). Implies
    /// `modeled_time`; numerical results are unavailable in this mode.
    predict_only: bool,
}

impl Sampler {
    pub fn new(library: Arc<dyn KernelLibrary>, machine: MachineModel) -> Sampler {
        let cache = CacheSim::new(&machine);
        Sampler {
            library,
            machine,
            mem: Memory::new(),
            cache,
            counters: Vec::new(),
            queue: Vec::new(),
            omp_depth: None,
            next_group: 0,
            rng: Xoshiro256::seeded(DEFAULT_RNG_SEED),
            rng_seed: DEFAULT_RNG_SEED,
            modeled_time: false,
            predict_only: false,
        }
    }

    /// Switch this sampler into fully deterministic mode: the operand
    /// RNG is reseeded with `seed`, and every record's `seconds` is the
    /// machine model's cache-aware prediction instead of measured wall
    /// time. Two deterministic samplers fed the same scripts produce
    /// bit-identical records — the reproducibility contract behind the
    /// engine's fixed-seed runs (`elaps run --seed S`).
    pub fn deterministic(mut self, seed: u64) -> Sampler {
        self.rng_seed = seed;
        self.rng = Xoshiro256::seeded(seed);
        self.modeled_time = true;
        self
    }

    /// Switch this sampler into pure prediction mode: deterministic as
    /// [`Sampler::deterministic`], but queued kernels are never
    /// executed — only the operand touches are fed to the cache
    /// simulator and each record reports the machine model's predicted
    /// time. Because kernel execution never reads or advances the
    /// simulated cache, a predictive run's records carry exactly the
    /// timings and counters a seeded *executed* run would report, at
    /// planning cost (`elaps rank`).
    pub fn predictive(mut self, seed: u64) -> Sampler {
        self = self.deterministic(seed);
        self.predict_only = true;
        self
    }

    /// Begin the next script in warm-execution mode. Everything
    /// per-script — memory arena (buffer ids restart, so re-allocated
    /// operands keep their simulated-cache identity), queued calls, omp
    /// grouping, counter selection and the RNG stream — is reset
    /// exactly as a fresh sampler would have it, but the simulated
    /// cache *contents* carry over: operands the previous script left
    /// resident stay resident, modeling back-to-back campaign execution
    /// (the paper's warm-cache experiment state; flushing is still the
    /// script's own `flush_caches` decision).
    pub fn reset_warm(&mut self) {
        self.mem = Memory::new();
        self.queue.clear();
        self.omp_depth = None;
        self.next_group = 0;
        self.counters.clear();
        self.rng = Xoshiro256::seeded(self.rng_seed);
        self.cache.reset_counters();
    }

    /// Direct access to the memory arena (used by tests/examples).
    pub fn memory(&mut self) -> &mut Memory {
        &mut self.mem
    }

    /// Feed one input line; returns the records produced (non-empty
    /// only for `go`).
    pub fn feed_line(&mut self, line: &str) -> Result<Vec<Record>> {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            return Ok(vec![]);
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks[0] {
            "go" => return self.go(),
            "{omp" => {
                if self.omp_depth.is_some() {
                    bail!("nested {{omp groups are not supported");
                }
                self.omp_depth = Some(self.next_group);
                self.next_group += 1;
            }
            "}" => {
                if self.omp_depth.take().is_none() {
                    bail!("'}}' without matching '{{omp'");
                }
            }
            "set_counters" => {
                let avail = self.cache.counter_names();
                for t in &toks[1..] {
                    if !avail.contains(&t.to_string()) {
                        bail!("unknown counter '{t}' (available: {avail:?})");
                    }
                }
                self.counters = toks[1..].iter().map(|s| s.to_string()).collect();
            }
            "set_threads" => {
                let n: usize = toks.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);
                self.library.set_threads(n);
            }
            "flush_caches" => self.cache.flush(),
            "dmalloc" | "smalloc" | "imalloc" => {
                let (name, elems) = two(&toks)?;
                self.mem.malloc(name, elems.parse().map_err(|_| anyhow!("bad size"))?)
                    .map_err(|e| anyhow!(e))?;
            }
            "doffset" | "soffset" => {
                if toks.len() != 4 {
                    bail!("usage: doffset <new> <base> <elems>");
                }
                self.mem
                    .offset(toks[1], toks[2], toks[3].parse().map_err(|_| anyhow!("bad offset"))?)
                    .map_err(|e| anyhow!(e))?;
            }
            "free" => {
                self.mem.free(toks.get(1).copied().unwrap_or("")).map_err(|e| anyhow!(e))?;
            }
            "dmemset" => {
                let (name, v) = two(&toks)?;
                self.mem
                    .memset(name, v.parse().map_err(|_| anyhow!("bad value"))?)
                    .map_err(|e| anyhow!(e))?;
            }
            "dgerand" => {
                let name = toks.get(1).copied().ok_or_else(|| anyhow!("usage: dgerand <name>"))?;
                let elems = toks.get(2).and_then(|s| s.parse().ok());
                self.mem.gerand(name, elems, &mut self.rng).map_err(|e| anyhow!(e))?;
            }
            "dporand" => {
                let (name, n) = two(&toks)?;
                self.mem
                    .porand(name, n.parse().map_err(|_| anyhow!("bad n"))?, &mut self.rng)
                    .map_err(|e| anyhow!(e))?;
            }
            "dtrrand" => {
                if toks.len() != 4 {
                    bail!("usage: dtrrand <name> <n> <L|U>");
                }
                let uplo = crate::linalg::Uplo::from_char(
                    toks[3].chars().next().unwrap_or('L'),
                )
                .ok_or_else(|| anyhow!("bad uplo"))?;
                self.mem
                    .trrand(toks[1], toks[2].parse().map_err(|_| anyhow!("bad n"))?, uplo, &mut self.rng)
                    .map_err(|e| anyhow!(e))?;
            }
            "dwritefile" => {
                let (name, path) = two(&toks)?;
                self.mem.writefile(name, path).map_err(|e| anyhow!(e))?;
            }
            "dreadfile" => {
                let (name, path) = two(&toks)?;
                self.mem.readfile(name, path).map_err(|e| anyhow!(e))?;
            }
            kernel => {
                // a kernel call: parse against its signature and queue
                let av = self.parse_call(kernel, &toks[1..])?;
                self.queue.push(QueuedCall { av, omp_group: self.omp_depth });
            }
        }
        Ok(vec![])
    }

    /// Run a whole multi-line script; returns all records.
    pub fn run_script(&mut self, script: &str) -> Result<Vec<Record>> {
        let mut out = Vec::new();
        for (no, line) in script.lines().enumerate() {
            let recs = self
                .feed_line(line)
                .map_err(|e| anyhow!("line {}: {e}: '{}'", no + 1, line.trim()))?;
            out.extend(recs);
        }
        Ok(out)
    }

    fn parse_call(&self, kernel: &str, toks: &[&str]) -> Result<ArgValues> {
        let sig = crate::kernels::lookup(kernel)
            .ok_or_else(|| anyhow!("unknown kernel '{kernel}'"))?;
        if toks.len() != sig.args.len() {
            bail!(
                "{kernel}: expected {} arguments ({}), got {}",
                sig.args.len(),
                sig.args.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", "),
                toks.len()
            );
        }
        let mut values = Vec::with_capacity(toks.len());
        for ((name, role), t) in sig.args.iter().zip(toks) {
            let v = match role {
                ArgRole::Flag(allowed) => {
                    let c = t.chars().next().unwrap_or('?').to_ascii_uppercase();
                    if !allowed.contains(&c) {
                        bail!("{kernel}: flag '{name}' must be one of {allowed:?}, got '{t}'");
                    }
                    ArgValue::Char(c)
                }
                ArgRole::Dim | ArgRole::Ld | ArgRole::Inc => ArgValue::Size(
                    t.parse().map_err(|_| anyhow!("{kernel}: bad integer '{t}' for '{name}'"))?,
                ),
                ArgRole::Scalar => ArgValue::Num(
                    t.parse().map_err(|_| anyhow!("{kernel}: bad scalar '{t}' for '{name}'"))?,
                ),
                ArgRole::Data(_) => ArgValue::Data(t.to_string()),
            };
            values.push(v);
        }
        Ok(ArgValues { sig, values })
    }

    /// Execute and time everything queued (the `go` command).
    pub fn go(&mut self) -> Result<Vec<Record>> {
        let queue = std::mem::take(&mut self.queue);
        let mut records = Vec::with_capacity(queue.len());
        for call in &queue {
            records.push(self.execute_one(call)?);
        }
        Ok(records)
    }

    fn execute_one(&mut self, call: &QueuedCall) -> Result<Record> {
        let av = &call.av;
        // resolve operands
        self.mem.reset_dynamic();
        // Pre-pass: reserve the call's total dynamic footprint so the
        // pool never reallocates while we hold pointers into it.
        {
            let mut total = 0usize;
            let mut ord = 0;
            for (i, (_, role)) in av.sig.args.iter().enumerate() {
                if let ArgRole::Data(_) = role {
                    if let Some(tok) = av.values[i].as_data() {
                        if let Some(dynspec) = tok.strip_prefix('[') {
                            let inner = dynspec.trim_end_matches(']');
                            let n: usize =
                                inner.parse().unwrap_or(0).max(av.operand_elems(ord));
                            total += n;
                        }
                    }
                    ord += 1;
                }
            }
            self.mem.reserve_dynamic(total);
        }
        let mut raw_ops = Vec::new();
        let mut touches = Vec::new(); // (buf, off, bytes) for the cache sim
        let mut ord = 0;
        for (i, (name, role)) in av.sig.args.iter().enumerate() {
            let _ = name;
            if let ArgRole::Data(dir) = role {
                let token = av.values[i].as_data().unwrap();
                let elems = av.operand_elems(ord);
                let r = if let Some(dynspec) = token.strip_prefix('[') {
                    // dynamic memory: "[n]" or "[]" (size from signature)
                    let inner = dynspec.trim_end_matches(']');
                    let n: usize = if inner.is_empty() {
                        elems
                    } else {
                        inner.parse().map_err(|_| anyhow!("bad dynamic size '{token}'"))?
                    };
                    self.mem.dynamic(n.max(elems))
                } else {
                    self.mem.resolve(token).map_err(|e| anyhow!(e))?
                };
                if r.len < elems {
                    bail!(
                        "{}: operand '{}' has {} elements, needs {}",
                        av.sig.name, token, r.len, elems
                    );
                }
                touches.push((r.buf_id, r.byte_off, elems * 8));
                raw_ops.push(RawOperand { ptr: r.ptr, len: elems, dir: *dir });
                ord += 1;
            }
        }
        let ops = OperandSet::new(raw_ops)?;
        // simulated counters: feed the cache model before timing so the
        // timing loop is undisturbed
        self.cache.reset_counters();
        for (buf, off, bytes) in &touches {
            self.cache.touch(*buf, *off, *bytes, 1);
        }
        let counters: Vec<u64> = self
            .counters
            .iter()
            .map(|c| self.cache.counter(c).unwrap_or(0))
            .collect();
        let level_misses = self.cache.level_misses();
        // execute + time (prediction mode skips execution entirely:
        // the model's inputs — flops and simulated misses — are all
        // gathered above, so the record is identical either way)
        let measured = if self.predict_only {
            0.0
        } else {
            let t0 = Instant::now();
            self.library.execute(av, &ops)?;
            t0.elapsed().as_secs_f64()
        };
        // deterministic mode reports the model's prediction for this
        // call (a pure function of script + simulated cache state); the
        // kernel still executes so numerical state and errors are real
        let seconds = if self.modeled_time {
            self.machine.modeled_seconds(av.flops(), &level_misses)
        } else {
            measured
        };
        Ok(Record {
            kernel: av.sig.name.to_string(),
            seconds,
            cycles: self.machine.cycles(seconds),
            counters,
            omp_group: call.omp_group,
            flops: av.flops(),
        })
    }
}

fn two<'a>(toks: &[&'a str]) -> Result<(&'a str, &'a str)> {
    if toks.len() != 3 {
        bail!("usage: {} <name> <value>", toks.first().unwrap_or(&"cmd"));
    }
    Ok((toks[1], toks[2]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::libraries;

    fn sampler() -> Sampler {
        Sampler::new(
            libraries::by_name("rustblocked").unwrap(),
            MachineModel::sandybridge(),
        )
    }

    #[test]
    fn experiment1_dgemm_metrics_pipeline() {
        // the paper's Experiment 1: one dgemm on random 100³ (scaled)
        let mut s = sampler();
        let recs = s
            .run_script(
                "dmalloc A 10000\ndmalloc B 10000\ndmalloc C 10000\n\
                 dgerand A\ndgerand B\ndgerand C\n\
                 dgemm N N 100 100 100 1.0 A 100 B 100 0.0 C 100\ngo",
            )
            .unwrap();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.kernel, "dgemm");
        assert!(r.seconds > 0.0);
        assert!(r.cycles > 0.0);
        assert_eq!(r.flops, 2e6);
    }

    #[test]
    fn repeated_calls_produce_one_record_each() {
        let mut s = sampler();
        s.run_script("dmalloc A 2500\ndmalloc B 2500\ndmalloc C 2500\ndgerand A\ndgerand B")
            .unwrap();
        let mut script = String::new();
        for _ in 0..10 {
            script.push_str("dgemm N N 50 50 50 1.0 A 50 B 50 0.0 C 50\n");
        }
        script.push_str("go");
        let recs = s.run_script(&script).unwrap();
        assert_eq!(recs.len(), 10);
    }

    #[test]
    fn counters_respond_to_locality() {
        // Experiment 3 shape: varying C (cold) vs fixed C (warm)
        let mut s = sampler();
        s.run_script("set_counters PAPI_L1_TCM").unwrap();
        s.run_script("dmalloc A 400\ndmalloc B 400\ndmalloc C 400\ndgerand A\ndgerand B")
            .unwrap();
        // first call: everything cold
        let r1 = s
            .run_script("dgemm N N 20 20 20 1.0 A 20 B 20 0.0 C 20\ngo")
            .unwrap();
        // second call same operands: warm
        let r2 = s
            .run_script("dgemm N N 20 20 20 1.0 A 20 B 20 0.0 C 20\ngo")
            .unwrap();
        assert!(r1[0].counters[0] > 0);
        assert_eq!(r2[0].counters[0], 0, "warm rerun should hit L1");
        // flush ⇒ cold again
        s.run_script("flush_caches").unwrap();
        let r3 = s
            .run_script("dgemm N N 20 20 20 1.0 A 20 B 20 0.0 C 20\ngo")
            .unwrap();
        assert!(r3[0].counters[0] > 0);
    }

    #[test]
    fn deterministic_mode_is_bit_reproducible() {
        let script = "set_counters PAPI_L1_TCM\n\
                      dmalloc A 400\ndmalloc B 400\ndmalloc C 400\n\
                      dgerand A\ndgerand B\n\
                      dgemm N N 20 20 20 1.0 A 20 B 20 0.0 C 20\ngo";
        let run = || {
            let mut s = Sampler::new(
                libraries::by_name("rustblocked").unwrap(),
                MachineModel::sandybridge(),
            )
            .deterministic(7);
            s.run_script(script).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].seconds.to_bits(), b[0].seconds.to_bits());
        assert_eq!(a[0].cycles.to_bits(), b[0].cycles.to_bits());
        assert_eq!(a[0].counters, b[0].counters);
        assert!(a[0].seconds > 0.0);
    }

    #[test]
    fn reset_warm_carries_cache_state_but_nothing_else() {
        let script = "set_counters PAPI_L1_TCM\n\
                      dmalloc A 400\ndmalloc B 400\ndmalloc C 400\n\
                      dgerand A\ndgerand B\n\
                      dgemm N N 20 20 20 1.0 A 20 B 20 0.0 C 20\ngo";
        let mut s = sampler();
        let cold = s.run_script(script).unwrap();
        assert!(cold[0].counters[0] > 0, "first script must run cold");
        // warm reset: the memory arena restarts (same names re-malloc
        // cleanly, same buffer ids), but A/B/C stay simulated-resident
        s.reset_warm();
        let warm = s.run_script(script).unwrap();
        assert_eq!(warm[0].counters[0], 0, "carried state must hit");
        // a reset sampler numbers {omp groups from 0 again, and its
        // counter selection is back to empty (per-script state)
        s.reset_warm();
        let recs = s
            .run_script(
                "dmalloc T 100\ndmalloc x 10\ndtrrand T 10 L\n\
                 {omp\ndtrsv L N N 10 T 10 x 1\n}\ngo",
            )
            .unwrap();
        assert_eq!(recs[0].omp_group, Some(0));
        assert!(recs[0].counters.is_empty(), "set_counters must not carry over");
    }

    #[test]
    fn omp_groups_are_tagged() {
        let mut s = sampler();
        s.run_script("dmalloc A 100\ndmalloc x1 10\ndmalloc x2 10\ndtrrand A 10 L")
            .unwrap();
        let recs = s
            .run_script(
                "{omp\ndtrsv L N N 10 A 10 x1 1\ndtrsv L N N 10 A 10 x2 1\n}\ngo",
            )
            .unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].omp_group, recs[1].omp_group);
        assert!(recs[0].omp_group.is_some());
        // separate groups get separate ids
        let recs2 = s
            .run_script("{omp\ndtrsv L N N 10 A 10 x1 1\n}\ngo")
            .unwrap();
        assert_ne!(recs2[0].omp_group, recs[0].omp_group);
    }

    #[test]
    fn dynamic_memory_operands() {
        let mut s = sampler();
        let recs = s
            .run_script("dgemm N N 30 30 30 1.0 [] 30 [] 30 0.0 [] 30\ngo")
            .unwrap();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn undersized_operand_rejected() {
        let mut s = sampler();
        s.run_script("dmalloc A 10\ndmalloc B 900\ndmalloc C 900").unwrap();
        let err = s
            .run_script("dgemm N N 30 30 30 1.0 A 30 B 30 0.0 C 30\ngo")
            .unwrap_err();
        assert!(err.to_string().contains("needs"), "{err}");
    }

    #[test]
    fn bad_flag_rejected() {
        let mut s = sampler();
        let err = s.feed_line("dgemm X N 8 8 8 1.0 [] 8 [] 8 0.0 [] 8").unwrap_err();
        assert!(err.to_string().contains("transa"), "{err}");
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut s = sampler();
        let err = s.feed_line("zgemm N N 8 8 8 1.0 [] 8 [] 8 0.0 [] 8").unwrap_err();
        assert!(err.to_string().contains("unknown kernel"));
    }

    #[test]
    fn record_line_format() {
        let r = Record {
            kernel: "dgemm".into(),
            seconds: 0.1,
            cycles: 2.6e8,
            counters: vec![42],
            omp_group: Some(3),
            flops: 2e9,
        };
        assert_eq!(r.to_line(), "dgemm 260000000 42 #omp3");
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let mut s = sampler();
        let recs = s.run_script("# a comment\n\n   \n").unwrap();
        assert!(recs.is_empty());
    }

    #[test]
    fn set_counters_validates() {
        let mut s = sampler();
        assert!(s.feed_line("set_counters PAPI_L1_TCM PAPI_BR_MSP").is_ok());
        assert!(s.feed_line("set_counters PAPI_NOPE").is_err());
    }
}
