//! The `elaps` CLI — the framework's top layer (substituting the
//! paper's PlayMat/Viewer GUI on this headless host; DESIGN.md
//! §Substitutions 6).
//!
//! Subcommands:
//!   run <exp.json>        run an experiment file (local or --batch)
//!   batch <exp.json>…     run a campaign of experiments via the engine
//!   submit <file>…        enqueue experiments/campaigns, print job ids
//!   wait [ids…]           block until jobs (or a campaign) publish
//!   fetch [ids…]          copy published reports to local files
//!   view <report.json>    metrics/statistics of a stored report
//!   plot <report.json>    ASCII + SVG plot of a stored report
//!   figures [ids…]        regenerate the paper's tables/figures
//!   cache stats|gc|clear  result-cache lifecycle (sizes, LRU eviction)
//!   calibrate             fit a machine profile from a seeded sweep
//!   rank <exp.json>       model-predict and rank a grid, no execution
//!   sampler               stdin/stdout sampler (the paper's §3.1 tool)
//!   worker --spool <dir>  lease-based batch-queue worker daemon
//!   retry                 resubmit a campaign's error jobs exactly once
//!   spool status          queued/leased/done per host for a spool dir
//!   spool dead-letter     list a campaign's dead-lettered jobs
//!   spool compact         fold a campaign ledger into its index snapshot
//!   analyze               latency/throughput/cache/audit over a spool's
//!                         job-lifecycle event log
//!   kernels               list the kernel signature database
//!   libraries             list kernel libraries (built-ins + registered
//!                         extras such as the xla backends)
//!   compare <op>          cross-library differential report over a
//!                         shared grid (winners, crossovers, ranking)
//!
//! `--jobs N` fans experiment points out over N engine worker threads;
//! `--cache DIR` enables the content-addressed result cache, so re-runs
//! and overlapping sweeps skip already-measured points; `--trusted-only`
//! serves hits only from entries measured without contention (jobs ≤ 1).

use anyhow::{anyhow, bail, Context, Result};
use elaps::coordinator::{campaign, io, ledger, Metric, Spooler, Stat};
use elaps::engine::{Engine, EngineConfig};
use elaps::perfmodel::resolve_machine;
use elaps::sampler::Sampler;
use elaps::util::cli::Args;
use elaps::util::json::Json;
use std::io::{BufRead, Write};

const USAGE: &str = "\
elaps — Experimental Linear Algebra Performance Studies (rust+JAX/Pallas)

USAGE:
  elaps run <experiment.json> [--jobs N] [--cache DIR] [--out report.json]
            [--warm] [--seed S] [--batch --spool DIR]
  elaps batch <exp.json>… [--jobs N] [--cache DIR] [--out-dir batch_out]
  elaps submit <exp-or-manifest.json>… [--campaign TAG] [--spool DIR]
  elaps wait [JOB_ID…] [--campaign TAG] [--timeout DUR] [--spool DIR]
             [--no-ledger]
  elaps fetch [JOB_ID…] [--campaign TAG] [--out-dir fetched] [--spool DIR]
             [--no-ledger]
  elaps retry --campaign TAG [--max-attempts N] [--spool DIR]
  elaps view <report.json> [--metric M] [--stat S]
  elaps plot <report.json> [--metric M] [--stat S] [--svg out.svg]
  elaps figures [T1 F1 … W1 S1 … S4|all|scenarios] [--full] [--jobs N]
                [--cache DIR] [--out-dir figures_out] [--seed S]
  elaps cache stats [--cache DIR]
  elaps cache gc [--max-bytes N[K|M|G]] [--max-age DUR] [--cache DIR]
  elaps cache clear [--cache DIR]
  elaps calibrate [--library L] [--machine M] [--out PROFILE.json]
                  [--quick] [--json] [--seed S] [--jobs N] [--cache DIR]
  elaps rank <experiment.json> [--machine M] [--seed S] [--json]
  elaps compare <dgemm|dtrsyl|dpotrf|dgetrf> [--libraries a,b,…]
                [--range lo:step:hi] [--metric M] [--stat S] [--nreps N]
                [--machine M] [--predicted] [--seed S] [--json]
                [--svg out.svg] [--jobs N] [--cache DIR]
  elaps sampler [--library L] [--machine M]
  elaps worker --spool DIR [--once] [--workers N] [--lease-ttl DUR]
               [--max-leases N] [--recover SECS|0=off] [--verbose]
  elaps spool status [--spool DIR] [--json] [--no-ledger]
  elaps spool dead-letter --campaign TAG [--spool DIR] [--json]
  elaps spool compact --campaign TAG [--archive] [--spool DIR]
  elaps analyze [--campaign TAG] [--spool DIR] [--json]
  elaps bench [SUITE…] [--quick] [--out DIR]
  elaps kernels
  elaps libraries   lists built-ins and registered extra backends (e.g.
                    xla/xla-pallas once AOT artifacts are found) — the
                    default backend set of `elaps compare`

metrics: cycles time_s time_ms gflops flops_per_cycle efficiency
         counter0 counter1 … (one per experiment counter)
stats:   min max avg med std

--machine M    machine spec: a preset (sandybridge ivybridge bluegene
               haswell xeonphi localhost) or profile:PATH for a fitted
               profile from `elaps calibrate`. `localhost` automatically
               prefers $ELAPS_MACHINE_PROFILE, then
               ./.elaps-machine-profile.json, then the built-in constants.
               calibrate itself takes a preset name (profiles refine
               presets) and writes the default path unless --out/--json
               say otherwise

--jobs N       engine worker threads (default 1; env ELAPS_JOBS). Note:
               parallel kernels contend for the CPU, so measure final
               timings (and fill shared caches) with --jobs 1.
--cache DIR    content-addressed result cache (env ELAPS_CACHE)
--trusted-only serve cache hits only from entries measured with jobs <= 1
               (publication-quality timings; env ELAPS_TRUSTED_ONLY=1).
               Seeded (--seed) entries are modeled, hence pure functions
               of the script: they are served whatever pool width stored
               them
--warm         warm execution: each worker reuses one sampler across its
               points, carrying simulated cache state (back-to-back
               campaign semantics); scheduling becomes deterministic
               contiguous-block sharding by worker index (env ELAPS_WARM=1)
--seed S       fully deterministic run: seeded operand data + modeled
               (machine-model) timings; two runs with the same seed,
               --warm and --jobs are byte-identical (env ELAPS_SEED)
--predicted    compare: rank the libraries from the machine model alone
               (one predictive sampler per point, no kernel executed) —
               bit-identical to what the same --seed would measure, so
               diffing it against a measured run validates the model
--libraries    compare: comma-separated backend list (default: every
               resolvable library, built-ins first)
--range        compare: the shared n grid as lo:step:hi (inclusive;
               lo:hi and a single value also work)
--max-bytes N  cache gc byte budget; K/M/G suffixes are powers of 1024
--max-age DUR  cache gc age cutoff by store time: N[s|m|h|d], e.g. 7d
--campaign TAG address jobs as a named campaign: submit appends the
               jobs to the campaign ledger <spool>/ledger/<TAG>.log
               (with --no-ledger: records ids under
               <spool>/campaigns/<TAG>.json); wait and fetch then take
               the tag instead of individual job ids. A manifest file
               {\"campaign\": TAG, \"experiments\": [...]} submits a
               whole campaign in one call (entries are paths resolved
               relative to the manifest, or inline experiments)
--no-ledger    submit: record the campaign in the flock-merged record
               file instead of the ledger; wait/fetch/spool status:
               answer from directory scans instead of the ledger index.
               Both paths yield identical results — the ledger is the
               O(changed-since-snapshot) fast path, not a different
               answer
--max-attempts retry: per-chain attempt budget, counting the original
               submission (default 3). An error job already at the
               budget is dead-lettered instead of resubmitted
--archive      spool compact: additionally move a fully folded ledger
               to <spool>/ledger/archive/<TAG>.log (refused, not an
               error, while unread appends remain)
--timeout DUR  wait deadline, N[s|m|h|d] (default 10m). Waiting is
               O(#jobs) per poll: report existence + stamp sidecars
               (a report body is read only as the outcome fallback for
               a done job whose stamp is missing)
--workers N    worker daemon threads draining one spool (default 1)
--max-leases N per-host lease backpressure: this host never holds more
               than N live leases at once; claims beyond that wait for
               a publish or an expiry (default: unlimited)
--lease-ttl D  job-lease TTL, N[s|m|h|d] (default 300s; env
               ELAPS_LEASE_TTL). Leases are heartbeat-renewed while a
               job runs; an expired lease is reclaimed by any worker,
               and the late publish of the old holder is fenced off by
               the lease epoch. SIGTERM drains gracefully: in-flight
               jobs finish and publish, no new jobs are claimed.
--recover SECS reclaim age for legacy (pre-lease) claims; 0 disables
               the mtime heuristic (leased claims are unaffected)
--no-events    disable job-lifecycle event logging to <spool>/events/
               (env ELAPS_EVENTS=0). Events are on by default, appended
               crash-safely per host, and never fail a job.
--verbose      worker: also mirror fenced-publish warnings to stderr
               (the structured `fenced` event is always recorded)
--json         machine-readable output (analyze, spool status)
--quick        bench: ~10x smaller workloads (CI smoke); metric names
               are unchanged, so quick and full BENCH files still diff
--out DIR      bench: directory for the BENCH_<suite>.json snapshots
               (default: current directory). Suites: cache spool obs
               sampler (default: all)
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn try_register_xla() {
    let dir = elaps::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        if let Err(e) = elaps::runtime::register_xla_library(&dir) {
            eprintln!("note: xla backend unavailable: {e:#}");
        }
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let Some(cmd) = raw.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(
        raw[1..].iter().cloned(),
        &[
            "batch",
            "once",
            "full",
            "help",
            "trusted-only",
            "warm",
            "no-events",
            "no-ledger",
            "archive",
            "verbose",
            "json",
            "quick",
            "predicted",
        ],
    );
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "batch" => cmd_batch(&args),
        "submit" => cmd_submit(&args),
        "wait" => cmd_wait(&args),
        "fetch" => cmd_fetch(&args),
        "view" => cmd_view(&args),
        "plot" => cmd_plot(&args),
        "figures" => cmd_figures(&args),
        "cache" => cmd_cache(&args),
        "calibrate" => cmd_calibrate(&args),
        "rank" => cmd_rank(&args),
        "compare" => cmd_compare(&args),
        "sampler" => cmd_sampler(&args),
        "worker" => cmd_worker(&args),
        "retry" => cmd_retry(&args),
        "spool" => cmd_spool(&args),
        "analyze" => cmd_analyze(&args),
        "bench" => cmd_bench(&args),
        "kernels" => cmd_kernels(),
        "libraries" => cmd_libraries(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_experiment(path: &str) -> Result<elaps::Experiment> {
    io::load_experiment_file(path)
}

/// Engine configuration from `--jobs` / `--cache`, layered over the
/// `ELAPS_JOBS` / `ELAPS_CACHE` environment defaults, with validation.
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::from_env();
    if let Some(jobs) = args.opt_usize_strict("jobs").map_err(|e| anyhow!(e))? {
        if jobs == 0 {
            bail!("--jobs must be ≥ 1");
        }
        cfg.jobs = jobs;
    }
    if let Some(dir) = args.opt("cache") {
        if dir.is_empty() {
            bail!("--cache requires a directory");
        }
        cfg.cache_dir = Some(dir.into());
    } else if args.flag("cache") {
        bail!("--cache requires a directory");
    }
    if args.flag("trusted-only") {
        cfg.trusted_only = true;
    }
    if args.flag("warm") {
        cfg.warm = true;
    }
    if let Some(seed) = args.opt_usize_strict("seed").map_err(|e| anyhow!(e))? {
        cfg.seed = Some(seed as u64);
    }
    Ok(cfg)
}

/// The `elaps cache {stats,gc,clear}` lifecycle subcommands, operating
/// on the cache directory from `--cache` / `ELAPS_CACHE`.
fn cmd_cache(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("usage: elaps cache <stats|gc|clear> [--cache DIR]"))?;
    let cfg = engine_config(args)?;
    let dir = cfg
        .cache_dir
        .ok_or_else(|| anyhow!("no cache directory: pass --cache DIR or set ELAPS_CACHE"))?;
    match sub {
        "stats" => {
            let st = elaps::engine::gc::cache_stats(&dir)?;
            println!("cache at {}:", dir.display());
            print!("{}", st.render());
        }
        "gc" => {
            let budget = args
                .opt("max-bytes")
                .map(|v| {
                    elaps::util::cli::parse_byte_size(v).map_err(|e| anyhow!("--max-bytes: {e}"))
                })
                .transpose()?;
            let max_age = args.opt_duration_strict("max-age").map_err(|e| anyhow!(e))?;
            if budget.is_none() && max_age.is_none() {
                bail!(
                    "cache gc requires --max-bytes N (K/M/G suffixes allowed) \
                     and/or --max-age DUR (s/m/h/d suffixes allowed)"
                );
            }
            // expire by age first, then enforce the byte budget on the
            // survivors
            if let Some(age) = max_age {
                let out = elaps::engine::gc::gc_max_age(&dir, age)?;
                println!(
                    "gc: deleted {}/{} entries older than {}s — {} → {} bytes; \
                     {} stale tmp file(s) removed",
                    out.deleted,
                    out.scanned,
                    age.as_secs(),
                    out.bytes_before,
                    out.bytes_after,
                    out.tmp_removed
                );
            }
            if let Some(budget) = budget {
                let out = elaps::engine::gc::gc_max_bytes(&dir, budget)?;
                println!(
                    "gc: deleted {}/{} entries — {} → {} bytes (budget {budget}); \
                     {} stale tmp file(s) removed",
                    out.deleted, out.scanned, out.bytes_before, out.bytes_after, out.tmp_removed
                );
            }
        }
        "clear" => {
            let removed = elaps::engine::gc::clear_cache(&dir)?;
            println!("cleared {removed} entries from {}", dir.display());
        }
        other => bail!("unknown cache subcommand '{other}' (expected stats, gc or clear)"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.positional.first().ok_or_else(|| anyhow!("usage: elaps run <exp.json>"))?;
    try_register_xla();
    let cfg = engine_config(args)?;
    // spooler workers and any nested run_local share the same pool/cache
    elaps::engine::set_default_config(cfg.clone());
    let exp = load_experiment(path)?;
    let report = if args.flag("batch") {
        let mut spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
        if args.flag("no-events") {
            spool = spool.with_events(false);
        }
        let id = spool.submit(&exp)?;
        println!("submitted job {id}; serving in-process worker…");
        println!("note: engine cache statistics are not reported on the spooled path");
        spool.serve_one()?;
        spool.fetch(&id)?.ok_or_else(|| anyhow!("job produced no report"))?
    } else {
        let (report, stats) = Engine::new(cfg).run_stats(&exp)?;
        println!("{}", stats.summary_line());
        report
    };
    print_report_summary(&report)?;
    let out = args.opt_or("out", "report.json");
    std::fs::write(out, io::report_to_json(&report).to_string_pretty())?;
    println!("report written to {out}");
    Ok(())
}

/// Batch submission: run a whole campaign of experiment files through
/// one engine scheduler and write one report per experiment.
fn cmd_batch(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("usage: elaps batch <exp.json>… [--jobs N] [--cache DIR] [--out-dir DIR]");
    }
    try_register_xla();
    let cfg = engine_config(args)?;
    elaps::engine::set_default_config(cfg.clone());
    let exps: Vec<elaps::Experiment> = args
        .positional
        .iter()
        .map(|p| load_experiment(p))
        .collect::<Result<_>>()?;
    let t0 = std::time::Instant::now();
    let (reports, stats) = Engine::new(cfg).run_batch_stats(&exps)?;
    let out_dir = std::path::PathBuf::from(args.opt_or("out-dir", "batch_out"));
    std::fs::create_dir_all(&out_dir)?;
    let mut used_names = std::collections::HashSet::new();
    for report in &reports {
        print_report_summary(report)?;
        // disambiguate duplicate experiment names instead of silently
        // overwriting an earlier report
        let base = report.experiment.name.replace(['/', ' '], "_");
        let mut name = base.clone();
        let mut k = 2;
        while !used_names.insert(name.clone()) {
            name = format!("{base}-{k}");
            k += 1;
        }
        let out = out_dir.join(format!("{name}.report.json"));
        std::fs::write(&out, io::report_to_json(report).to_string_pretty())?;
        println!("report written to {}", out.display());
    }
    println!("{} ({:.1}s)", stats.summary_line(), t0.elapsed().as_secs_f64());
    Ok(())
}

/// `elaps submit`: the asynchronous client's enqueue step — drop
/// experiments (or whole campaign manifests) into the spool and print
/// the job ids, one per line on stdout, without blocking on any
/// worker. A manifest submits under its own campaign tag; `--campaign`
/// overrides it (and tags loose experiment files).
fn cmd_submit(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("usage: elaps submit <exp-or-manifest.json>… [--campaign TAG] [--spool DIR]");
    }
    if args.flag("campaign") {
        bail!("--campaign requires a tag");
    }
    let mut spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    if args.flag("no-events") {
        spool = spool.with_events(false);
    }
    let override_tag = args.opt("campaign");
    let mut total = 0usize;
    for path in &args.positional {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let (tag, exps) = if campaign::CampaignManifest::is_manifest(&j) {
            let m = campaign::CampaignManifest::from_json(&j)
                .with_context(|| path.clone())?;
            let base = std::path::Path::new(path)
                .parent()
                .filter(|p| !p.as_os_str().is_empty())
                .unwrap_or_else(|| std::path::Path::new("."))
                .to_path_buf();
            let exps = m.resolve(&base)?;
            (Some(override_tag.unwrap_or(&m.campaign).to_string()), exps)
        } else {
            let exp = io::experiment_from_json(&j).with_context(|| path.clone())?;
            (override_tag.map(String::from), vec![exp])
        };
        // campaigns default to the ledger (append-only canonical
        // store); --no-ledger keeps the flock-merged record file
        let ids = match &tag {
            Some(t) if !args.flag("no-ledger") => {
                ledger::submit_experiments(&spool, t, &exps)?
            }
            _ => campaign::submit_experiments(&spool, tag.as_deref(), &exps)?,
        };
        for id in &ids {
            println!("{id}");
        }
        match &tag {
            Some(tag) => eprintln!(
                "submitted {} job(s) from {path} to campaign '{tag}'",
                ids.len()
            ),
            None => eprintln!("submitted {} job(s) from {path}", ids.len()),
        }
        total += ids.len();
    }
    eprintln!(
        "{total} job(s) queued in {0}; drain with: elaps worker --spool {0}",
        spool.dir.display()
    );
    Ok(())
}

/// Job ids addressed by a `wait`/`fetch` invocation: the explicit
/// positional ids plus every job recorded under `--campaign TAG`.
fn jobs_from_args(args: &Args, spool: &std::path::Path) -> Result<Vec<String>> {
    if args.flag("campaign") {
        bail!("--campaign requires a tag");
    }
    let mut seen = std::collections::HashSet::new();
    let mut ids: Vec<String> = Vec::new();
    for id in &args.positional {
        if seen.insert(id.clone()) {
            ids.push(id.clone());
        }
    }
    if let Some(tag) = args.opt("campaign") {
        for id in ledger::campaign_jobs_resolved(spool, tag, !args.flag("no-ledger"))? {
            if seen.insert(id.clone()) {
                ids.push(id);
            }
        }
    }
    if ids.is_empty() {
        bail!("nothing to address: pass job ids or --campaign TAG");
    }
    Ok(ids)
}

/// Print one finished job's outcome line — the shared format of the
/// stamp path and the ledger path, byte-identical between them — and
/// bucket the result. A job whose outcome is unknown (no stamp, or a
/// ledger entry folded without one) falls back to probing its report
/// body, so an error report still fails the wait either way.
fn print_outcome_line(
    dir: &std::path::Path,
    id: &str,
    known: Option<(elaps::coordinator::StampOutcome, &str, &str, u64)>,
    ok: &mut usize,
    errors: &mut usize,
    unknown: &mut usize,
) {
    use elaps::coordinator::StampOutcome;
    match known {
        Some((outcome, host, worker, epoch)) => {
            println!(
                "{id}  {} (host {host}, worker {worker}, epoch {epoch})",
                outcome.as_str()
            );
            match outcome {
                StampOutcome::Ok => *ok += 1,
                StampOutcome::Error => *errors += 1,
            }
        }
        None => {
            let body_error = std::fs::read_to_string(
                dir.join("done").join(format!("{id}.report.json")),
            )
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .map(|j| !j.get("error").is_null());
            match body_error {
                Some(true) => {
                    println!("{id}  error (no stamp; outcome from report body)");
                    *errors += 1;
                }
                Some(false) => {
                    println!("{id}  ok (no stamp; outcome from report body)");
                    *ok += 1;
                }
                None => {
                    println!("{id}  done (no stamp, unreadable report: outcome unknown)");
                    *unknown += 1;
                }
            }
        }
    }
}

/// `elaps wait`: block until every addressed job has published,
/// polling with jittered backoff. The file-backed path is O(#jobs) per
/// poll (report existence checks and stamp sidecars only, never a
/// report body); a ledger-backed campaign polls only the jobs its
/// index has not yet seen done — O(changed-since-snapshot).
fn cmd_wait(args: &Args) -> Result<()> {
    let spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    if let Some(tag) = args.opt("campaign") {
        if args.positional.is_empty()
            && !args.flag("no-ledger")
            && ledger::has_ledger(&spool.dir, tag)
        {
            return cmd_wait_ledger(args, &spool, tag);
        }
    }
    let ids = jobs_from_args(args, &spool.dir)?;
    let timeout = args
        .opt_duration_strict("timeout")
        .map_err(|e| anyhow!(e))?
        .unwrap_or(std::time::Duration::from_secs(600));
    if let Err(e) = spool.wait_many(&ids, timeout) {
        let st = campaign::status_of_jobs(&spool.dir, &ids);
        eprint!("{}", st.render(args.opt_or("campaign", "(ad-hoc)")));
        return Err(e);
    }
    // one stamp read per job: the outcome lines and the campaign
    // summary are derived from the same pass (every job is done at
    // this point, so the summary needs no further probing)
    let (mut ok, mut errors, mut unknown) = (0usize, 0usize, 0usize);
    for id in &ids {
        let stamp = campaign::read_stamp(&spool.dir, id);
        let known = stamp
            .as_ref()
            .map(|s| (s.outcome, s.host.as_str(), s.worker.as_str(), s.epoch));
        print_outcome_line(&spool.dir, id, known, &mut ok, &mut errors, &mut unknown);
    }
    if let Some(tag) = args.opt("campaign") {
        let st = elaps::coordinator::CampaignStatus {
            total: ids.len(),
            done_ok: ok,
            done_error: errors,
            done_unknown: unknown,
            ..Default::default()
        };
        print!("{}", st.render(tag));
    }
    if errors > 0 {
        bail!("{errors} of {} job(s) published error reports", ids.len());
    }
    Ok(())
}

/// The ledger-backed arm of [`cmd_wait`]: jobs and outcomes come from
/// the campaign index, so only the still-pending jobs are polled and
/// the final summary costs zero per-job I/O for everything the
/// snapshot already saw done. Output is byte-identical to the
/// file-backed arm — same outcome lines, same summary.
fn cmd_wait_ledger(args: &Args, spool: &Spooler, tag: &str) -> Result<()> {
    let timeout = args
        .opt_duration_strict("timeout")
        .map_err(|e| anyhow!(e))?
        .unwrap_or(std::time::Duration::from_secs(600));
    let mut idx = ledger::CampaignIndex::load(&spool.dir, tag)?;
    idx.refresh(&spool.dir)?;
    if idx.job_ids().is_empty() {
        bail!("nothing to address: pass job ids or --campaign TAG");
    }
    let pending = idx.pending_ids();
    if let Err(e) = spool.wait_many(&pending, timeout) {
        idx.refresh(&spool.dir)?;
        let _ = idx.save(&spool.dir);
        eprint!("{}", idx.status(&spool.dir).render(tag));
        return Err(e);
    }
    idx.refresh(&spool.dir)?;
    idx.save(&spool.dir)?;
    let ids = idx.job_ids();
    let (mut ok, mut errors, mut unknown) = (0usize, 0usize, 0usize);
    for id in &ids {
        let known = idx.jobs.get(id).and_then(|e| {
            e.outcome
                .map(|o| (o, e.host.as_str(), e.worker.as_str(), e.epoch))
        });
        print_outcome_line(&spool.dir, id, known, &mut ok, &mut errors, &mut unknown);
    }
    let st = elaps::coordinator::CampaignStatus {
        total: ids.len(),
        done_ok: ok,
        done_error: errors,
        done_unknown: unknown,
        ..Default::default()
    };
    print!("{}", st.render(tag));
    if errors > 0 {
        bail!("{errors} of {} job(s) published error reports", ids.len());
    }
    Ok(())
}

/// `elaps retry`: resubmit every error-stamped job of a ledger-backed
/// campaign exactly once (durably — a `retried` ledger fact marks the
/// failure as replaced, so a second invocation is a no-op), printing
/// the new job ids on stdout like `elaps submit`. Failures whose retry
/// chain is at the attempt budget are dead-lettered instead.
fn cmd_retry(args: &Args) -> Result<()> {
    if args.flag("campaign") {
        bail!("--campaign requires a tag");
    }
    let Some(tag) = args.opt("campaign") else {
        bail!("usage: elaps retry --campaign TAG [--max-attempts N] [--spool DIR]");
    };
    let mut spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    if args.flag("no-events") {
        spool = spool.with_events(false);
    }
    let max_attempts = match args.opt_usize_strict("max-attempts").map_err(|e| anyhow!(e))? {
        Some(0) => bail!("--max-attempts must be ≥ 1"),
        Some(n) => n as u64,
        None => ledger::DEFAULT_MAX_ATTEMPTS,
    };
    let out = ledger::retry_errors(&spool, tag, max_attempts)?;
    for (old, new) in &out.resubmitted {
        println!("{new}");
        eprintln!("retrying {old} as {new}");
    }
    for id in &out.dead_lettered {
        eprintln!("dead-lettered {id} (attempt budget {max_attempts} exhausted)");
    }
    for id in &out.unrecoverable {
        eprintln!("cannot retry {id}: no experiment recorded in the ledger");
    }
    eprintln!(
        "campaign '{tag}': {} resubmitted, {} dead-lettered, {} unrecoverable",
        out.resubmitted.len(),
        out.dead_lettered.len(),
        out.unrecoverable.len()
    );
    Ok(())
}

/// `elaps fetch`: copy the published reports of the addressed jobs to
/// local files, byte-for-byte (each report keeps its `served_by`
/// provenance stamp). Prints the fetched paths, one per line.
fn cmd_fetch(args: &Args) -> Result<()> {
    let spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    let ids = jobs_from_args(args, &spool.dir)?;
    let out_dir = std::path::PathBuf::from(args.opt_or("out-dir", "fetched"));
    let files = campaign::fetch_jobs(&spool, &ids, &out_dir)?;
    for f in &files {
        println!("{}", f.display());
    }
    eprintln!("fetched {} report(s) to {}", files.len(), out_dir.display());
    Ok(())
}

fn parse_metric(name: &str) -> Result<Metric> {
    Ok(match name {
        "cycles" => Metric::Cycles,
        "time_s" => Metric::TimeS,
        "time_ms" => Metric::TimeMs,
        "gflops" => Metric::Gflops,
        "flops_per_cycle" => Metric::FlopsPerCycle,
        "efficiency" => Metric::Efficiency,
        other => {
            if let Some(i) = other.strip_prefix("counter") {
                // a malformed index must not silently alias counter 0
                let idx: usize = i.parse().map_err(|_| {
                    anyhow!(
                        "unknown metric '{other}' (counter metrics are \
                         counter0, counter1, … — one per experiment counter)"
                    )
                })?;
                Metric::Counter(idx)
            } else {
                bail!("unknown metric '{other}'")
            }
        }
    })
}

fn load_report(args: &Args) -> Result<elaps::Report> {
    let path = args.positional.first().ok_or_else(|| anyhow!("need a report file"))?;
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    io::report_from_json(&j)
}

fn print_report_summary(report: &elaps::Report) -> Result<()> {
    println!(
        "experiment '{}' on library '{}' ({} point(s), {} rep(s))",
        report.experiment.name,
        report.experiment.library,
        report.points.len(),
        report.experiment.nreps
    );
    if report.points.len() == 1 {
        for (name, v) in report.metrics_table()? {
            println!("  {name:<18} {v:>16.4}");
        }
    } else {
        println!("  {:>8} {:>14} {:>14}", "range", "Gflops/s(med)", "time[s](med)");
        let g = report.series(Metric::Gflops, Stat::Median);
        let t = report.series(Metric::TimeS, Stat::Median);
        for (i, (x, gf)) in g.iter().enumerate() {
            println!("  {x:>8} {gf:>14.4} {:>14.6}", t[i].1);
        }
    }
    Ok(())
}

fn cmd_view(args: &Args) -> Result<()> {
    let report = load_report(args)?;
    let metric = parse_metric(args.opt_or("metric", "gflops"))?;
    let stat = Stat::by_name(args.opt_or("stat", "med"))
        .ok_or_else(|| anyhow!("unknown stat"))?;
    print_report_summary(&report)?;
    println!("\n{} ({}):", metric.name(), stat.name());
    for (x, v) in report.series(metric, stat) {
        println!("  {x:>8} {v:>16.4}");
    }
    Ok(())
}

fn cmd_plot(args: &Args) -> Result<()> {
    let report = load_report(args)?;
    let metric = parse_metric(args.opt_or("metric", "gflops"))?;
    let stat = Stat::by_name(args.opt_or("stat", "med"))
        .ok_or_else(|| anyhow!("unknown stat"))?;
    let mut fig = elaps::coordinator::Figure::new(
        &report.experiment.name,
        report
            .experiment
            .range
            .as_ref()
            .map(|r| r.sym.as_str())
            .unwrap_or("point"),
        &metric.name(),
    );
    fig.add_iseries(
        &format!("{} ({})", report.experiment.library, stat.name()),
        &report.series(metric, stat),
    );
    println!("{}", fig.to_ascii(70, 20));
    if let Some(svg) = args.opt("svg") {
        std::fs::write(svg, fig.to_svg(720, 440))?;
        println!("svg written to {svg}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    try_register_xla();
    // figure builders execute through the process-default engine
    // config; route them through the requested pool/cache
    elaps::engine::set_default_config(engine_config(args)?);
    let quick = !args.flag("full");
    let out_dir = std::path::PathBuf::from(args.opt_or("out-dir", "figures_out"));
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|p| p == "all")
    {
        elaps::figures::builder_registry().iter().map(|(id, _)| id.to_string()).collect()
    } else {
        // "scenarios" expands to the S* pack (CI regression fixtures)
        args.positional
            .iter()
            .flat_map(|p| {
                if p == "scenarios" {
                    elaps::figures::scenarios::scenario_builders()
                        .iter()
                        .map(|(id, _)| id.to_string())
                        .collect()
                } else {
                    vec![p.clone()]
                }
            })
            .collect()
    };
    // every builder's experiments go through ONE engine batch, so
    // campaign-level sharding and the cache probe cover them all
    println!("--- running {} figure(s) as one campaign (quick={quick}) ---", ids.len());
    let t0 = std::time::Instant::now();
    let outcome = elaps::figures::run_figures_campaign(&ids, quick)?;
    // write every completed figure before reporting any failure, so a
    // late builder error cannot discard hours of finished output
    for out in &outcome.outputs {
        out.write_to(&out_dir)?;
        println!(
            "{}: {} rows → {}/{}.{{csv,svg,txt}}",
            out.id,
            out.rows.len(),
            out_dir.display(),
            out.id
        );
        println!("    {}", out.notes.replace('\n', "\n    "));
    }
    println!("{} ({:.1}s)", outcome.stats.summary_line(), t0.elapsed().as_secs_f64());
    if !outcome.failures.is_empty() {
        for (id, e) in &outcome.failures {
            eprintln!("figure {id} failed: {e:#}");
        }
        bail!(
            "{} of {} figure(s) failed ({} completed and written)",
            outcome.failures.len(),
            ids.len(),
            outcome.outputs.len()
        );
    }
    Ok(())
}

/// `elaps calibrate`: run the staged, seeded calibration campaign
/// ([`elaps::figures::calibrate`]) and persist the fitted machine
/// profile. In `--json` mode stdout is the profile JSON itself (for
/// piping into `jq`), progress goes to stderr and no file is written
/// unless `--out` is given explicitly.
fn cmd_calibrate(args: &Args) -> Result<()> {
    try_register_xla();
    let lib = args.opt_or("library", "rustblocked");
    let machine = args.opt_or("machine", "localhost");
    let mut cfg = engine_config(args)?;
    if cfg.seed.is_none() {
        // the fit wants modeled (seeded) cycles: they are exactly linear
        // in (flops, misses), so the recovered parameters are exact
        cfg.seed = Some(elaps::figures::calibrate::CALIBRATE_SEED);
    }
    elaps::engine::set_default_config(cfg.clone());
    let quick = args.flag("quick");
    let (profile, stats) = elaps::figures::calibrate::calibrate(machine, lib, quick, cfg)?;
    let json_mode = args.flag("json");
    if json_mode {
        println!("{}", profile.to_json().to_string_pretty());
        eprintln!("{}", stats.summary_line());
    } else {
        println!("{}", stats.summary_line());
        println!(
            "fitted '{}' (base {}): flops/cycle {:.4}, miss penalties [{}] cycles",
            profile.name,
            profile.base,
            profile.flops_per_cycle,
            profile
                .miss_penalty_cycles
                .iter()
                .map(|p| format!("{p:.2}"))
                .collect::<Vec<_>>()
                .join(", ")
        );
        println!(
            "fit: {} point(s), mean |rel err| {:.2e} (uncalibrated constants: {:.2e})",
            profile.fit_points, profile.mean_abs_rel_err, profile.uncalibrated_mean_abs_rel_err
        );
    }
    let out = match args.opt("out") {
        Some(p) => Some(p.to_string()),
        None if !json_mode => {
            Some(elaps::perfmodel::profile::DEFAULT_PROFILE_PATH.to_string())
        }
        None => None,
    };
    if let Some(path) = out {
        profile.save(&path)?;
        if path == elaps::perfmodel::profile::DEFAULT_PROFILE_PATH {
            eprintln!("profile written to {path} (picked up automatically by --machine localhost)");
        } else {
            eprintln!("profile written to {path} (use --machine profile:{path})");
        }
    }
    Ok(())
}

/// `elaps rank`: predict `modeled_seconds` for every point of an
/// experiment's variant/parameter grid *without executing any kernel*
/// (one fresh predictive sampler per point — exactly the engine's cold
/// seeded semantics, so the ranking provably matches what `elaps run
/// --seed S` would measure) and print the grid fastest-first.
fn cmd_rank(args: &Args) -> Result<()> {
    try_register_xla();
    let path = args.positional.first().ok_or_else(|| {
        anyhow!("usage: elaps rank <experiment.json> [--machine M] [--seed S] [--json]")
    })?;
    let exp = load_experiment(path)?;
    let spec = args.opt_or("machine", &exp.machine);
    let machine = resolve_machine(spec)?;
    let seed = args
        .opt_usize_strict("seed")
        .map_err(|e| anyhow!(e))?
        .map(|s| s as u64)
        .unwrap_or(elaps::figures::calibrate::CALIBRATE_SEED);
    let library = elaps::libraries::by_name(&exp.library)
        .ok_or_else(|| anyhow!("unknown library '{}'", exp.library))?;
    let mut points = Vec::new();
    for pt in exp.unroll()? {
        let mut sampler =
            Sampler::new(std::sync::Arc::clone(&library), machine.clone()).predictive(seed);
        points.push(elaps::engine::execute_point_on(&mut sampler, &exp, &pt)?);
    }
    let report = elaps::Report::assemble(exp, machine, points)?;
    let series = report.series(Metric::TimeS, Stat::Median);
    let mut ranked: Vec<(usize, i64, usize, f64)> = series
        .iter()
        .enumerate()
        .map(|(i, &(x, t))| (i, x, report.points[i].nthreads, t))
        .collect();
    ranked.sort_by(|a, b| a.3.total_cmp(&b.3));
    if args.flag("json") {
        let rows: Vec<Json> = ranked
            .iter()
            .enumerate()
            .map(|(rank, &(i, x, t, secs))| {
                let mut j = Json::obj();
                j.set("rank", rank + 1);
                j.set("point", i);
                j.set("range_value", x);
                j.set("nthreads", t);
                j.set("modeled_seconds", secs);
                j
            })
            .collect();
        let mut top = Json::obj();
        top.set("experiment", report.experiment.name.as_str());
        top.set("machine", report.machine.name.as_str());
        top.set("seed", seed);
        top.set("ranking", rows);
        println!("{}", top.to_string_pretty());
    } else {
        println!(
            "modeled ranking of '{}' on machine '{}' ({} point(s); no kernels executed):",
            report.experiment.name,
            report.machine.name,
            ranked.len()
        );
        let sym = report
            .experiment
            .range
            .as_ref()
            .map(|r| r.sym.as_str())
            .unwrap_or("point");
        println!("  {:>4} {sym:>8} {:>8} {:>16}", "rank", "threads", "modeled[s]");
        for (rank, &(_, x, t, secs)) in ranked.iter().enumerate() {
            println!("  {:>4} {x:>8} {t:>8} {secs:>16.6}", rank + 1);
        }
    }
    Ok(())
}

/// `elaps compare`: run one operation across several backends over a
/// shared parameter grid and print the ranked differential report —
/// per-library series, winner per point, crossovers, direction-aware
/// ranking. `--predicted` swaps the engine for the predictive sampler
/// ([`elaps::figures::scenarios::PredictiveRunner`]), so measured and
/// modeled rankings can be diffed with the same output contract.
fn cmd_compare(args: &Args) -> Result<()> {
    use elaps::figures::scenarios::{compare_libraries, op_experiment, PredictiveRunner, COMPARE_OPS};
    try_register_xla();
    let op = args.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow!(
            "usage: elaps compare <{}> [--libraries a,b,…] [--range lo:step:hi] \
             [--metric M] [--stat S] [--predicted] [--seed S] [--json]",
            COMPARE_OPS.join("|")
        )
    })?;
    let values: Vec<i64> = match args.opt("range") {
        Some(spec) => elaps::util::cli::parse_range(spec)
            .ok_or_else(|| anyhow!("--range expects lo:step:hi (inclusive)"))?
            .into_iter()
            .map(|v| v as i64)
            .collect(),
        None => vec![32, 64, 96, 128, 192, 256],
    };
    let nreps = args
        .opt_usize_strict("nreps")
        .map_err(|e| anyhow!(e))?
        .unwrap_or(3);
    if nreps == 0 {
        bail!("--nreps must be ≥ 1");
    }
    let libs: Vec<String> = match args.opt("libraries") {
        Some(list) => {
            let libs: Vec<String> = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect();
            for lib in &libs {
                if elaps::libraries::by_name(lib).is_none() {
                    bail!(
                        "unknown library '{lib}' (available: {})",
                        elaps::libraries::available_libraries().join(", ")
                    );
                }
            }
            libs
        }
        None => elaps::libraries::available_libraries(),
    };
    let metric = parse_metric(args.opt_or("metric", "gflops"))?;
    let stat = Stat::by_name(args.opt_or("stat", "med"))
        .ok_or_else(|| anyhow!("unknown stat (use min/max/avg/med/std)"))?;
    let mut template = op_experiment(op, values, nreps)?;
    if let Some(m) = args.opt("machine") {
        template.machine = m.to_string();
    }
    let cmp = if args.flag("predicted") {
        let seed = args
            .opt_usize_strict("seed")
            .map_err(|e| anyhow!(e))?
            .map(|s| s as u64)
            .unwrap_or(elaps::figures::calibrate::CALIBRATE_SEED);
        let runner = PredictiveRunner::new(seed);
        compare_libraries(&runner, &template, &libs, metric, stat, "predicted")?
    } else {
        elaps::engine::set_default_config(engine_config(args)?);
        compare_libraries(&elaps::figures::LocalRunner, &template, &libs, metric, stat, "measured")?
    };
    if args.flag("json") {
        println!("{}", cmp.to_json().to_string_pretty());
    } else {
        println!(
            "{} of '{}' on machine '{}' — {} ({}), {} librar(ies), {} point(s):",
            cmp.mode,
            cmp.experiment,
            cmp.machine,
            cmp.metric.name(),
            cmp.stat.name(),
            cmp.libraries.len(),
            cmp.winners.len(),
        );
        let header: Vec<String> =
            cmp.libraries.iter().map(|l| format!("{:>14}", l.library)).collect();
        println!("  {:>8} {} {:>14}", "n", header.join(" "), "winner");
        for (i, (x, winner, _)) in cmp.winners.iter().enumerate() {
            let vals: Vec<String> =
                cmp.libraries.iter().map(|l| format!("{:>14.4}", l.series[i].1)).collect();
            println!("  {x:>8} {} {winner:>14}", vals.join(" "));
        }
        if cmp.crossovers.is_empty() {
            println!("no crossovers: one library wins the whole grid");
        } else {
            for (x, from, to) in &cmp.crossovers {
                println!("crossover at n={x}: {from} → {to}");
            }
        }
        println!("ranking (best first, by mean {}):", cmp.metric.name());
        for (i, r) in cmp.ranking.iter().enumerate() {
            println!(
                "  {:>4} {:<14} score {:>14.4}  wins {}/{}",
                i + 1,
                r.library,
                r.score,
                r.wins,
                cmp.winners.len()
            );
        }
        println!("\n{}", cmp.to_figure().to_ascii(70, 20));
    }
    if let Some(svg) = args.opt("svg") {
        std::fs::write(svg, cmp.to_figure().to_svg(720, 440))?;
        println!("svg written to {svg}");
    }
    Ok(())
}

fn cmd_sampler(args: &Args) -> Result<()> {
    try_register_xla();
    let lib_name = args.opt_or("library", "rustblocked");
    let library = elaps::libraries::by_name(lib_name)
        .ok_or_else(|| anyhow!("unknown library '{lib_name}'"))?;
    let machine = resolve_machine(args.opt_or("machine", "localhost"))?;
    let mut sampler = Sampler::new(library, machine);
    let stdin = std::io::stdin();
    let mut out = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line?;
        match sampler.feed_line(&line) {
            Ok(records) => {
                for r in records {
                    writeln!(out, "{}", r.to_line())?;
                }
                out.flush()?;
            }
            Err(e) => {
                writeln!(out, "error: {e:#}")?;
                out.flush()?;
            }
        }
    }
    Ok(())
}

/// The worker daemon's shutdown flag, raised by SIGTERM/SIGINT so the
/// pool drains gracefully: in-flight jobs finish and publish, no new
/// jobs are claimed.
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn raise_shutdown(_sig: i32) {
    // only an atomic store: async-signal-safe
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT to the shutdown flag (best-effort; on
/// failure the daemon still works, it just dies hard on signals).
#[cfg(unix)]
fn install_shutdown_handler() {
    // libc's classic signal(2) registration — the crates.io cache has
    // no `libc`/`signal-hook`, but the symbol is always there since
    // std links libc on unix
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, raise_shutdown);
        signal(SIGINT, raise_shutdown);
    }
}

#[cfg(not(unix))]
fn install_shutdown_handler() {}

fn cmd_worker(args: &Args) -> Result<()> {
    try_register_xla();
    let mut cfg = engine_config(args)?;
    // --workers parallelizes across queued jobs; each job itself runs
    // serially so the thread count stays bounded (--jobs is accepted
    // as the pre-lease spelling). The cache is still shared through
    // the default engine config.
    let workers = match args.opt_usize_strict("workers").map_err(|e| anyhow!(e))? {
        Some(0) => bail!("--workers must be ≥ 1"),
        Some(n) => n,
        None => cfg.jobs,
    };
    cfg.jobs = 1;
    elaps::engine::set_default_config(cfg);
    let mut spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    if let Some(ttl) = args.opt_duration_strict("lease-ttl").map_err(|e| anyhow!(e))? {
        if ttl.is_zero() {
            bail!("--lease-ttl must be > 0");
        }
        spool = spool.with_ttl(ttl);
    }
    // per-host lease backpressure: this daemon (and, via the on-disk
    // lease count, this host) never holds more than N live leases
    match args.opt_usize_strict("max-leases").map_err(|e| anyhow!(e))? {
        Some(0) => bail!("--max-leases must be ≥ 1"),
        Some(n) => spool = spool.with_max_leases(n),
        None => {}
    }
    if args.flag("no-events") {
        spool = spool.with_events(false);
    }
    if args.flag("verbose") {
        spool = spool.with_verbose(true);
    }
    let once = args.flag("once");
    // legacy (pre-lease) claims are reclaimed by claim-file mtime; 0
    // disables that heuristic. Leased claims always reclaim on lease
    // expiry, independent of this knob.
    let legacy_recover = match args.opt_usize_strict("recover").map_err(|e| anyhow!(e))? {
        Some(0) => None,
        Some(secs) => Some(std::time::Duration::from_secs(secs as u64)),
        None => Some(std::time::Duration::from_secs(300)),
    };
    install_shutdown_handler();
    println!(
        "worker {} draining {} with {workers} worker(s), lease TTL {:?}{}{}",
        spool.worker_id(),
        spool.dir.display(),
        spool.ttl(),
        match spool.max_leases() {
            Some(n) => format!(", ≤{n} lease(s)"),
            None => String::new(),
        },
        if once { " (once)" } else { "" }
    );
    let served = spool.run_worker_pool(workers, once, legacy_recover, &SHUTDOWN)?;
    if SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst) {
        println!("shutdown: drained gracefully after {served} job(s)");
    } else {
        println!("served {served} job(s)");
    }
    Ok(())
}

/// The `elaps spool {status,dead-letter,compact}` subcommands.
/// `status`: queued/leased/done counts with the per-host lease and
/// provenance breakdown — through the incremental ledger status cache
/// by default, via full directory scans under `--no-ledger` (both
/// produce identical output). `dead-letter`: a campaign's
/// dead-lettered jobs. `compact`: fold a campaign ledger into its
/// index snapshot, optionally archiving it.
fn cmd_spool(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("usage: elaps spool status|dead-letter|compact …"))?;
    let dir = std::path::PathBuf::from(args.opt_or("spool", ".elaps-spool"));
    if args.flag("campaign") {
        bail!("--campaign requires a tag");
    }
    match sub {
        "status" => {
            let st = if args.flag("no-ledger") {
                elaps::coordinator::lease::spool_status(&dir)?
            } else {
                ledger::spool_status_ledger(&dir)?
            };
            if args.flag("json") {
                println!("{}", st.to_json().to_string_pretty());
            } else {
                println!("spool at {}:", dir.display());
                print!("{}", st.render());
            }
        }
        "dead-letter" => {
            let Some(tag) = args.opt("campaign") else {
                bail!("usage: elaps spool dead-letter --campaign TAG [--spool DIR] [--json]");
            };
            let mut idx = ledger::CampaignIndex::load(&dir, tag)?;
            idx.refresh(&dir)?;
            let dead = idx.dead_letters();
            if args.flag("json") {
                let arr = Json::Arr(dead.iter().map(|e| e.to_json()).collect());
                println!("{}", arr.to_string_pretty());
            } else {
                for e in &dead {
                    println!(
                        "{}  attempt {} (retry of {})",
                        e.job_id,
                        e.attempt,
                        e.retry_of.as_deref().unwrap_or("-")
                    );
                }
                eprintln!("{} dead-lettered job(s) in campaign '{tag}'", dead.len());
            }
            let _ = idx.save(&dir);
        }
        "compact" => {
            let Some(tag) = args.opt("campaign") else {
                bail!("usage: elaps spool compact --campaign TAG [--archive] [--spool DIR]");
            };
            let archived = ledger::compact(&dir, tag, args.flag("archive"))?;
            if archived {
                println!("campaign '{tag}': ledger folded into its snapshot and archived");
            } else if args.flag("archive") {
                println!(
                    "campaign '{tag}': snapshot refreshed; ledger kept (already archived, \
                     or unread appends remain)"
                );
            } else {
                println!("campaign '{tag}': ledger folded into its snapshot");
            }
        }
        other => {
            bail!("unknown spool subcommand '{other}' (expected status|dead-letter|compact)")
        }
    }
    Ok(())
}

/// `elaps analyze`: merge a spool's job-lifecycle event log into
/// queue-wait/service/publish percentiles, per-host throughput and
/// backpressure stall, cache hit rates by class, the exactly-once
/// publish audit and straggler detection — for one campaign
/// (`--campaign TAG`) or the whole spool.
fn cmd_analyze(args: &Args) -> Result<()> {
    if args.flag("campaign") {
        bail!("--campaign requires a tag");
    }
    let dir = std::path::PathBuf::from(args.opt_or("spool", ".elaps-spool"));
    let analysis = elaps::obs::analyze(&dir, args.opt("campaign"))?;
    if args.flag("json") {
        println!("{}", analysis.to_json().to_string_pretty());
    } else {
        print!("{}", analysis.render());
    }
    Ok(())
}

/// `elaps bench`: micro-benchmark the framework's own hot paths and
/// snapshot the numbers to machine-readable `BENCH_<suite>.json` files
/// (cache probe/hash, spooler claim + scans, event log, sampler inner
/// loop). `--quick` shrinks workloads ~10x for CI smoke runs.
fn cmd_bench(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.opt_or("out", "."));
    std::fs::create_dir_all(&out_dir)?;
    let written = elaps::obs::run_bench(&out_dir, args.flag("quick"), &args.positional)?;
    println!("{} suite snapshot(s) written", written.len());
    Ok(())
}

fn cmd_kernels() -> Result<()> {
    for (name, sig) in elaps::kernels::registry() {
        let args: Vec<&str> = sig.args.iter().map(|(n, _)| *n).collect();
        println!("{name:<8} ({})\n         {}", args.join(", "), sig.doc);
    }
    Ok(())
}

fn cmd_libraries() -> Result<()> {
    try_register_xla();
    // built-ins first, then every registered extra (xla backends land
    // here once try_register_xla finds artifacts) — the same list
    // `elaps compare` defaults to
    let builtin: &[&str] = elaps::libraries::RUST_LIBRARIES;
    for name in elaps::libraries::available_libraries() {
        if builtin.contains(&name.as_str()) {
            println!("{name}");
        } else {
            println!("{name}  (registered)");
        }
    }
    Ok(())
}
