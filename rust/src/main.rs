//! The `elaps` CLI — the framework's top layer (substituting the
//! paper's PlayMat/Viewer GUI on this headless host; DESIGN.md
//! §Substitutions 6).
//!
//! Subcommands:
//!   run <exp.json>        run an experiment file (local or --batch)
//!   view <report.json>    metrics/statistics of a stored report
//!   plot <report.json>    ASCII + SVG plot of a stored report
//!   figures [ids…]        regenerate the paper's tables/figures
//!   sampler               stdin/stdout sampler (the paper's §3.1 tool)
//!   worker --spool <dir>  batch-queue worker
//!   kernels               list the kernel signature database
//!   libraries             list available kernel libraries

use anyhow::{anyhow, bail, Context, Result};
use elaps::coordinator::{io, run_local, Metric, Spooler, Stat};
use elaps::perfmodel::MachineModel;
use elaps::sampler::Sampler;
use elaps::util::cli::Args;
use elaps::util::json::Json;
use std::io::{BufRead, Write};

const USAGE: &str = "\
elaps — Experimental Linear Algebra Performance Studies (rust+JAX/Pallas)

USAGE:
  elaps run <experiment.json> [--batch --spool DIR] [--out report.json]
  elaps view <report.json> [--metric M] [--stat S]
  elaps plot <report.json> [--metric M] [--stat S] [--svg out.svg]
  elaps figures [T1 F1 F2 …|all] [--full] [--out-dir figures_out]
  elaps sampler [--library L] [--machine M]
  elaps worker --spool DIR [--once]
  elaps kernels
  elaps libraries

metrics: cycles time_s time_ms gflops flops_per_cycle efficiency
stats:   min max avg med std
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn try_register_xla() {
    let dir = elaps::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        if let Err(e) = elaps::runtime::register_xla_library(&dir) {
            eprintln!("note: xla backend unavailable: {e:#}");
        }
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let Some(cmd) = raw.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(raw[1..].iter().cloned(), &["batch", "once", "full", "help"]);
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "view" => cmd_view(&args),
        "plot" => cmd_plot(&args),
        "figures" => cmd_figures(&args),
        "sampler" => cmd_sampler(&args),
        "worker" => cmd_worker(&args),
        "kernels" => cmd_kernels(),
        "libraries" => cmd_libraries(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_experiment(path: &str) -> Result<elaps::Experiment> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    io::experiment_from_json(&j)
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.positional.first().ok_or_else(|| anyhow!("usage: elaps run <exp.json>"))?;
    try_register_xla();
    let exp = load_experiment(path)?;
    let report = if args.flag("batch") {
        let spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
        let id = spool.submit(&exp)?;
        println!("submitted job {id}; serving in-process worker…");
        spool.serve_one()?;
        spool.fetch(&id)?.ok_or_else(|| anyhow!("job produced no report"))?
    } else {
        run_local(&exp)?
    };
    print_report_summary(&report)?;
    let out = args.opt_or("out", "report.json");
    std::fs::write(out, io::report_to_json(&report).to_string_pretty())?;
    println!("report written to {out}");
    Ok(())
}

fn parse_metric(name: &str) -> Result<Metric> {
    Ok(match name {
        "cycles" => Metric::Cycles,
        "time_s" => Metric::TimeS,
        "time_ms" => Metric::TimeMs,
        "gflops" => Metric::Gflops,
        "flops_per_cycle" => Metric::FlopsPerCycle,
        "efficiency" => Metric::Efficiency,
        other => {
            if let Some(i) = other.strip_prefix("counter") {
                Metric::Counter(i.parse().unwrap_or(0))
            } else {
                bail!("unknown metric '{other}'")
            }
        }
    })
}

fn load_report(args: &Args) -> Result<elaps::Report> {
    let path = args.positional.first().ok_or_else(|| anyhow!("need a report file"))?;
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    io::report_from_json(&j)
}

fn print_report_summary(report: &elaps::Report) -> Result<()> {
    println!(
        "experiment '{}' on library '{}' ({} point(s), {} rep(s))",
        report.experiment.name,
        report.experiment.library,
        report.points.len(),
        report.experiment.nreps
    );
    if report.points.len() == 1 {
        for (name, v) in report.metrics_table() {
            println!("  {name:<18} {v:>16.4}");
        }
    } else {
        println!("  {:>8} {:>14} {:>14}", "range", "Gflops/s(med)", "time[s](med)");
        let g = report.series(Metric::Gflops, Stat::Median);
        let t = report.series(Metric::TimeS, Stat::Median);
        for (i, (x, gf)) in g.iter().enumerate() {
            println!("  {x:>8} {gf:>14.4} {:>14.6}", t[i].1);
        }
    }
    Ok(())
}

fn cmd_view(args: &Args) -> Result<()> {
    let report = load_report(args)?;
    let metric = parse_metric(args.opt_or("metric", "gflops"))?;
    let stat = Stat::by_name(args.opt_or("stat", "med"))
        .ok_or_else(|| anyhow!("unknown stat"))?;
    print_report_summary(&report)?;
    println!("\n{} ({}):", metric.name(), stat.name());
    for (x, v) in report.series(metric, stat) {
        println!("  {x:>8} {v:>16.4}");
    }
    Ok(())
}

fn cmd_plot(args: &Args) -> Result<()> {
    let report = load_report(args)?;
    let metric = parse_metric(args.opt_or("metric", "gflops"))?;
    let stat = Stat::by_name(args.opt_or("stat", "med"))
        .ok_or_else(|| anyhow!("unknown stat"))?;
    let mut fig = elaps::coordinator::Figure::new(
        &report.experiment.name,
        report
            .experiment
            .range
            .as_ref()
            .map(|r| r.sym.as_str())
            .unwrap_or("point"),
        &metric.name(),
    );
    fig.add_iseries(
        &format!("{} ({})", report.experiment.library, stat.name()),
        &report.series(metric, stat),
    );
    println!("{}", fig.to_ascii(70, 20));
    if let Some(svg) = args.opt("svg") {
        std::fs::write(svg, fig.to_svg(720, 440))?;
        println!("svg written to {svg}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    try_register_xla();
    let quick = !args.flag("full");
    let out_dir = std::path::PathBuf::from(args.opt_or("out-dir", "figures_out"));
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|p| p == "all")
    {
        elaps::figures::all_builders().iter().map(|(id, _)| id.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for id in &ids {
        println!("--- running {id} (quick={quick}) ---");
        let t0 = std::time::Instant::now();
        let out = elaps::figures::run_figure(id, quick)?;
        out.write_to(&out_dir)?;
        println!(
            "{}: {} rows, {:.1}s → {}/{}.{{csv,svg,txt}}",
            out.id,
            out.rows.len(),
            t0.elapsed().as_secs_f64(),
            out_dir.display(),
            out.id
        );
        println!("    {}", out.notes.replace('\n', "\n    "));
    }
    Ok(())
}

fn cmd_sampler(args: &Args) -> Result<()> {
    try_register_xla();
    let lib_name = args.opt_or("library", "rustblocked");
    let library = elaps::libraries::by_name(lib_name)
        .ok_or_else(|| anyhow!("unknown library '{lib_name}'"))?;
    let machine = MachineModel::by_name(args.opt_or("machine", "localhost"))
        .ok_or_else(|| anyhow!("unknown machine"))?;
    let mut sampler = Sampler::new(library, machine);
    let stdin = std::io::stdin();
    let mut out = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line?;
        match sampler.feed_line(&line) {
            Ok(records) => {
                for r in records {
                    writeln!(out, "{}", r.to_line())?;
                }
                out.flush()?;
            }
            Err(e) => {
                writeln!(out, "error: {e:#}")?;
                out.flush()?;
            }
        }
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    try_register_xla();
    let spool = Spooler::new(args.opt_or("spool", ".elaps-spool"))?;
    let once = args.flag("once");
    loop {
        match spool.serve_one()? {
            Some(id) => println!("served job {id}"),
            None => {
                if once {
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
        }
    }
}

fn cmd_kernels() -> Result<()> {
    for (name, sig) in elaps::kernels::registry() {
        let args: Vec<&str> = sig.args.iter().map(|(n, _)| *n).collect();
        println!("{name:<8} ({})\n         {}", args.join(", "), sig.doc);
    }
    Ok(())
}

fn cmd_libraries() -> Result<()> {
    try_register_xla();
    for name in elaps::libraries::RUST_LIBRARIES {
        println!("{name}");
    }
    for name in ["xla", "xla-pallas"] {
        if elaps::libraries::by_name(name).is_some() {
            println!("{name}  (AOT artifacts via PJRT)");
        }
    }
    Ok(())
}
