//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client, and
//! expose them as the `xla` kernel-library backend.
//!
//! Python never runs here — the artifacts directory is the only
//! contact surface between the build-time JAX/Pallas path and the Rust
//! request path (see /opt/xla-example/load_hlo for the pattern).

use crate::kernels::ArgValues;
use crate::libraries::{KernelLibrary, OperandSet};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One manifest entry.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub kernel: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// "jnp" (vendor XLA dot) or "pallas" (the L1 kernel).
    pub impl_name: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub key: ArtifactKey,
    pub file: PathBuf,
}

/// The artifact registry: manifest index + lazily compiled
/// executables.
///
/// The `xla` crate's PJRT wrappers are `Rc`-based and thus neither
/// `Send` nor `Sync`; the PJRT C API itself is thread-safe. We restore
/// `Send + Sync` by funneling *every* client/executable access through
/// one mutex (`inner`), so no `Rc` handle is ever touched by two
/// threads concurrently — see the `unsafe impl`s below.
pub struct ArtifactRegistry {
    artifacts: Vec<ArtifactMeta>,
    inner: Mutex<RegistryInner>,
    compiled: AtomicUsize,
}

struct RegistryInner {
    client: xla::PjRtClient,
    cache: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
}

// SAFETY: all uses of the Rc-based PJRT wrappers are confined to
// `RegistryInner`, only reachable through the `inner` mutex; no Rc
// handle escapes a locked section.
unsafe impl Send for ArtifactRegistry {}
unsafe impl Sync for ArtifactRegistry {}

impl ArtifactRegistry {
    /// Read `<dir>/manifest.json` and prepare the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").as_arr().unwrap_or(&[]) {
            let key = ArtifactKey {
                kernel: a.get("kernel").as_str().unwrap_or("?").to_string(),
                m: a.get("m").as_u64().unwrap_or(0) as usize,
                n: a.get("n").as_u64().unwrap_or(0) as usize,
                k: a.get("k").as_u64().unwrap_or(0) as usize,
                impl_name: a.get("impl").as_str().unwrap_or("jnp").to_string(),
            };
            let file = dir.join(a.get("file").as_str().ok_or_else(|| anyhow!("missing file"))?);
            if !file.exists() {
                bail!("artifact {file:?} listed in manifest but missing on disk");
            }
            artifacts.push(ArtifactMeta { key, file });
        }
        if artifacts.is_empty() {
            bail!("manifest {manifest_path:?} lists no artifacts");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactRegistry {
            artifacts,
            inner: Mutex::new(RegistryInner { client, cache: HashMap::new() }),
            compiled: AtomicUsize::new(0),
        })
    }

    pub fn artifact_count(&self) -> usize {
        self.artifacts.len()
    }

    /// How many executables have been compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.compiled.load(Ordering::Relaxed)
    }

    /// Find an artifact: exact (kernel, m, n, k) match, preferring the
    /// requested impl but falling back to any.
    pub fn find(&self, kernel: &str, m: usize, n: usize, k: usize, prefer: &str) -> Option<&ArtifactMeta> {
        let mut fallback = None;
        for a in &self.artifacts {
            if a.key.kernel == kernel && a.key.m == m && a.key.n == n && a.key.k == k {
                if a.key.impl_name == prefer {
                    return Some(a);
                }
                fallback = Some(a);
            }
        }
        fallback
    }

    /// Execute a gemm artifact on raw column-major buffers.
    ///
    /// Column-major bridge (see python/compile/model.py): the m×k A
    /// buffer is bit-identical to Aᵀ in row-major (k, m); likewise B.
    /// The artifact computes Bᵀ·Aᵀ = (A·B)ᵀ, whose row-major bytes are
    /// C in column-major. alpha/beta are applied here (O(mn), keeps
    /// the artifact generic).
    #[allow(clippy::too_many_arguments)]
    pub fn run_gemm(
        &self,
        meta: &ArtifactMeta,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        beta: f64,
    ) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(&meta.key) {
            let path = meta
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
            self.compiled.fetch_add(1, Ordering::Relaxed);
            inner.cache.insert(meta.key.clone(), exe);
        }
        let exe = inner.cache.get(&meta.key).unwrap();
        let bt = xla::Literal::vec1(&b[..k * n])
            .reshape(&[n as i64, k as i64])
            .map_err(|e| anyhow!("reshape B: {e:?}"))?;
        let at = xla::Literal::vec1(&a[..m * k])
            .reshape(&[k as i64, m as i64])
            .map_err(|e| anyhow!("reshape A: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[bt, at])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let values = out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if values.len() != m * n {
            bail!("artifact returned {} values, expected {}", values.len(), m * n);
        }
        if beta == 0.0 && alpha == 1.0 {
            c[..m * n].copy_from_slice(&values);
        } else {
            for (ci, vi) in c[..m * n].iter_mut().zip(&values) {
                *ci = alpha * vi + beta * *ci;
            }
        }
        Ok(())
    }

    /// Warm the executable cache for a key (compile without running).
    pub fn precompile(&self, meta: &ArtifactMeta) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.cache.contains_key(&meta.key) {
            return Ok(());
        }
        let path = meta.file.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        self.compiled.fetch_add(1, Ordering::Relaxed);
        inner.cache.insert(meta.key.clone(), exe);
        Ok(())
    }
}

/// The `xla` kernel library: routes dgemm calls with artifact-covered
/// shapes to PJRT; everything else is rejected (the experiments pick
/// shapes the manifest covers — exactly like linking a vendor library
/// that only ships certain optimized paths).
pub struct XlaLibrary {
    registry: Arc<ArtifactRegistry>,
    prefer: String,
    nthreads: AtomicUsize,
}

impl XlaLibrary {
    pub fn new(registry: Arc<ArtifactRegistry>, prefer_impl: &str) -> XlaLibrary {
        XlaLibrary {
            registry,
            prefer: prefer_impl.to_string(),
            nthreads: AtomicUsize::new(1),
        }
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }
}

impl KernelLibrary for XlaLibrary {
    fn name(&self) -> &str {
        "xla"
    }

    fn set_threads(&self, n: usize) {
        self.nthreads.store(n.max(1), Ordering::Relaxed);
    }

    fn threads(&self) -> usize {
        self.nthreads.load(Ordering::Relaxed)
    }

    fn execute(&self, av: &ArgValues, ops: &OperandSet) -> Result<()> {
        match av.sig.name {
            "dgemm" => {
                let (m, n, k) = (av.dim("m"), av.dim("n"), av.dim("k"));
                if av.flag("transa") != 'N' || av.flag("transb") != 'N' {
                    bail!("xla library: only dgemm N/N artifacts are compiled");
                }
                if av.dim("lda") != m || av.dim("ldb") != k || av.dim("ldc") != m {
                    bail!("xla library: requires packed operands (ld == rows)");
                }
                let meta = self
                    .registry
                    .find("dgemm", m, n, k, &self.prefer)
                    .ok_or_else(|| {
                        anyhow!("xla library: no artifact for dgemm {m}x{n}x{k} — add it to aot.py")
                    })?
                    .clone();
                self.registry.run_gemm(
                    &meta,
                    ops.get(0),
                    ops.get(1),
                    ops.get_mut(2),
                    m,
                    n,
                    k,
                    av.num("alpha"),
                    av.num("beta"),
                )
            }
            other => bail!("xla library: kernel '{other}' has no AOT artifact"),
        }
    }
}

/// Load the registry from `dir` and register the `xla` (and
/// `xla-pallas`) libraries for resolution by name. Idempotent-ish:
/// re-registering replaces the previous instance.
pub fn register_xla_library(dir: impl AsRef<Path>) -> Result<Arc<ArtifactRegistry>> {
    let registry = Arc::new(ArtifactRegistry::load(dir)?);
    crate::libraries::register("xla", Arc::new(XlaLibrary::new(registry.clone(), "jnp")));
    crate::libraries::register(
        "xla-pallas",
        Arc::new(XlaLibrary::new(registry.clone(), "pallas")),
    );
    Ok(registry)
}

/// Default artifacts directory: `$ELAPS_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("ELAPS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DataDir;
    use crate::libraries::RawOperand;
    use crate::linalg::Matrix;
    use crate::util::rng::Xoshiro256;

    fn registry() -> Option<Arc<ArtifactRegistry>> {
        // Tests are skipped when artifacts haven't been built (CI
        // runs `make artifacts` first; `cargo test` alone must not
        // hard-fail).
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts at {dir:?}");
            return None;
        }
        Some(Arc::new(ArtifactRegistry::load(dir).unwrap()))
    }

    #[test]
    fn manifest_loads_and_finds_shapes() {
        let Some(reg) = registry() else { return };
        assert!(reg.artifact_count() >= 10);
        assert!(reg.find("dgemm", 128, 128, 128, "jnp").is_some());
        assert!(reg.find("dgemm", 128, 128, 128, "pallas").is_some());
        assert!(reg.find("dgemm", 7, 7, 7, "jnp").is_none());
        // impl preference honored, with fallback
        let a = reg.find("dgemm", 128, 128, 128, "pallas").unwrap();
        assert_eq!(a.key.impl_name, "pallas");
        let b = reg.find("dgemm", 1000, 1000, 1000, "pallas").unwrap();
        assert_eq!(b.key.impl_name, "jnp"); // fallback
    }

    #[test]
    fn gemm_via_pjrt_matches_rust_blas() {
        let Some(reg) = registry() else { return };
        let n = 128;
        let mut rng = Xoshiro256::seeded(500);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let expect = a.matmul(&b);
        let meta = reg.find("dgemm", n, n, n, "jnp").unwrap().clone();
        let mut c = vec![0.0f64; n * n];
        reg.run_gemm(&meta, &a.data, &b.data, &mut c, n, n, n, 1.0, 0.0).unwrap();
        let c = Matrix { m: n, n, data: c };
        assert!(c.max_abs_diff(&expect) < 1e-10, "{}", c.max_abs_diff(&expect));
        // executable caching
        assert_eq!(reg.compiled_count(), 1);
        let mut c2 = vec![0.0f64; n * n];
        reg.run_gemm(&meta, &a.data, &b.data, &mut c2, n, n, n, 1.0, 0.0).unwrap();
        assert_eq!(reg.compiled_count(), 1);
    }

    #[test]
    fn pallas_artifact_matches_jnp_artifact() {
        let Some(reg) = registry() else { return };
        let n = 128;
        let mut rng = Xoshiro256::seeded(501);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let jnp = reg.find("dgemm", n, n, n, "jnp").unwrap().clone();
        let pal = reg.find("dgemm", n, n, n, "pallas").unwrap().clone();
        assert_eq!(pal.key.impl_name, "pallas");
        let mut c1 = vec![0.0f64; n * n];
        let mut c2 = vec![0.0f64; n * n];
        reg.run_gemm(&jnp, &a.data, &b.data, &mut c1, n, n, n, 1.0, 0.0).unwrap();
        reg.run_gemm(&pal, &a.data, &b.data, &mut c2, n, n, n, 1.0, 0.0).unwrap();
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn xla_library_full_dispatch_and_alpha_beta() {
        let Some(reg) = registry() else { return };
        let lib = XlaLibrary::new(reg, "jnp");
        let n = 128;
        let mut rng = Xoshiro256::seeded(502);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let c0 = Matrix::random(n, n, &mut rng);
        let sig = crate::kernels::lookup("dgemm").unwrap();
        let ns = n.to_string();
        let toks = ["N", "N", &ns, &ns, &ns, "2.0", "A", &ns, "B", &ns, "-1.0", "C", &ns];
        let values: Vec<crate::kernels::ArgValue> = sig
            .args
            .iter()
            .zip(toks.iter())
            .map(|((_, role), t)| match role {
                crate::kernels::ArgRole::Flag(_) => {
                    crate::kernels::ArgValue::Char(t.chars().next().unwrap())
                }
                crate::kernels::ArgRole::Scalar => {
                    crate::kernels::ArgValue::Num(t.parse().unwrap())
                }
                crate::kernels::ArgRole::Data(_) => {
                    crate::kernels::ArgValue::Data(t.to_string())
                }
                _ => crate::kernels::ArgValue::Size(t.parse().unwrap()),
            })
            .collect();
        let av = ArgValues { sig, values };
        let mut ab = a.data.clone();
        let mut bb = b.data.clone();
        let mut cb = c0.data.clone();
        let ops = OperandSet::new(vec![
            RawOperand { ptr: ab.as_mut_ptr(), len: ab.len(), dir: DataDir::In },
            RawOperand { ptr: bb.as_mut_ptr(), len: bb.len(), dir: DataDir::In },
            RawOperand { ptr: cb.as_mut_ptr(), len: cb.len(), dir: DataDir::InOut },
        ])
        .unwrap();
        lib.execute(&av, &ops).unwrap();
        let expect = {
            let ab2 = a.matmul(&b);
            Matrix::from_fn(n, n, |i, j| 2.0 * ab2[(i, j)] - c0[(i, j)])
        };
        let got = Matrix { m: n, n, data: cb };
        assert!(got.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn missing_shape_is_clean_error() {
        let Some(reg) = registry() else { return };
        let lib = XlaLibrary::new(reg, "jnp");
        assert!(lib
            .registry()
            .find("dgemm", 77, 77, 77, "jnp")
            .is_none());
        let _ = lib;
    }
}
