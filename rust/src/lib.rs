//! # ELAPS-RS — Experimental Linear Algebra Performance Studies
//!
//! A Rust + JAX/Pallas reproduction of *"The ELAPS Framework:
//! Experimental Linear Algebra Performance Studies"* (Peise &
//! Bientinesi, 2015).
//!
//! The framework is structured after the paper's three layers:
//!
//! * [`sampler`] — the bottom layer: a low-level tool that reads a list
//!   of kernel calls, executes and times them, and reports raw
//!   measurements (cycles, simulated PAPI counters).
//! * [`coordinator`] — the middle layer: the [`coordinator::Experiment`]
//!   abstraction (repetitions, operand varying, parameter-/sum-/OpenMP-
//!   ranges), execution on samplers, [`coordinator::Report`]s, metrics,
//!   statistics and plotting.
//! * [`engine`] — the execution engine between coordinator and
//!   samplers: shards an experiment's (or a whole batch's) unrolled
//!   points across a worker-thread pool with a shared work queue and
//!   deterministic in-order result merging, and skips already-measured
//!   points via a content-addressed on-disk result cache.
//! * the top layer (the paper's GUI) is substituted by the `elaps` CLI
//!   binary and file-based experiment descriptions.
//!
//! Underneath sit the substrates a reproduction must provide itself:
//! a from-scratch dense linear algebra library ([`linalg`]) in several
//! algorithmic variants ([`libraries`]), a machine/cache performance
//! model ([`perfmodel`]) standing in for real hardware counters and
//! multi-core platforms, and a PJRT runtime ([`runtime`]) that executes
//! JAX/Pallas kernels AOT-compiled to HLO.

// The CI clippy gate runs with -D warnings; these two stylistic lints
// fire on long-standing idioms of this codebase (nested slot/result
// type aliases and the kernels' BLAS-shaped signatures) and are not
// worth churning every call site over.
#![allow(clippy::type_complexity)]
#![allow(clippy::too_many_arguments)]

pub mod util;
pub mod linalg;
pub mod kernels;
pub mod libraries;
pub mod perfmodel;
pub mod sampler;
pub mod coordinator;
pub mod engine;
pub mod obs;
pub mod runtime;
pub mod figures;

pub use coordinator::{Experiment, Report};
pub use engine::{BatchStats, Engine, EngineConfig};
