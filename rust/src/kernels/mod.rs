//! Kernel signature database — the paper's "Signatures" (§3.2.1).
//!
//! A [`Signature`] annotates a BLAS/LAPACK-style kernel with the
//! semantics of each argument (flags with feasible values, dimensions,
//! scalars, leading dimensions, data operands with direction), the
//! kernel's flop count, and the sizes of its data operands as derived
//! from the scalar arguments. The coordinator uses Signatures to unroll
//! experiments into sampler calls, to size and place operands, and to
//! compute performance metrics; the sampler uses them to parse calls.

use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Direction of a data operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataDir {
    In,
    Out,
    InOut,
}

/// Role of one kernel argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgRole {
    /// Single-character flag with the feasible values listed.
    Flag(&'static [char]),
    /// Problem dimension (non-negative integer).
    Dim,
    /// Floating-point scalar (e.g. alpha, beta).
    Scalar,
    /// Leading dimension of the preceding data operand.
    Ld,
    /// Vector stride.
    Inc,
    /// Data operand (matrix/vector in sampler memory).
    Data(DataDir),
}

/// A parsed argument value, aligned with the signature's `args`.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    Char(char),
    Size(usize),
    Num(f64),
    /// Name of a sampler variable (possibly with an offset applied by
    /// the coordinator via derived variables).
    Data(String),
}

impl ArgValue {
    pub fn as_size(&self) -> Option<usize> {
        match self {
            ArgValue::Size(s) => Some(*s),
            _ => None,
        }
    }
    pub fn as_char(&self) -> Option<char> {
        match self {
            ArgValue::Char(c) => Some(*c),
            _ => None,
        }
    }
    pub fn as_num(&self) -> Option<f64> {
        match self {
            ArgValue::Num(v) => Some(*v),
            ArgValue::Size(s) => Some(*s as f64),
            _ => None,
        }
    }
    pub fn as_data(&self) -> Option<&str> {
        match self {
            ArgValue::Data(s) => Some(s),
            _ => None,
        }
    }
}

/// Parsed argument list with by-name access through the signature.
#[derive(Debug, Clone)]
pub struct ArgValues {
    pub sig: &'static Signature,
    pub values: Vec<ArgValue>,
}

impl ArgValues {
    pub fn get(&self, name: &str) -> Option<&ArgValue> {
        self.sig
            .args
            .iter()
            .position(|(n, _)| *n == name)
            .map(|i| &self.values[i])
    }

    /// Dimension argument by name (panics if absent — signatures are
    /// static, so a miss is a programming error).
    pub fn dim(&self, name: &str) -> usize {
        self.get(name)
            .and_then(ArgValue::as_size)
            .unwrap_or_else(|| panic!("{}: missing dim '{name}'", self.sig.name))
    }

    pub fn flag(&self, name: &str) -> char {
        self.get(name)
            .and_then(ArgValue::as_char)
            .unwrap_or_else(|| panic!("{}: missing flag '{name}'", self.sig.name))
    }

    pub fn num(&self, name: &str) -> f64 {
        self.get(name)
            .and_then(ArgValue::as_num)
            .unwrap_or_else(|| panic!("{}: missing scalar '{name}'", self.sig.name))
    }

    /// (signature index, variable name) of the data operands, in order.
    pub fn data_args(&self) -> Vec<(usize, &str)> {
        self.sig
            .args
            .iter()
            .enumerate()
            .filter(|(_, (_, role))| matches!(role, ArgRole::Data(_)))
            .map(|(i, _)| (i, self.values[i].as_data().unwrap_or("?")))
            .collect()
    }

    /// Flop count of this call.
    pub fn flops(&self) -> f64 {
        (self.sig.flops)(self)
    }

    /// Element count of the k-th data operand (ordinal among data args).
    pub fn operand_elems(&self, ordinal: usize) -> usize {
        (self.sig.operand_elems)(self, ordinal)
    }

    /// Total bytes touched (reads + writes), for the cache model.
    pub fn traffic_bytes(&self) -> f64 {
        let mut total = 0.0;
        let mut ord = 0;
        for (_, role) in self.sig.args.iter() {
            if let ArgRole::Data(dir) = role {
                let bytes = 8.0 * self.operand_elems(ord) as f64;
                total += match dir {
                    DataDir::In | DataDir::Out => bytes,
                    DataDir::InOut => 2.0 * bytes,
                };
                ord += 1;
            }
        }
        total
    }
}

/// Static description of one kernel.
pub struct Signature {
    pub name: &'static str,
    /// (argument name, role) in calling order.
    pub args: &'static [(&'static str, ArgRole)],
    /// Flop count as a function of the call's scalar arguments.
    pub flops: fn(&ArgValues) -> f64,
    /// Size in f64 elements of the data operand with the given ordinal.
    pub operand_elems: fn(&ArgValues, usize) -> usize,
    /// One-line human description (PlayMat-style annotation).
    pub doc: &'static str,
}

impl std::fmt::Debug for Signature {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Signature").field("name", &self.name).finish()
    }
}

use ArgRole::*;
use DataDir::*;

const TT: &[char] = &['N', 'T'];
const UL: &[char] = &['L', 'U'];
const LR: &[char] = &['L', 'R'];
const DG: &[char] = &['N', 'U'];
const JZ: &[char] = &['N', 'V'];

fn gemm_elems(av: &ArgValues, ord: usize) -> usize {
    let (m, k) = (av.dim("m"), av.dim("k"));
    match ord {
        0 => av.dim("lda") * if av.flag("transa") == 'N' { k } else { m },
        1 => av.dim("ldb") * if av.flag("transb") == 'N' { av.dim("n") } else { k },
        _ => av.dim("ldc") * av.dim("n"),
    }
}

fn trsm_elems(av: &ArgValues, ord: usize) -> usize {
    let (m, n) = (av.dim("m"), av.dim("n"));
    match ord {
        0 => av.dim("lda") * if av.flag("side") == 'L' { m } else { n },
        _ => av.dim("ldb") * n,
    }
}

fn square_elems(av: &ArgValues, _ord: usize) -> usize {
    av.dim("lda") * av.dim("n")
}

fn eig_flops(av: &ArgValues) -> f64 {
    // LAPACK-style estimate: tridiagonal reduction 4/3·n³, plus ≈6n³
    // for eigenvector accumulation when jobz = 'V'.
    let n = av.dim("n") as f64;
    if av.flag("jobz") == 'V' {
        4.0 / 3.0 * n * n * n + 6.0 * n * n * n
    } else {
        4.0 / 3.0 * n * n * n
    }
}

fn eig_elems(av: &ArgValues, ord: usize) -> usize {
    match ord {
        0 => av.dim("lda") * av.dim("n"),
        _ => av.dim("n"),
    }
}

const EIG_ARGS: &[(&str, ArgRole)] = &[
    ("jobz", Flag(JZ)),
    ("uplo", Flag(UL)),
    ("n", Dim),
    ("A", Data(InOut)),
    ("lda", Ld),
    ("W", Data(Out)),
];

static SIGNATURES: OnceLock<BTreeMap<&'static str, Signature>> = OnceLock::new();

/// The kernel database.
pub fn registry() -> &'static BTreeMap<&'static str, Signature> {
    SIGNATURES.get_or_init(|| {
        let mut m = BTreeMap::new();
        let mut add = |s: Signature| {
            m.insert(s.name, s);
        };

        add(Signature {
            name: "dgemm",
            args: &[
                ("transa", Flag(TT)),
                ("transb", Flag(TT)),
                ("m", Dim),
                ("n", Dim),
                ("k", Dim),
                ("alpha", Scalar),
                ("A", Data(In)),
                ("lda", Ld),
                ("B", Data(In)),
                ("ldb", Ld),
                ("beta", Scalar),
                ("C", Data(InOut)),
                ("ldc", Ld),
            ],
            flops: |av| 2.0 * av.dim("m") as f64 * av.dim("n") as f64 * av.dim("k") as f64,
            operand_elems: gemm_elems,
            doc: "C := alpha*op(A)*op(B) + beta*C",
        });

        add(Signature {
            name: "dtrsm",
            args: &[
                ("side", Flag(LR)),
                ("uplo", Flag(UL)),
                ("transa", Flag(TT)),
                ("diag", Flag(DG)),
                ("m", Dim),
                ("n", Dim),
                ("alpha", Scalar),
                ("A", Data(In)),
                ("lda", Ld),
                ("B", Data(InOut)),
                ("ldb", Ld),
            ],
            flops: |av| {
                let (m, n) = (av.dim("m") as f64, av.dim("n") as f64);
                if av.flag("side") == 'L' {
                    m * m * n
                } else {
                    m * n * n
                }
            },
            operand_elems: trsm_elems,
            doc: "solve op(A)*X = alpha*B or X*op(A) = alpha*B",
        });

        add(Signature {
            name: "dtrmm",
            args: &[
                ("side", Flag(LR)),
                ("uplo", Flag(UL)),
                ("transa", Flag(TT)),
                ("diag", Flag(DG)),
                ("m", Dim),
                ("n", Dim),
                ("alpha", Scalar),
                ("A", Data(In)),
                ("lda", Ld),
                ("B", Data(InOut)),
                ("ldb", Ld),
            ],
            flops: |av| {
                let (m, n) = (av.dim("m") as f64, av.dim("n") as f64);
                if av.flag("side") == 'L' {
                    m * m * n
                } else {
                    m * n * n
                }
            },
            operand_elems: trsm_elems,
            doc: "B := alpha*op(A)*B or alpha*B*op(A)",
        });

        add(Signature {
            name: "dsyrk",
            args: &[
                ("uplo", Flag(UL)),
                ("trans", Flag(TT)),
                ("n", Dim),
                ("k", Dim),
                ("alpha", Scalar),
                ("A", Data(In)),
                ("lda", Ld),
                ("beta", Scalar),
                ("C", Data(InOut)),
                ("ldc", Ld),
            ],
            flops: |av| av.dim("n") as f64 * (av.dim("n") + 1) as f64 * av.dim("k") as f64,
            operand_elems: |av, ord| {
                let (n, k) = (av.dim("n"), av.dim("k"));
                match ord {
                    0 => av.dim("lda") * if av.flag("trans") == 'N' { k } else { n },
                    _ => av.dim("ldc") * n,
                }
            },
            doc: "C := alpha*A*A' + beta*C (symmetric rank-k update)",
        });

        add(Signature {
            name: "dgemv",
            args: &[
                ("trans", Flag(TT)),
                ("m", Dim),
                ("n", Dim),
                ("alpha", Scalar),
                ("A", Data(In)),
                ("lda", Ld),
                ("x", Data(In)),
                ("incx", Inc),
                ("beta", Scalar),
                ("y", Data(InOut)),
                ("incy", Inc),
            ],
            flops: |av| 2.0 * av.dim("m") as f64 * av.dim("n") as f64,
            operand_elems: |av, ord| {
                let (m, n) = (av.dim("m"), av.dim("n"));
                let (xl, yl) = if av.flag("trans") == 'N' { (n, m) } else { (m, n) };
                match ord {
                    0 => av.dim("lda") * n,
                    1 => xl * av.dim("incx"),
                    _ => yl * av.dim("incy"),
                }
            },
            doc: "y := alpha*op(A)*x + beta*y",
        });

        add(Signature {
            name: "dtrsv",
            args: &[
                ("uplo", Flag(UL)),
                ("trans", Flag(TT)),
                ("diag", Flag(DG)),
                ("n", Dim),
                ("A", Data(In)),
                ("lda", Ld),
                ("x", Data(InOut)),
                ("incx", Inc),
            ],
            flops: |av| av.dim("n") as f64 * av.dim("n") as f64,
            operand_elems: |av, ord| match ord {
                0 => av.dim("lda") * av.dim("n"),
                _ => av.dim("n") * av.dim("incx"),
            },
            doc: "solve op(A)*x = b (single right-hand side)",
        });

        add(Signature {
            name: "dgetrf",
            args: &[("m", Dim), ("n", Dim), ("A", Data(InOut)), ("lda", Ld)],
            flops: |av| {
                let (m, n) = (av.dim("m") as f64, av.dim("n") as f64);
                let k = m.min(n);
                m * n * k - (m + n) * k * k / 2.0 + k * k * k / 3.0
            },
            operand_elems: |av, _| av.dim("lda") * av.dim("n"),
            doc: "LU factorization with partial pivoting (pivots internal)",
        });

        add(Signature {
            name: "dgesv",
            args: &[
                ("n", Dim),
                ("nrhs", Dim),
                ("A", Data(InOut)),
                ("lda", Ld),
                ("B", Data(InOut)),
                ("ldb", Ld),
            ],
            flops: |av| {
                let n = av.dim("n") as f64;
                let r = av.dim("nrhs") as f64;
                2.0 / 3.0 * n * n * n + 2.0 * n * n * r
            },
            operand_elems: |av, ord| match ord {
                0 => av.dim("lda") * av.dim("n"),
                _ => av.dim("ldb") * av.dim("nrhs"),
            },
            doc: "solve A*X = B via LU with partial pivoting",
        });

        add(Signature {
            name: "dpotrf",
            args: &[("uplo", Flag(UL)), ("n", Dim), ("A", Data(InOut)), ("lda", Ld)],
            flops: |av| {
                let n = av.dim("n") as f64;
                n * n * n / 3.0
            },
            operand_elems: square_elems,
            doc: "Cholesky factorization",
        });

        add(Signature {
            name: "dpotrs",
            args: &[
                ("uplo", Flag(UL)),
                ("n", Dim),
                ("nrhs", Dim),
                ("A", Data(In)),
                ("lda", Ld),
                ("B", Data(InOut)),
                ("ldb", Ld),
            ],
            flops: |av| 2.0 * av.dim("n") as f64 * av.dim("n") as f64 * av.dim("nrhs") as f64,
            operand_elems: |av, ord| match ord {
                0 => av.dim("lda") * av.dim("n"),
                _ => av.dim("ldb") * av.dim("nrhs"),
            },
            doc: "solve A*X = B given the Cholesky factor",
        });

        add(Signature {
            name: "dposv",
            args: &[
                ("uplo", Flag(UL)),
                ("n", Dim),
                ("nrhs", Dim),
                ("A", Data(InOut)),
                ("lda", Ld),
                ("B", Data(InOut)),
                ("ldb", Ld),
            ],
            flops: |av| {
                let n = av.dim("n") as f64;
                let r = av.dim("nrhs") as f64;
                n * n * n / 3.0 + 2.0 * n * n * r
            },
            operand_elems: |av, ord| match ord {
                0 => av.dim("lda") * av.dim("n"),
                _ => av.dim("ldb") * av.dim("nrhs"),
            },
            doc: "Cholesky factorization + solve",
        });

        add(Signature {
            name: "dtrtri",
            args: &[
                ("uplo", Flag(UL)),
                ("diag", Flag(DG)),
                ("n", Dim),
                ("A", Data(InOut)),
                ("lda", Ld),
            ],
            flops: |av| {
                let n = av.dim("n") as f64;
                n * n * n / 3.0
            },
            operand_elems: square_elems,
            doc: "triangular matrix inversion (blocked)",
        });

        add(Signature {
            name: "dtrti2",
            args: &[
                ("uplo", Flag(UL)),
                ("diag", Flag(DG)),
                ("n", Dim),
                ("A", Data(InOut)),
                ("lda", Ld),
            ],
            flops: |av| {
                let n = av.dim("n") as f64;
                n * n * n / 3.0
            },
            operand_elems: square_elems,
            doc: "triangular matrix inversion (unblocked)",
        });

        add(Signature {
            name: "dsyev",
            args: EIG_ARGS,
            flops: eig_flops,
            operand_elems: eig_elems,
            doc: "symmetric eigensolver (QL/QR iteration)",
        });
        add(Signature {
            name: "dsyevd",
            args: EIG_ARGS,
            flops: eig_flops,
            operand_elems: eig_elems,
            doc: "symmetric eigensolver (divide & conquer)",
        });
        add(Signature {
            name: "dsyevx",
            args: EIG_ARGS,
            flops: eig_flops,
            operand_elems: eig_elems,
            doc: "symmetric eigensolver (bisection + inverse iteration)",
        });
        add(Signature {
            name: "dsyevr",
            args: EIG_ARGS,
            flops: eig_flops,
            operand_elems: eig_elems,
            doc: "symmetric eigensolver (MRRR-style)",
        });

        add(Signature {
            name: "dtrsyl",
            args: &[
                ("transa", Flag(TT)),
                ("transb", Flag(TT)),
                ("isgn", Dim),
                ("m", Dim),
                ("n", Dim),
                ("A", Data(In)),
                ("lda", Ld),
                ("B", Data(In)),
                ("ldb", Ld),
                ("C", Data(InOut)),
                ("ldc", Ld),
            ],
            flops: |av| {
                let (m, n) = (av.dim("m") as f64, av.dim("n") as f64);
                m * n * (m + n)
            },
            operand_elems: |av, ord| match ord {
                0 => av.dim("lda") * av.dim("m"),
                1 => av.dim("ldb") * av.dim("n"),
                _ => av.dim("ldc") * av.dim("n"),
            },
            doc: "triangular Sylvester equation A*X + X*B = C",
        });

        m
    })
}

/// Look up a kernel signature by name.
pub fn lookup(name: &str) -> Option<&'static Signature> {
    registry().get(name)
}

/// Derive default leading dimensions for a kernel given its dimension
/// arguments — the "automatically derive connected arguments" feature
/// of the paper's Signatures.
pub fn default_ld(sig: &Signature, dims: &[(String, usize)]) -> BTreeMap<String, usize> {
    let get = |n: &str| dims.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
    let mut out = BTreeMap::new();
    match sig.name {
        "dgemm" => {
            if let (Some(m), Some(k)) = (get("m"), get("k")) {
                out.insert("lda".into(), m.max(1));
                out.insert("ldb".into(), k.max(1));
                out.insert("ldc".into(), m.max(1));
            }
        }
        "dtrsm" | "dtrmm" => {
            if let (Some(m), Some(n)) = (get("m"), get("n")) {
                out.insert("lda".into(), m.max(n).max(1));
                out.insert("ldb".into(), m.max(1));
            }
        }
        "dtrsyl" => {
            if let (Some(m), Some(n)) = (get("m"), get("n")) {
                out.insert("lda".into(), m.max(1));
                out.insert("ldb".into(), n.max(1));
                out.insert("ldc".into(), m.max(1));
            }
        }
        "dgemv" => {
            if let Some(m) = get("m") {
                out.insert("lda".into(), m.max(1));
            }
        }
        _ => {
            if let Some(n) = get("n") {
                out.insert("lda".into(), n.max(1));
                out.insert("ldb".into(), n.max(1));
                out.insert("ldc".into(), n.max(1));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn parse_vals(sig: &'static Signature, toks: &[&str]) -> ArgValues {
        assert_eq!(sig.args.len(), toks.len(), "{}: token count", sig.name);
        let values: Vec<ArgValue> = sig
            .args
            .iter()
            .zip(toks)
            .map(|((_, role), t)| match role {
                Flag(_) => ArgValue::Char(t.chars().next().unwrap()),
                Dim | Ld | Inc => ArgValue::Size(t.parse().unwrap()),
                Scalar => ArgValue::Num(t.parse().unwrap()),
                Data(_) => ArgValue::Data(t.to_string()),
            })
            .collect();
        ArgValues { sig, values }
    }

    #[test]
    fn registry_has_all_experiment_kernels() {
        for k in [
            "dgemm", "dtrsm", "dtrmm", "dsyrk", "dgemv", "dtrsv", "dgetrf", "dgesv",
            "dpotrf", "dpotrs", "dposv", "dtrtri", "dtrti2", "dsyev", "dsyevd", "dsyevx",
            "dsyevr", "dtrsyl",
        ] {
            assert!(lookup(k).is_some(), "missing {k}");
        }
    }

    #[test]
    fn gemm_flops_and_operands() {
        let sig = lookup("dgemm").unwrap();
        let av = parse_vals(
            sig,
            &["N", "N", "1000", "1000", "1000", "1.0", "A", "1000", "B", "1000", "0.0", "C", "1000"],
        );
        assert_eq!(av.flops(), 2e9);
        assert_eq!(av.operand_elems(0), 1_000_000);
        assert_eq!(av.operand_elems(1), 1_000_000);
        assert_eq!(av.operand_elems(2), 1_000_000);
        assert_eq!(av.data_args().len(), 3);
        assert_eq!(av.data_args()[2].1, "C");
    }

    #[test]
    fn gemm_transposed_operand_sizes() {
        let sig = lookup("dgemm").unwrap();
        let av = parse_vals(
            sig,
            &["T", "N", "100", "50", "200", "1.0", "A", "200", "B", "200", "0.0", "C", "100"],
        );
        // A is 200×100 stored with lda=200
        assert_eq!(av.operand_elems(0), 200 * 100);
        assert_eq!(av.operand_elems(1), 200 * 50);
        assert_eq!(av.operand_elems(2), 100 * 50);
    }

    #[test]
    fn trsm_flops_side_dependent() {
        let sig = lookup("dtrsm").unwrap();
        let left =
            parse_vals(sig, &["L", "L", "N", "N", "10", "100", "1.0", "A", "10", "B", "10"]);
        let right =
            parse_vals(sig, &["R", "L", "N", "N", "10", "100", "1.0", "A", "100", "B", "10"]);
        assert_eq!(left.flops(), 10.0 * 10.0 * 100.0);
        assert_eq!(right.flops(), 10.0 * 100.0 * 100.0);
    }

    #[test]
    fn traffic_counts_inout_twice() {
        let sig = lookup("dpotrf").unwrap();
        let av = parse_vals(sig, &["L", "100", "A", "100"]);
        assert_eq!(av.traffic_bytes(), 2.0 * 8.0 * 100.0 * 100.0);
    }

    #[test]
    fn default_lds() {
        let sig = lookup("dgemm").unwrap();
        let lds = default_ld(sig, &[("m".into(), 30), ("k".into(), 20)]);
        assert_eq!(lds["lda"], 30);
        assert_eq!(lds["ldb"], 20);
        assert_eq!(lds["ldc"], 30);
    }

    #[test]
    fn eig_flops_jobz_dependent() {
        let sig = lookup("dsyev").unwrap();
        let v = parse_vals(sig, &["V", "L", "100", "A", "100", "W"]);
        let n = parse_vals(sig, &["N", "L", "100", "A", "100", "W"]);
        assert!(v.flops() > n.flops());
    }

    #[test]
    fn flag_feasible_values_exposed() {
        let sig = lookup("dtrsm").unwrap();
        match sig.args[0].1 {
            Flag(vals) => assert_eq!(vals, &['L', 'R']),
            _ => panic!("side should be a flag"),
        }
    }
}
