//! The parallel experiment execution engine — the subsystem between the
//! [`crate::coordinator::Experiment`] abstraction and the
//! [`crate::sampler`]s.
//!
//! The paper's whole workflow (§2, §3.2.1–3.2.2) is running *many*
//! sampler invocations: one per repetition × parameter-range point ×
//! thread count, across whole figure campaigns. The engine turns that
//! into a scheduled workload:
//!
//! * **sharding** ([`batch`]) — an experiment's unrolled points (and,
//!   for batches, the points of *all* submitted experiments) are pushed
//!   into one shared [`queue::WorkQueue`] and drained by a configurable
//!   pool of OS threads;
//! * **determinism** — every worker constructs its samplers locally,
//!   one *fresh* sampler per point (exactly the serial semantics: the
//!   paper starts the sampler separately per range value / thread
//!   count), and results are merged back by point index, so a parallel
//!   run is structurally identical — same point order, record counts,
//!   simulated counters, flop counts and OpenMP groups — to `--jobs 1`;
//! * **warm execution** ([`EngineConfig::warm`], CLI `--warm`, env
//!   `ELAPS_WARM=1`) — each worker instead reuses one long-lived
//!   sampler across the points it executes, carrying simulated cache
//!   state between points ([`crate::sampler::Sampler::reset_warm`]) to
//!   model back-to-back campaign runs (the warm/cold distinction the
//!   paper controls with operand variation and `flush_caches`). Because
//!   results now depend on execution order, warm scheduling abandons
//!   the dynamic FIFO for deterministic contiguous-block sharding by
//!   worker index ([`queue::shard_contiguous`]): the point sequence
//!   each worker executes is a pure function of `(experiments, jobs)`,
//!   two warm runs with the same seed and the same `--jobs` are
//!   byte-identical, and `--jobs 1` reproduces strict serial
//!   back-to-back order. Warm cache entries use chained, `w`-prefixed
//!   keys and `warm` envelope provenance so they never mix with cold
//!   entries;
//! * **fixed-seed reproducibility** ([`EngineConfig::seed`], CLI
//!   `--seed S`, env `ELAPS_SEED`) — samplers are seeded and report the
//!   machine model's cache-aware time prediction instead of measured
//!   wall time, making whole runs bit-reproducible (the foundation of
//!   the warm determinism contract above and of the differential test
//!   harness in `rust/tests/warm_determinism.rs`);
//! * **result caching** ([`cache`]) — a content-addressed on-disk cache
//!   keyed by the fingerprint of (library, machine model, nreps,
//!   unrolled script) lets re-runs and overlapping sweeps skip
//!   already-measured points;
//! * **cache-aware scheduling** — [`batch`] probes the cache *before*
//!   enqueueing: fully-cached experiments bypass the worker pool
//!   entirely, partially-cached ones enqueue only their misses, and the
//!   hit/miss/skip accounting comes back in [`BatchStats`];
//! * **cache lifecycle** ([`gc`]) — `elaps cache {stats,gc,clear}`:
//!   entry/byte/age statistics and an LRU-by-atime (mtime fallback)
//!   sweep that keeps the cache under a byte budget;
//! * **batch submission** — [`Engine::run_batch`] schedules whole
//!   campaigns (the `elaps batch` command, the `elaps figures`
//!   campaign built by [`crate::figures`]) through one queue instead of
//!   one experiment at a time.
//!
//! [`crate::coordinator::run_local`] routes through the engine with the
//! process-default configuration ([`default_config`]), which the CLI
//! sets from `--jobs N --cache DIR` and which honours the `ELAPS_JOBS`
//! / `ELAPS_CACHE` environment variables (used by the bench binaries).
//!
//! **Timing caveat and provenance.** Structure is deterministic,
//! wall-clock is not: with `--jobs > 1` concurrently executing kernels
//! contend for cores and memory bandwidth, which inflates the measured
//! `seconds`/`cycles` of each point. Use parallel runs for campaign
//! exploration and functional sweeps; measure publication timings with
//! `--jobs 1`. The simulated PAPI counters, flop counts and record
//! structure are unaffected either way. To keep a shared cache honest,
//! every entry is stored inside a versioned envelope
//! `{schema, jobs, created_unix, result}` recording the worker-pool
//! width (`jobs`) that measured it — see [`cache`]. The
//! timing-provenance rule: **trust only `jobs ≤ 1` entries for
//! publication timings**. [`EngineConfig::trusted_only`] (CLI
//! `--trusted-only`, env `ELAPS_TRUSTED_ONLY=1`) enforces the rule at
//! lookup time, turning contended and legacy (pre-envelope,
//! provenance-unknown) entries into misses that are re-measured.

pub mod batch;
pub mod cache;
pub mod gc;
pub mod queue;

pub use cache::{CacheEnvelope, ResultCache};
pub use queue::{shard_contiguous, WorkQueue};

use crate::coordinator::experiment::{Experiment, UnrolledPoint};
use crate::coordinator::report::{PointResult, Report};
use crate::libraries::KernelLibrary;
use crate::perfmodel::MachineModel;
use crate::sampler::Sampler;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock, RwLock};

/// Engine configuration: worker-pool width, result-cache location,
/// cache trust policy, and the warm-execution / deterministic-seed
/// axes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads; 0 and 1 both mean serial execution.
    pub jobs: usize,
    /// Result-cache directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Serve cache hits only from entries proven to be measured without
    /// worker contention (`jobs ≤ 1`); contended and legacy entries are
    /// re-measured. See the module docs' timing-provenance rule.
    pub trusted_only: bool,
    /// Warm execution: each worker reuses one long-lived sampler across
    /// the points it executes, carrying simulated cache state between
    /// points ([`crate::sampler::Sampler::reset_warm`]) to model
    /// back-to-back campaign runs. Scheduling switches from the dynamic
    /// FIFO to deterministic contiguous-block sharding by worker index
    /// ([`queue::shard_contiguous`]), so each worker's point sequence —
    /// and therefore its carried state — is a pure function of
    /// `(experiments, jobs)`.
    pub warm: bool,
    /// Fully deterministic runs: samplers are seeded with this value
    /// and report the machine model's cache-aware time prediction
    /// instead of measured wall time
    /// ([`crate::sampler::Sampler::deterministic`]). Two runs with the
    /// same seed, experiments, `warm` and `jobs` produce byte-identical
    /// reports. Seeded measurements are cached under seed-specific keys
    /// so they never mix with wall-clock entries.
    pub seed: Option<u64>,
}

impl EngineConfig {
    pub fn with_jobs(mut self, jobs: usize) -> EngineConfig {
        self.jobs = jobs;
        self
    }

    pub fn with_cache(mut self, dir: impl Into<PathBuf>) -> EngineConfig {
        self.cache_dir = Some(dir.into());
        self
    }

    pub fn with_trusted_only(mut self, trusted_only: bool) -> EngineConfig {
        self.trusted_only = trusted_only;
        self
    }

    pub fn with_warm(mut self, warm: bool) -> EngineConfig {
        self.warm = warm;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> EngineConfig {
        self.seed = Some(seed);
        self
    }

    /// Configuration from the `ELAPS_JOBS` / `ELAPS_CACHE` /
    /// `ELAPS_TRUSTED_ONLY` / `ELAPS_WARM` / `ELAPS_SEED` environment
    /// variables (unset, empty or unparsable values fall back to the
    /// serial, uncached, cold, trust-everything default).
    pub fn from_env() -> EngineConfig {
        let truthy = |name: &str| {
            std::env::var(name)
                .map(|v| {
                    let v = v.trim().to_ascii_lowercase();
                    v == "1" || v == "true" || v == "yes"
                })
                .unwrap_or(false)
        };
        let jobs = std::env::var("ELAPS_JOBS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(1);
        let cache_dir = std::env::var("ELAPS_CACHE")
            .ok()
            .filter(|v| !v.trim().is_empty())
            .map(PathBuf::from);
        let seed = std::env::var("ELAPS_SEED").ok().and_then(|v| v.trim().parse().ok());
        EngineConfig {
            jobs,
            cache_dir,
            trusted_only: truthy("ELAPS_TRUSTED_ONLY"),
            warm: truthy("ELAPS_WARM"),
            seed,
        }
    }
}

/// Execution statistics of one engine run or batch: the hit/miss/skip
/// accounting behind the CLI's summary line.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Experiments submitted.
    pub experiments: usize,
    /// Experiments whose every point was served from the cache by the
    /// pre-enqueue probe — they bypassed the worker pool entirely.
    pub fully_cached: usize,
    /// Points whose sampler scripts were actually executed (misses).
    pub executed: usize,
    /// Points served from the result cache without touching a sampler
    /// (scheduled probe hits plus hits a worker observed late).
    pub cache_hits: usize,
    /// The subset of `cache_hits` discovered by the pre-enqueue probe,
    /// i.e. points that were never enqueued at all.
    pub scheduled_hits: usize,
    /// Worker threads used.
    pub jobs: usize,
    /// Whether the run executed in warm mode (per-worker sampler reuse
    /// with deterministic sharding).
    pub warm: bool,
    /// Hostname the batch executed on — the provenance the multi-host
    /// spooler extends from jobs to `(host, worker)`; empty when
    /// unknown (hand-built stats).
    pub host: String,
}

impl BatchStats {
    pub fn total_points(&self) -> usize {
        self.executed + self.cache_hits
    }

    /// The run-summary line, e.g. `engine: 12 point(s) on 1 worker(s) —
    /// 0 executed, 12 cache hit(s) (12 scheduled), 3/3 experiment(s)
    /// fully cached`.
    pub fn summary_line(&self) -> String {
        let mut line = format!(
            "engine: {} point(s) on {} worker(s) — {} executed, {} cache hit(s)",
            self.total_points(),
            self.jobs.max(1),
            self.executed,
            self.cache_hits
        );
        if self.cache_hits > 0 {
            line += &format!(" ({} scheduled)", self.scheduled_hits);
        }
        if self.experiments > 0 {
            line += &format!(
                ", {}/{} experiment(s) fully cached",
                self.fully_cached, self.experiments
            );
        }
        if !self.host.is_empty() {
            line += &format!(" @{}", self.host);
        }
        if self.warm {
            line += " [warm]";
        }
        line
    }
}

/// The execution engine. Cheap to construct; all state lives on disk
/// (the cache) or per-run (the worker pool).
pub struct Engine {
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        Engine { cfg }
    }

    /// An engine with the process-default configuration (see
    /// [`default_config`]).
    pub fn with_defaults() -> Engine {
        Engine::new(default_config())
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Run one experiment.
    pub fn run(&self, exp: &Experiment) -> Result<Report> {
        self.run_stats(exp).map(|(report, _)| report)
    }

    /// Run one experiment, returning execution statistics alongside.
    pub fn run_stats(&self, exp: &Experiment) -> Result<(Report, BatchStats)> {
        let (mut reports, stats) =
            batch::run_batch_stats(&self.cfg, std::slice::from_ref(exp))?;
        let report = reports.pop().expect("one report per experiment");
        Ok((report, stats))
    }

    /// Run a whole campaign through one scheduler; reports come back in
    /// input order.
    pub fn run_batch(&self, exps: &[Experiment]) -> Result<Vec<Report>> {
        batch::run_batch_stats(&self.cfg, exps).map(|(reports, _)| reports)
    }

    /// [`Engine::run_batch`] with execution statistics.
    pub fn run_batch_stats(&self, exps: &[Experiment]) -> Result<(Vec<Report>, BatchStats)> {
        batch::run_batch_stats(&self.cfg, exps)
    }
}

/// Execute one unrolled point on a fresh sampler.
///
/// This is the cold-mode point-execution primitive: the serial path,
/// every cold engine worker and the spooler all funnel through it. A
/// *fresh* sampler per point (not per worker) keeps the simulated cache
/// counters, RNG stream and OpenMP group ids bit-identical to serial
/// execution regardless of which worker picks the point up.
pub fn execute_point(
    library: &Arc<dyn KernelLibrary>,
    machine: &MachineModel,
    exp: &Experiment,
    point: &UnrolledPoint,
) -> Result<PointResult> {
    execute_point_with(library, machine, exp, point, None)
}

/// [`execute_point`] with an optional deterministic seed: seeded runs
/// use seeded operand data and the machine model's deterministic time
/// prediction ([`crate::sampler::Sampler::deterministic`]), so they are
/// bit-reproducible.
pub fn execute_point_with(
    library: &Arc<dyn KernelLibrary>,
    machine: &MachineModel,
    exp: &Experiment,
    point: &UnrolledPoint,
    seed: Option<u64>,
) -> Result<PointResult> {
    let mut sampler = Sampler::new(Arc::clone(library), machine.clone());
    if let Some(seed) = seed {
        sampler = sampler.deterministic(seed);
    }
    execute_point_on(&mut sampler, exp, point)
}

/// Execute one unrolled point on an existing sampler — the warm-mode
/// primitive. The caller controls the sampler's state: fresh (cold
/// semantics) or carrying simulated cache contents from the previous
/// point via [`crate::sampler::Sampler::reset_warm`].
pub fn execute_point_on(
    sampler: &mut Sampler,
    exp: &Experiment,
    point: &UnrolledPoint,
) -> Result<PointResult> {
    let records = sampler
        .run_script(&point.script)
        .with_context(|| format!("point {} of '{}'", point.range_value, exp.name))?;
    let expected = point.expected_records(exp.nreps);
    if records.len() != expected {
        bail!(
            "point {}: sampler produced {} records, expected {expected}",
            point.range_value,
            records.len()
        );
    }
    Ok(PointResult {
        range_value: point.range_value,
        nthreads: point.nthreads,
        sum_iters: point.sum_iters,
        calls_per_iter: point.calls_per_iter,
        records,
    })
}

// ------------------------------------------------ process-default config

static DEFAULT: OnceLock<RwLock<EngineConfig>> = OnceLock::new();

fn default_cell() -> &'static RwLock<EngineConfig> {
    DEFAULT.get_or_init(|| RwLock::new(EngineConfig::from_env()))
}

/// The process-default engine configuration used by
/// [`crate::coordinator::run_local`]. Initialized from the environment
/// ([`EngineConfig::from_env`]) on first use.
pub fn default_config() -> EngineConfig {
    default_cell().read().unwrap().clone()
}

/// Override the process-default engine configuration (the CLI's
/// `--jobs` / `--cache` flags call this so that every `run_local` in
/// the process — including figure builders and spooler workers — routes
/// through the same pool and cache).
pub fn set_default_config(cfg: EngineConfig) {
    *default_cell().write().unwrap() = cfg;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;

    #[test]
    fn run_stats_counts_points() {
        let mut exp = dgemm_experiment(20);
        exp.nreps = 2;
        exp.range = Some(crate::coordinator::RangeDef::new("unused", vec![1, 2, 3]));
        // range sym unused by the call: still one point per value
        let engine = Engine::new(EngineConfig::default().with_jobs(2));
        let (report, stats) = engine.run_stats(&exp).unwrap();
        assert_eq!(report.points.len(), 3);
        assert_eq!(stats.executed, 3);
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.total_points(), 3);
        assert!(stats.summary_line().contains("3 executed"));
        // provenance: the batch records the executing host
        assert_eq!(stats.host, crate::util::hostid::hostname());
        assert!(stats.summary_line().contains(&format!("@{}", stats.host)));
    }

    #[test]
    fn config_builders() {
        let cfg = EngineConfig::default()
            .with_jobs(4)
            .with_cache("/tmp/x")
            .with_trusted_only(true)
            .with_warm(true)
            .with_seed(7);
        assert_eq!(cfg.jobs, 4);
        assert_eq!(cfg.cache_dir.as_deref(), Some(std::path::Path::new("/tmp/x")));
        assert!(cfg.trusted_only);
        assert!(cfg.warm);
        assert_eq!(cfg.seed, Some(7));
        let default = EngineConfig::default();
        assert!(!default.trusted_only);
        assert!(!default.warm, "cold execution stays the default");
        assert_eq!(default.seed, None);
    }

    #[test]
    fn warm_summary_line_is_marked() {
        let stats = BatchStats { warm: true, ..Default::default() };
        assert!(stats.summary_line().ends_with("[warm]"));
        assert!(!BatchStats::default().summary_line().contains("[warm]"));
    }
}
