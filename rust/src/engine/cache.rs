//! Content-addressed result cache: fingerprint of (library, machine
//! model, repetitions, unrolled point) → stored [`PointResult`] on
//! disk. Re-runs and overlapping sweep campaigns skip already-measured
//! points entirely — the paper's sweeps (§2.4, §3.2.1) routinely share
//! points between figure campaigns.
//!
//! The fingerprint hashes the *unrolled sampler script*, not the
//! experiment description: the script is the canonical form after all
//! symbolic ranges are evaluated, so two different experiments that
//! unroll to the same measurement share a cache entry, while any change
//! to operand sizes, vary specs, counters or thread counts changes the
//! script and therefore the key.

use crate::coordinator::experiment::UnrolledPoint;
use crate::coordinator::io;
use crate::coordinator::report::PointResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// On-disk cache of measured points, one JSON file per fingerprint.
pub struct ResultCache {
    dir: PathBuf,
}

/// 64-bit FNV-1a (the registry provides no hashing crates; this is the
/// standard offset-basis/prime pair).
fn fnv1a64(basis: u64, data: &[u8]) -> u64 {
    let mut h = basis;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl ResultCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<ResultCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(ResultCache { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Content fingerprint of one measurement point. Two independent
    /// FNV-1a passes (the second chained on the first) give a 128-bit
    /// key — ample for campaign-scale point counts.
    pub fn fingerprint(
        library: &str,
        machine: &str,
        nreps: usize,
        point: &UnrolledPoint,
    ) -> String {
        let desc = format!(
            "library={library}\nmachine={machine}\nnreps={nreps}\n\
             range_value={}\nnthreads={}\nsum_iters={}\ncalls_per_iter={}\nscript:\n{}",
            point.range_value, point.nthreads, point.sum_iters, point.calls_per_iter,
            point.script
        );
        let lo = fnv1a64(0xcbf2_9ce4_8422_2325, desc.as_bytes());
        let hi = fnv1a64(lo ^ 0x9e37_79b9_7f4a_7c15, desc.as_bytes());
        format!("{hi:016x}{lo:016x}")
    }

    fn entry_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Look up a cached point. Entries whose stored record count does
    /// not match `expected_records` (e.g. written by an older run with
    /// different semantics, or truncated) are treated as misses.
    pub fn lookup(&self, key: &str, expected_records: usize) -> Option<PointResult> {
        let text = std::fs::read_to_string(self.entry_path(key)).ok()?;
        let j = Json::parse(&text).ok()?;
        let p = io::point_result_from_json(&j);
        if p.records.len() == expected_records {
            Some(p)
        } else {
            None
        }
    }

    /// Store a measured point atomically (unique temp file + rename),
    /// so concurrent workers racing on the same key never expose a
    /// partially written entry — last writer wins.
    pub fn store(&self, key: &str, point: &PointResult) -> Result<()> {
        let path = self.entry_path(key);
        let tmp = self.dir.join(format!(
            "{key}.{}.{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, io::point_result_to_json(point).to_string_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Number of entries currently stored.
    pub fn entries(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                    .count()
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::sampler::Record;

    fn point() -> UnrolledPoint {
        dgemm_experiment(16).unroll().unwrap().remove(0)
    }

    fn result(nrecords: usize) -> PointResult {
        PointResult {
            range_value: 0,
            nthreads: 1,
            sum_iters: 1,
            calls_per_iter: 1,
            records: (0..nrecords)
                .map(|i| Record {
                    kernel: "dgemm".into(),
                    seconds: 0.001 * (i + 1) as f64,
                    cycles: 2.6e6 * (i + 1) as f64,
                    counters: vec![i as u64],
                    omp_group: None,
                    flops: 2.0 * 16.0 * 16.0 * 16.0,
                })
                .collect(),
        }
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let p = point();
        let k1 = ResultCache::fingerprint("rustblocked", "localhost", 3, &p);
        let k2 = ResultCache::fingerprint("rustblocked", "localhost", 3, &p);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 32);
        // any input component changes the key
        assert_ne!(k1, ResultCache::fingerprint("rustref", "localhost", 3, &p));
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "sandybridge", 3, &p));
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "localhost", 4, &p));
        let other = dgemm_experiment(32).unroll().unwrap().remove(0);
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "localhost", 3, &other));
    }

    #[test]
    fn store_lookup_roundtrip_and_count_validation() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let key = ResultCache::fingerprint("rustblocked", "localhost", 3, &point());
        assert!(cache.lookup(&key, 3).is_none());
        cache.store(&key, &result(3)).unwrap();
        assert_eq!(cache.entries(), 1);
        let hit = cache.lookup(&key, 3).unwrap();
        assert_eq!(hit.records.len(), 3);
        assert_eq!(hit.records[2].counters, vec![2]);
        assert!((hit.records[1].seconds - 0.002).abs() < 1e-12);
        // a mismatching expected count is a miss, not a wrong answer
        assert!(cache.lookup(&key, 5).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
