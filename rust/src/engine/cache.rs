//! Content-addressed result cache: fingerprint of (library, machine
//! model, repetitions, unrolled point) → stored [`PointResult`] on
//! disk. Re-runs and overlapping sweep campaigns skip already-measured
//! points entirely — the paper's sweeps (§2.4, §3.2.1) routinely share
//! points between figure campaigns.
//!
//! The fingerprint hashes the *unrolled sampler script*, not the
//! experiment description: the script is the canonical form after all
//! symbolic ranges are evaluated, so two different experiments that
//! unroll to the same measurement share a cache entry, while any change
//! to operand sizes, vary specs, counters or thread counts changes the
//! script and therefore the key.
//!
//! **Entry format (envelope schema 3).** Each entry is a JSON object
//! `{schema, jobs, warm, host, worker, created_unix, result}`
//! ([`CacheEnvelope`]): `jobs` records the worker-pool width of the
//! measuring run (the timing provenance — entries measured with
//! `jobs > 1` carry contention-inflated wall times), `warm` the
//! sampler-reuse mode, `host`/`worker` which machine and worker
//! process measured it (the multi-host provenance shared NFS caches
//! need; `elaps cache stats` breaks entries down by host),
//! `created_unix` the store time, and `result` the [`PointResult`]
//! payload. Legacy pre-envelope entries (a bare point object) remain
//! readable with unknown provenance. Corrupt, truncated or
//! unknown-schema files are cache *misses*, never errors. With
//! [`ResultCache::with_trusted_only`], lookups additionally reject
//! every entry that cannot prove `jobs ≤ 1`.

use crate::coordinator::experiment::UnrolledPoint;
use crate::coordinator::io;
use crate::coordinator::report::PointResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

pub use crate::coordinator::io::{CacheEnvelope, CACHE_ENTRY_SCHEMA};

/// On-disk cache of measured points, one JSON file per fingerprint.
pub struct ResultCache {
    dir: PathBuf,
    /// Provenance recorded on every `store`: the worker-pool width of
    /// the run producing the entries.
    store_jobs: usize,
    /// Whether this handle operates in warm-execution mode: entries are
    /// stored with `warm` provenance, and `lookup` serves only entries
    /// whose flag matches — warm and cold measurements never
    /// cross-contaminate (their keys are already disjoint, see
    /// [`ResultCache::warm_fingerprint`]; the flag check is the
    /// belt-and-braces for hand-edited caches).
    warm: bool,
    /// When set, `lookup` serves only entries proven to be measured
    /// without worker contention (`jobs ≤ 1`).
    trusted_only: bool,
    /// Whether this handle's keys are seed-specific (the run executes
    /// with modeled timings). Seeded entries are pure functions of the
    /// script, so trusted-only mode serves them regardless of the
    /// worker-pool width that produced them.
    seeded: bool,
    /// Host/worker provenance recorded on every `store` (schema-3
    /// envelope fields). Defaults to this process on this host.
    host: String,
    worker: String,
}

/// 64-bit FNV-1a (the registry provides no hashing crates; this is the
/// standard offset-basis/prime pair).
fn fnv1a64(basis: u64, data: &[u8]) -> u64 {
    let mut h = basis;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl ResultCache {
    /// Open (creating if needed) a cache directory. Entries are stored
    /// with `jobs: 1` provenance and served regardless of provenance
    /// unless the builders below say otherwise.
    pub fn open(dir: impl AsRef<Path>) -> Result<ResultCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(ResultCache {
            dir,
            store_jobs: 1,
            warm: false,
            trusted_only: false,
            seeded: false,
            host: crate::util::hostid::hostname().to_string(),
            worker: crate::util::hostid::new_worker_id(),
        })
    }

    /// Record `jobs` as the provenance of every entry this cache stores.
    pub fn with_provenance(mut self, jobs: usize) -> ResultCache {
        self.store_jobs = jobs;
        self
    }

    /// Override the host/worker provenance recorded on stores (the
    /// spooler stamps entries with the serving worker's lease
    /// identity; tests simulate multi-host fleets).
    pub fn with_worker(
        mut self,
        host: impl Into<String>,
        worker: impl Into<String>,
    ) -> ResultCache {
        self.host = host.into();
        self.worker = worker.into();
        self
    }

    /// Put this handle in warm mode: stores record `warm: true`
    /// provenance, and lookups serve only warm entries (a cold handle
    /// symmetrically serves only cold ones).
    pub fn with_warm(mut self, warm: bool) -> ResultCache {
        self.warm = warm;
        self
    }

    /// Serve only entries proven to be measured with `jobs ≤ 1`
    /// (publication-quality timings); contended and legacy entries
    /// become misses.
    pub fn with_trusted_only(mut self, trusted_only: bool) -> ResultCache {
        self.trusted_only = trusted_only;
        self
    }

    /// Mark this handle as serving a seeded (modeled-time) run. Seeded
    /// keys embed the seed and only ever match seeded entries, which
    /// are bit-reproducible pure functions of the script — so
    /// trusted-only mode accepts them even when they were produced by a
    /// contended (`jobs > 1`) pool.
    pub fn with_seeded(mut self, seeded: bool) -> ResultCache {
        self.seeded = seeded;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The canonical description text the fingerprints hash.
    fn fingerprint_desc(
        library: &str,
        machine: &str,
        nreps: usize,
        point: &UnrolledPoint,
        seed: Option<u64>,
    ) -> String {
        let mut desc = format!(
            "library={library}\nmachine={machine}\nnreps={nreps}\n\
             range_value={}\nnthreads={}\nsum_iters={}\ncalls_per_iter={}\nscript:\n{}",
            point.range_value, point.nthreads, point.sum_iters, point.calls_per_iter,
            point.script
        );
        // fixed-seed runs report modeled (deterministic) timings —
        // never interchangeable with wall-clock measurements, so the
        // seed is part of the identity. Unseeded keys are unchanged
        // from the pre-seed format: existing caches stay valid.
        if let Some(s) = seed {
            desc.push_str(&format!("\nseed={s}\nmodeled_time=1"));
        }
        desc
    }

    fn hash_desc(desc: &str) -> String {
        let lo = fnv1a64(0xcbf2_9ce4_8422_2325, desc.as_bytes());
        let hi = fnv1a64(lo ^ 0x9e37_79b9_7f4a_7c15, desc.as_bytes());
        format!("{hi:016x}{lo:016x}")
    }

    /// Content fingerprint of one measurement point. Two independent
    /// FNV-1a passes (the second chained on the first) give a 128-bit
    /// key — ample for campaign-scale point counts.
    pub fn fingerprint(
        library: &str,
        machine: &str,
        nreps: usize,
        point: &UnrolledPoint,
    ) -> String {
        Self::fingerprint_with(library, machine, nreps, point, None)
    }

    /// [`ResultCache::fingerprint`] extended with the run's
    /// deterministic seed (if any). `seed: None` reproduces the classic
    /// key byte-for-byte.
    pub fn fingerprint_with(
        library: &str,
        machine: &str,
        nreps: usize,
        point: &UnrolledPoint,
        seed: Option<u64>,
    ) -> String {
        Self::hash_desc(&Self::fingerprint_desc(library, machine, nreps, point, seed))
    }

    /// Fingerprint of one point measured in **warm** execution mode.
    ///
    /// A warm measurement depends on the simulated cache state the
    /// worker's previous points left behind, so the key chains: it
    /// hashes the point's own description *plus the warm key of the
    /// predecessor point in the same worker shard* (`prev`, `None` for
    /// the shard's first point, which starts from cold state). A warm
    /// entry therefore only ever hits when the entire executed prefix
    /// matches — and the `w` prefix keeps warm keys visibly (and
    /// structurally) disjoint from cold ones.
    pub fn warm_fingerprint(
        library: &str,
        machine: &str,
        nreps: usize,
        point: &UnrolledPoint,
        seed: Option<u64>,
        prev: Option<&str>,
    ) -> String {
        let mut desc = Self::fingerprint_desc(library, machine, nreps, point, seed);
        desc.push_str("\nwarm=1\nprev=");
        desc.push_str(prev.unwrap_or("cold-start"));
        format!("w{}", Self::hash_desc(&desc))
    }

    fn entry_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Parse a cached entry with its provenance, without applying the
    /// record-count or trust filters. Corrupt, truncated or unknown-
    /// schema files return `None`.
    pub fn lookup_entry(&self, key: &str) -> Option<CacheEnvelope> {
        let text = std::fs::read_to_string(self.entry_path(key)).ok()?;
        let j = Json::parse(&text).ok()?;
        io::cache_envelope_from_json(&j)
    }

    /// Look up a cached point. Entries whose stored record count does
    /// not match `expected_records` (e.g. written by an older run with
    /// different semantics, or truncated) are treated as misses, as are
    /// untrusted entries when the cache is in trusted-only mode.
    /// Served hits have their file times bumped so the gc sweep's LRU
    /// ordering works even on `noatime`/`relatime` mounts.
    pub fn lookup(&self, key: &str, expected_records: usize) -> Option<PointResult> {
        let env = self.lookup_entry(key)?;
        // warm and cold measurements are never interchangeable: a
        // mismatched flag is a miss even if the key somehow matched
        if env.warm != self.warm {
            return None;
        }
        // seeded entries are provably reproducible whatever pool width
        // produced them; contention can only taint measured wall time
        if self.trusted_only && !self.seeded && !env.trusted() {
            return None;
        }
        if env.result.records.len() != expected_records {
            return None;
        }
        self.touch(key);
        Some(env.result)
    }

    /// Best-effort recency bump of an entry's atime+mtime (the age
    /// shown by `cache stats` comes from the envelope's `created_unix`,
    /// which is unaffected). Failure — entry deleted by a racing gc,
    /// read-only cache — is fine: the entry just keeps its old recency.
    fn touch(&self, key: &str) {
        let now = std::time::SystemTime::now();
        let times = std::fs::FileTimes::new().set_accessed(now).set_modified(now);
        if let Ok(f) = std::fs::OpenOptions::new().write(true).open(self.entry_path(key)) {
            let _ = f.set_times(times);
        }
    }

    /// Store a measured point atomically (unique temp file + rename),
    /// so concurrent workers racing on the same key never expose a
    /// partially written entry — last writer wins. The entry carries
    /// the envelope with this cache's provenance (`with_provenance`).
    pub fn store(&self, key: &str, point: &PointResult) -> Result<()> {
        let path = self.entry_path(key);
        let tmp = self.dir.join(format!(
            "{key}.{}.{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .ok()
            .map(|d| d.as_secs());
        let j = io::cache_envelope_to_json(
            point,
            self.store_jobs,
            created,
            self.warm,
            Some(&self.host),
            Some(&self.worker),
        );
        std::fs::write(&tmp, j.to_string_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Number of entries currently stored.
    pub fn entries(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                    .count()
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::sampler::Record;

    fn point() -> UnrolledPoint {
        dgemm_experiment(16).unroll().unwrap().remove(0)
    }

    fn result(nrecords: usize) -> PointResult {
        PointResult {
            range_value: 0,
            nthreads: 1,
            sum_iters: 1,
            calls_per_iter: 1,
            records: (0..nrecords)
                .map(|i| Record {
                    kernel: "dgemm".into(),
                    seconds: 0.001 * (i + 1) as f64,
                    cycles: 2.6e6 * (i + 1) as f64,
                    counters: vec![i as u64],
                    omp_group: None,
                    flops: 2.0 * 16.0 * 16.0 * 16.0,
                })
                .collect(),
        }
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let p = point();
        let k1 = ResultCache::fingerprint("rustblocked", "localhost", 3, &p);
        let k2 = ResultCache::fingerprint("rustblocked", "localhost", 3, &p);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 32);
        // any input component changes the key
        assert_ne!(k1, ResultCache::fingerprint("rustref", "localhost", 3, &p));
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "sandybridge", 3, &p));
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "localhost", 4, &p));
        let other = dgemm_experiment(32).unroll().unwrap().remove(0);
        assert_ne!(k1, ResultCache::fingerprint("rustblocked", "localhost", 3, &other));
    }

    #[test]
    fn seed_and_warmth_change_the_key_but_unseeded_keys_are_stable() {
        let p = point();
        let classic = ResultCache::fingerprint("rustblocked", "localhost", 3, &p);
        // seed: None is byte-for-byte the classic key (old caches valid)
        assert_eq!(
            classic,
            ResultCache::fingerprint_with("rustblocked", "localhost", 3, &p, None)
        );
        let seeded = ResultCache::fingerprint_with("rustblocked", "localhost", 3, &p, Some(7));
        assert_ne!(classic, seeded);
        assert_ne!(
            seeded,
            ResultCache::fingerprint_with("rustblocked", "localhost", 3, &p, Some(8))
        );
        // warm keys: disjoint from cold, chained on the predecessor
        let w0 = ResultCache::warm_fingerprint("rustblocked", "localhost", 3, &p, None, None);
        assert!(w0.starts_with('w'));
        assert_eq!(w0.len(), 33);
        assert_ne!(&w0[1..], classic.as_str());
        let w1 =
            ResultCache::warm_fingerprint("rustblocked", "localhost", 3, &p, None, Some(&w0));
        assert_ne!(w0, w1, "a different prefix is a different measurement");
        assert_eq!(
            w1,
            ResultCache::warm_fingerprint("rustblocked", "localhost", 3, &p, None, Some(&w0)),
            "chained keys are deterministic"
        );
    }

    #[test]
    fn warm_and_cold_lookups_never_cross_contaminate() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_warmflag_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cold = ResultCache::open(&dir).unwrap();
        let warm = ResultCache::open(&dir).unwrap().with_warm(true);
        cold.store("coldkey", &result(2)).unwrap();
        warm.store("warmkey", &result(2)).unwrap();
        assert!(cold.lookup_entry("warmkey").unwrap().warm);
        assert!(!cold.lookup_entry("coldkey").unwrap().warm);
        // each handle serves only its own kind — even on the "wrong" key
        assert!(cold.lookup("coldkey", 2).is_some());
        assert!(cold.lookup("warmkey", 2).is_none());
        assert!(warm.lookup("warmkey", 2).is_some());
        assert!(warm.lookup("coldkey", 2).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_lookup_roundtrip_and_count_validation() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let key = ResultCache::fingerprint("rustblocked", "localhost", 3, &point());
        assert!(cache.lookup(&key, 3).is_none());
        cache.store(&key, &result(3)).unwrap();
        assert_eq!(cache.entries(), 1);
        let hit = cache.lookup(&key, 3).unwrap();
        assert_eq!(hit.records.len(), 3);
        assert_eq!(hit.records[2].counters, vec![2]);
        assert!((hit.records[1].seconds - 0.002).abs() < 1e-12);
        // a mismatching expected count is a miss, not a wrong answer
        assert!(cache.lookup(&key, 5).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn provenance_survives_store_and_gates_trusted_lookups() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_prov_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir)
            .unwrap()
            .with_provenance(8)
            .with_worker("nodeX", "nodeX#1-0");
        cache.store("contended", &result(3)).unwrap();
        let env = cache.lookup_entry("contended").unwrap();
        assert_eq!(env.schema, CACHE_ENTRY_SCHEMA);
        assert_eq!(env.jobs, Some(8));
        assert!(env.created_unix.is_some());
        assert!(!env.trusted());
        assert_eq!(env.host.as_deref(), Some("nodeX"));
        assert_eq!(env.worker.as_deref(), Some("nodeX#1-0"));
        // plain lookups serve it; trusted-only lookups reject it
        assert!(cache.lookup("contended", 3).is_some());
        let strict = ResultCache::open(&dir).unwrap().with_trusted_only(true);
        assert!(strict.lookup("contended", 3).is_none());
        // a jobs=1 entry passes the trust gate
        let serial = ResultCache::open(&dir).unwrap();
        serial.store("clean", &result(3)).unwrap();
        assert!(strict.lookup("clean", 3).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn served_hits_refresh_lru_recency() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_touch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        cache.store("hot", &result(2)).unwrap();
        let path = dir.join("hot.json");
        // backdate the entry, as if it were measured days ago
        let old = std::time::SystemTime::now() - std::time::Duration::from_secs(86_400);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_times(std::fs::FileTimes::new().set_accessed(old).set_modified(old)).unwrap();
        assert!(cache.lookup("hot", 2).is_some());
        // the hit bumped the file times: gc's LRU now sees it as recent
        let mtime = std::fs::metadata(&path).unwrap().modified().unwrap();
        assert!(
            mtime.elapsed().unwrap() < std::time::Duration::from_secs(3_600),
            "lookup must refresh recency"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_and_corrupt_entries() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_cache_legacy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        // PR-1 format: a bare point object, no envelope — still a hit
        let legacy_json = io::point_result_to_json(&result(2)).to_string_pretty();
        std::fs::write(dir.join("old.json"), &legacy_json).unwrap();
        let env = cache.lookup_entry("old").unwrap();
        assert_eq!((env.schema, env.jobs), (0, None));
        assert!(cache.lookup("old", 2).is_some());
        // ...but not under trusted-only: provenance is unknown
        let strict = ResultCache::open(&dir).unwrap().with_trusted_only(true);
        assert!(strict.lookup("old", 2).is_none());
        // corrupt / truncated / wrong-schema files are misses, not errors
        std::fs::write(dir.join("trunc.json"), &legacy_json[..legacy_json.len() / 2]).unwrap();
        std::fs::write(dir.join("junk.json"), "not json at all").unwrap();
        std::fs::write(dir.join("schema9.json"), r#"{"schema":9,"jobs":1,"result":{}}"#).unwrap();
        std::fs::write(dir.join("empty.json"), "").unwrap();
        for key in ["trunc", "junk", "schema9", "empty"] {
            assert!(cache.lookup(key, 2).is_none(), "{key}");
            assert!(cache.lookup_entry(key).is_none(), "{key}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
