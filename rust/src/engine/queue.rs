//! The engine's shared work queue: a FIFO that many worker threads pop
//! from concurrently. Items are enqueued up front (the unrolled points
//! of one or more experiments), so the queue doubles as the engine's
//! scheduler: whichever worker is free takes the next point.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A multi-consumer FIFO work queue.
///
/// Intentionally simple — a [`Mutex`]ed deque. The engine's work items
/// are whole sampler scripts (milliseconds to minutes each), so queue
/// contention is negligible next to the work itself.
pub struct WorkQueue<T> {
    items: Mutex<VecDeque<T>>,
}

impl<T> WorkQueue<T> {
    /// Build a queue pre-loaded with `items`, preserving their order.
    pub fn new(items: impl IntoIterator<Item = T>) -> WorkQueue<T> {
        WorkQueue { items: Mutex::new(items.into_iter().collect()) }
    }

    /// Take the next item, or `None` once the queue is drained.
    ///
    /// Draining is final: all work is enqueued before workers start, so
    /// a `None` means this worker is done (there is deliberately no
    /// late `push` — a worker that already observed an empty queue
    /// would never see such items).
    pub fn pop(&self) -> Option<T> {
        self.items.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[test]
    fn fifo_order() {
        let q = WorkQueue::new(vec![1, 2, 3]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn concurrent_pop_covers_every_item_once() {
        let n = 1000usize;
        let q = WorkQueue::new(0..n);
        let seen: StdMutex<Vec<usize>> = StdMutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(v) = q.pop() {
                        seen.lock().unwrap().push(v);
                    }
                });
            }
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }
}
