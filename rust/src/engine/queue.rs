//! The engine's shared work queue: a FIFO that many worker threads pop
//! from concurrently. Items are enqueued up front (the unrolled points
//! of one or more experiments), so the queue doubles as the engine's
//! scheduler: whichever worker is free takes the next point.
//!
//! Cold execution uses the dynamic FIFO ([`WorkQueue`]): which worker
//! runs which point is a race, and that is fine because every point
//! runs on a fresh sampler. Warm execution instead uses deterministic
//! contiguous-block sharding ([`shard_contiguous`]): each worker owns a
//! fixed block of the point sequence, so the per-worker order — and
//! with it the carried sampler state — is a pure function of
//! `(experiments, jobs)`, never of thread scheduling.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Split `items` into at most `jobs` contiguous blocks, in order: block
/// `w` holds the `w`-th run of consecutive items, block sizes differing
/// by at most one (the first `len % jobs` blocks get the extra item).
/// The split is a pure function of `(items order, jobs)` — the warm
/// engine's determinism contract. `jobs = 1` yields the whole sequence
/// as one block (strict serial back-to-back order); an empty input
/// yields no blocks.
pub fn shard_contiguous<T>(mut items: Vec<T>, jobs: usize) -> Vec<Vec<T>> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let shards = jobs.max(1).min(n);
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut drain = items.drain(..);
    for w in 0..shards {
        let len = base + usize::from(w < extra);
        out.push(drain.by_ref().take(len).collect());
    }
    out
}

/// A multi-consumer FIFO work queue.
///
/// Intentionally simple — a [`Mutex`]ed deque. The engine's work items
/// are whole sampler scripts (milliseconds to minutes each), so queue
/// contention is negligible next to the work itself.
pub struct WorkQueue<T> {
    items: Mutex<VecDeque<T>>,
}

impl<T> WorkQueue<T> {
    /// Build a queue pre-loaded with `items`, preserving their order.
    pub fn new(items: impl IntoIterator<Item = T>) -> WorkQueue<T> {
        WorkQueue { items: Mutex::new(items.into_iter().collect()) }
    }

    /// Take the next item, or `None` once the queue is drained.
    ///
    /// Draining is final: all work is enqueued before workers start, so
    /// a `None` means this worker is done (there is deliberately no
    /// late `push` — a worker that already observed an empty queue
    /// would never see such items).
    pub fn pop(&self) -> Option<T> {
        self.items.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[test]
    fn fifo_order() {
        let q = WorkQueue::new(vec![1, 2, 3]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn contiguous_sharding_is_deterministic_and_complete() {
        // every item exactly once, order preserved within and across
        // shards, sizes differ by at most one
        for (n, jobs) in [(0usize, 3usize), (1, 4), (5, 1), (7, 3), (8, 4), (3, 9)] {
            let shards = shard_contiguous((0..n).collect::<Vec<_>>(), jobs);
            let flat: Vec<usize> = shards.iter().flatten().copied().collect();
            assert_eq!(flat, (0..n).collect::<Vec<_>>(), "n={n} jobs={jobs}");
            if n == 0 {
                assert!(shards.is_empty());
                continue;
            }
            assert_eq!(shards.len(), jobs.min(n).max(1));
            let min = shards.iter().map(Vec::len).min().unwrap();
            let max = shards.iter().map(Vec::len).max().unwrap();
            assert!(max - min <= 1, "n={n} jobs={jobs}: {shards:?}");
            assert!(min >= 1, "no shard may be empty");
            // pure function of the input: same call, same layout
            assert_eq!(shards, shard_contiguous((0..n).collect::<Vec<_>>(), jobs));
        }
        // jobs=1 is the strict serial back-to-back order
        assert_eq!(shard_contiguous(vec![4, 2, 9], 1), vec![vec![4, 2, 9]]);
    }

    #[test]
    fn concurrent_pop_covers_every_item_once() {
        let n = 1000usize;
        let q = WorkQueue::new(0..n);
        let seen: StdMutex<Vec<usize>> = StdMutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(v) = q.pop() {
                        seen.lock().unwrap().push(v);
                    }
                });
            }
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }
}
