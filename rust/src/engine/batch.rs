//! Batch scheduling: the core fan-out/merge loop shared by single-
//! experiment runs and whole-campaign batches. Every experiment is
//! validated and unrolled up front; the result cache is probed *before*
//! anything is enqueued (the probe itself fans out across the worker
//! pool — reading and parsing thousands of entries serially was the
//! NFS-cache bottleneck), so fully-cached experiments bypass the worker
//! pool entirely and partially-cached ones enqueue only their misses;
//! the remaining points of all experiments go into one [`WorkQueue`];
//! a pool of OS threads drains it; results are merged back into
//! per-experiment [`Report`]s strictly in point order, so parallel
//! output is structurally identical to serial execution.
//!
//! **Warm mode** ([`EngineConfig::warm`]) replaces the dynamic FIFO
//! with deterministic contiguous-block sharding ([`shard_contiguous`]):
//! worker `w` owns block `w` of the full point sequence and executes it
//! in order on one long-lived sampler that carries simulated cache
//! state between points. Because a warm measurement depends on the
//! whole executed prefix of its shard, warm cache keys chain on the
//! predecessor's key and a shard replays from the cache only
//! all-or-nothing: serving a mid-chain hit without executing its
//! predecessors would leave the carried sampler state wrong for the
//! next miss.

use super::cache::ResultCache;
use super::queue::{shard_contiguous, WorkQueue};
use super::{execute_point_on, execute_point_with, BatchStats, EngineConfig};
use crate::coordinator::experiment::{Experiment, UnrolledPoint};
use crate::coordinator::report::{PointResult, Report};
use crate::perfmodel::MachineModel;
use crate::sampler::Sampler;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One experiment's resolved execution plan.
struct Plan<'a> {
    exp: &'a Experiment,
    machine: MachineModel,
    points: Vec<UnrolledPoint>,
}

/// One schedulable unit: point `pt_i` of experiment `exp_i`.
#[derive(Clone, Copy)]
struct Item {
    exp_i: usize,
    pt_i: usize,
}

/// Registered backends (e.g. xla) are one shared instance whose
/// `set_threads` would race across workers — points on such libraries
/// are serialized so their measurements stay identical to serial
/// execution. The three built-in rust libraries are constructed fresh
/// per `by_name` call (cold mode) or owned by one worker (warm mode)
/// and need no lock.
static SHARED_BACKEND_LOCK: Mutex<()> = Mutex::new(());

/// Per-point result slots, one per (experiment, point): the probe and
/// the workers fill them by index, which makes the merge deterministic
/// regardless of completion order.
type Slots = Vec<Vec<Mutex<Option<PointResult>>>>;

fn make_slots(plans: &[Plan]) -> Slots {
    plans
        .iter()
        .map(|p| (0..p.points.len()).map(|_| Mutex::new(None)).collect())
        .collect()
}

/// Deterministic in-order merge of the filled slots into one report per
/// experiment.
fn merge_reports(plans: &[Plan], slots: &Slots) -> Result<Vec<Report>> {
    let mut reports = Vec::with_capacity(plans.len());
    for (plan, row) in plans.iter().zip(slots) {
        let mut results = Vec::with_capacity(row.len());
        for (pt_i, slot) in row.iter().enumerate() {
            let r = slot.lock().unwrap().take().ok_or_else(|| {
                anyhow!("engine produced no result for point {pt_i} of '{}'", plan.exp.name)
            })?;
            results.push(r);
        }
        reports.push(Report::assemble(plan.exp.clone(), plan.machine.clone(), results)?);
    }
    Ok(reports)
}

/// Keep only the failure at the lowest (experiment, point) index, so a
/// parallel run reports the same error a serial run would hit first.
fn record_first_err(
    first_err: &Mutex<Option<(usize, usize, anyhow::Error)>>,
    exp_i: usize,
    pt_i: usize,
    e: anyhow::Error,
) {
    let mut guard = first_err.lock().unwrap();
    let replace = match &*guard {
        None => true,
        Some((ei, pi, _)) => (exp_i, pt_i) < (*ei, *pi),
    };
    if replace {
        *guard = Some((exp_i, pt_i, e));
    }
}

/// Probe the cache for every keyed point, fanning the lookups out over
/// up to `jobs` threads. Lookups are independent reads, so the combined
/// result is identical to the serial probe — only the wall time
/// changes (the ROADMAP's "serial on the caller thread" bottleneck for
/// 10k-point campaigns on NFS cache dirs).
fn probe_cache(
    cache: &Option<ResultCache>,
    plans: &[Plan],
    keys: &[Vec<Option<String>>],
    jobs: usize,
) -> Vec<Vec<Option<PointResult>>> {
    let mut out: Vec<Vec<Option<PointResult>>> =
        plans.iter().map(|p| (0..p.points.len()).map(|_| None).collect()).collect();
    let Some(cache) = cache else { return out };
    let tasks: Vec<Item> = keys
        .iter()
        .enumerate()
        .flat_map(|(exp_i, row)| {
            row.iter()
                .enumerate()
                .filter(|(_, k)| k.is_some())
                .map(move |(pt_i, _)| Item { exp_i, pt_i })
        })
        .collect();
    if tasks.is_empty() {
        return out;
    }
    let lookup = |it: &Item| {
        let plan = &plans[it.exp_i];
        let key = keys[it.exp_i][it.pt_i].as_ref().unwrap();
        cache.lookup(key, plan.points[it.pt_i].expected_records(plan.exp.nreps))
    };
    let jobs = jobs.max(1).min(tasks.len());
    if jobs <= 1 {
        for it in &tasks {
            out[it.exp_i][it.pt_i] = lookup(it);
        }
        return out;
    }
    let found: Vec<Mutex<Option<PointResult>>> =
        (0..tasks.len()).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(it) = tasks.get(i) else { break };
                *found[i].lock().unwrap() = lookup(it);
            });
        }
    });
    for (it, slot) in tasks.iter().zip(found) {
        out[it.exp_i][it.pt_i] = slot.into_inner().unwrap();
    }
    out
}

/// Run a batch of experiments through the worker pool; returns one
/// report per experiment (in input order) plus execution statistics.
///
/// When the calling thread carries a job context
/// ([`crate::obs::emit::current_job`], set by the spooler around
/// payload execution), the batch's aggregate cache-probe accounting is
/// also emitted as `cache_hit`/`cache_miss`/`cache_skip` lifecycle
/// events attributed to that job, classed `seeded`/`warm`/`cold` by
/// the run's mode. Without a context this is a no-op, so the engine
/// stays usable far from any spool.
pub fn run_batch_stats(
    cfg: &EngineConfig,
    exps: &[Experiment],
) -> Result<(Vec<Report>, BatchStats)> {
    let out = run_batch_stats_inner(cfg, exps);
    if let Ok((_, stats)) = &out {
        emit_cache_events(cfg, stats);
    }
    out
}

/// Map a finished batch's cache accounting onto lifecycle events: a
/// configured cache splits points into hits (probe or worker re-probe)
/// and executed misses; a cache-less run reports every executed point
/// as a skip.
fn emit_cache_events(cfg: &EngineConfig, stats: &BatchStats) {
    use crate::obs::emit::emit_cache_counts;
    use crate::obs::events::EventKind;
    let class = if cfg.seed.is_some() {
        "seeded"
    } else if cfg.warm {
        "warm"
    } else {
        "cold"
    };
    if cfg.cache_dir.is_some() {
        emit_cache_counts(EventKind::CacheHit, class, stats.cache_hits);
        emit_cache_counts(EventKind::CacheMiss, class, stats.executed);
    } else {
        emit_cache_counts(EventKind::CacheSkip, class, stats.executed);
    }
}

fn run_batch_stats_inner(
    cfg: &EngineConfig,
    exps: &[Experiment],
) -> Result<(Vec<Report>, BatchStats)> {
    // -- phase 1: validate and unroll everything before spawning
    let mut plans = Vec::with_capacity(exps.len());
    for exp in exps {
        let machine = crate::perfmodel::resolve_machine(&exp.machine)?;
        // fail fast on unknown libraries before any worker spawns; the
        // workers re-resolve per point so every point gets a library
        // instance with fresh thread-count state, exactly like serial
        crate::libraries::by_name(&exp.library)
            .ok_or_else(|| anyhow!("unknown library '{}'", exp.library))?;
        let points = exp.unroll()?;
        plans.push(Plan { exp, machine, points });
    }
    let cache = match &cfg.cache_dir {
        Some(dir) => Some(
            ResultCache::open(dir)?
                .with_trusted_only(cfg.trusted_only)
                .with_seeded(cfg.seed.is_some()),
        ),
        None => None,
    };
    if cfg.warm {
        return run_batch_warm(cfg, &plans, cache);
    }

    let slots = make_slots(&plans);
    // Fingerprints, computed once and shared by the probe and the
    // workers' store path.
    let keys: Vec<Vec<Option<String>>> = plans
        .iter()
        .map(|p| {
            p.points
                .iter()
                .map(|pt| {
                    cache.as_ref().map(|_| {
                        ResultCache::fingerprint_with(
                            &p.exp.library,
                            &p.machine.name,
                            p.exp.nreps,
                            pt,
                            cfg.seed,
                        )
                    })
                })
                .collect()
        })
        .collect();

    // -- phase 2: probe the cache (lookups fan out across the pool),
    // account serially in point order, then shard only the misses
    let mut probe = probe_cache(&cache, &plans, &keys, cfg.jobs);
    let mut scheduled_hits = 0usize;
    let mut fully_cached = 0usize;
    let mut items: Vec<Item> = Vec::new();
    for (exp_i, plan) in plans.iter().enumerate() {
        let mut misses = 0usize;
        for pt_i in 0..plan.points.len() {
            match probe[exp_i][pt_i].take() {
                Some(r) => {
                    *slots[exp_i][pt_i].lock().unwrap() = Some(r);
                    scheduled_hits += 1;
                }
                None => {
                    items.push(Item { exp_i, pt_i });
                    misses += 1;
                }
            }
        }
        if misses == 0 {
            fully_cached += 1;
        }
    }
    let enqueued = items.len();
    let jobs = cfg.jobs.max(1).min(enqueued.max(1));
    // provenance recorded on every entry this run stores: the actual
    // worker-pool width the misses execute under
    let cache = cache.map(|c| c.with_provenance(jobs));
    let queue = WorkQueue::new(items);

    let executed = AtomicUsize::new(0);
    let worker_hits = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let first_err: Mutex<Option<(usize, usize, anyhow::Error)>> = Mutex::new(None);

    let process = |item: Item| -> Result<()> {
        let plan = &plans[item.exp_i];
        let point = &plan.points[item.pt_i];
        let expected = point.expected_records(plan.exp.nreps);
        let run = || -> Result<PointResult> {
            let library = crate::libraries::by_name(&plan.exp.library)
                .ok_or_else(|| anyhow!("unknown library '{}'", plan.exp.library))?;
            let shared = !crate::libraries::RUST_LIBRARIES
                .contains(&plan.exp.library.as_str());
            let _guard = shared.then(|| SHARED_BACKEND_LOCK.lock().unwrap());
            let r = execute_point_with(&library, &plan.machine, plan.exp, point, cfg.seed)?;
            executed.fetch_add(1, Ordering::Relaxed);
            Ok(r)
        };
        let result = if let (Some(c), Some(key)) = (&cache, &keys[item.exp_i][item.pt_i]) {
            // re-probe: a concurrent run may have stored this point
            // between the scheduling probe and now
            if let Some(hit) = c.lookup(key, expected) {
                worker_hits.fetch_add(1, Ordering::Relaxed);
                hit
            } else {
                let r = run()?;
                // a full/read-only cache must not discard a measurement
                // that already succeeded — degrade to uncached
                if let Err(e) = c.store(key, &r) {
                    eprintln!("warning: result-cache write failed ({e:#}); continuing uncached");
                }
                r
            }
        } else {
            run()?
        };
        *slots[item.exp_i][item.pt_i].lock().unwrap() = Some(result);
        Ok(())
    };
    let worker = || {
        while let Some(item) = queue.pop() {
            if failed.load(Ordering::Relaxed) {
                break;
            }
            if let Err(e) = process(item) {
                failed.store(true, Ordering::Relaxed);
                record_first_err(&first_err, item.exp_i, item.pt_i, e);
            }
        }
    };
    // a fully-cached batch enqueues nothing — don't spin up a pool
    // just to watch an empty queue
    if enqueued > 0 {
        if jobs <= 1 {
            worker();
        } else {
            std::thread::scope(|s| {
                for _ in 0..jobs {
                    s.spawn(&worker);
                }
            });
        }
    }

    if let Some((_, _, e)) = first_err.lock().unwrap().take() {
        return Err(e);
    }

    // -- phase 3: deterministic in-order merge
    let reports = merge_reports(&plans, &slots)?;
    let stats = BatchStats {
        experiments: plans.len(),
        fully_cached,
        executed: executed.load(Ordering::Relaxed),
        cache_hits: scheduled_hits + worker_hits.load(Ordering::Relaxed),
        scheduled_hits,
        jobs,
        warm: false,
        host: crate::util::hostid::hostname().to_string(),
    };
    Ok((reports, stats))
}

/// The warm-mode scheduler: deterministic contiguous-block sharding
/// with one carried sampler per worker.
fn run_batch_warm(
    cfg: &EngineConfig,
    plans: &[Plan],
    cache: Option<ResultCache>,
) -> Result<(Vec<Report>, BatchStats)> {
    // All points in (experiment, point) order — the strict serial
    // back-to-back sequence. The shard layout is a pure function of
    // (experiments, jobs): unlike cold mode it must NOT depend on cache
    // contents, or the determinism contract would break.
    let items: Vec<Item> = plans
        .iter()
        .enumerate()
        .flat_map(|(exp_i, p)| (0..p.points.len()).map(move |pt_i| Item { exp_i, pt_i }))
        .collect();
    let total = items.len();
    let jobs = cfg.jobs.max(1).min(total.max(1));
    let shards = shard_contiguous(items, jobs);
    let cache = cache.map(|c| c.with_provenance(jobs).with_warm(true));

    // Chained warm keys: each point's key hashes its own content plus
    // its predecessor's key within the shard, so a warm entry can only
    // hit when the whole executed prefix matches. The chain resets
    // exactly where execution starts a fresh sampler — at a
    // (library, machine) switch — so keys encode precisely the state
    // the sampler actually carries, and an experiment's warm entries
    // are reusable across batch compositions that share the stretch.
    let keys: Vec<Vec<Option<String>>> = shards
        .iter()
        .map(|shard| {
            let mut prev: Option<String> = None;
            let mut prev_chain: Option<(&str, &str)> = None;
            shard
                .iter()
                .map(|it| {
                    cache.as_ref().map(|_| {
                        let plan = &plans[it.exp_i];
                        let chain = (plan.exp.library.as_str(), plan.machine.name.as_str());
                        if prev_chain != Some(chain) {
                            prev = None;
                            prev_chain = Some(chain);
                        }
                        let k = ResultCache::warm_fingerprint(
                            &plan.exp.library,
                            &plan.machine.name,
                            plan.exp.nreps,
                            &plan.points[it.pt_i],
                            cfg.seed,
                            prev.as_deref(),
                        );
                        prev = Some(k.clone());
                        k
                    })
                })
                .collect()
        })
        .collect();

    let slots = make_slots(plans);
    // per-experiment probe-hit counts, for the fully-cached accounting
    let probe_hits: Vec<AtomicUsize> = plans.iter().map(|_| AtomicUsize::new(0)).collect();
    let executed = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let first_err: Mutex<Option<(usize, usize, anyhow::Error)>> = Mutex::new(None);

    let run_shard = |shard_i: usize| {
        let shard = &shards[shard_i];
        // probe: a warm shard replays from the cache all-or-nothing. A
        // mid-chain hit served without executing its predecessors would
        // leave the carried sampler state wrong for the next miss, so a
        // single miss re-executes the whole shard.
        if let Some(c) = &cache {
            let hits: Vec<Option<PointResult>> = shard
                .iter()
                .enumerate()
                .map(|(i, it)| {
                    let plan = &plans[it.exp_i];
                    let key = keys[shard_i][i].as_ref().unwrap();
                    c.lookup(key, plan.points[it.pt_i].expected_records(plan.exp.nreps))
                })
                .collect();
            if hits.iter().all(Option::is_some) {
                for (it, hit) in shard.iter().zip(hits) {
                    probe_hits[it.exp_i].fetch_add(1, Ordering::Relaxed);
                    *slots[it.exp_i][it.pt_i].lock().unwrap() = hit;
                }
                return;
            }
        }
        // execute the whole shard in order, one carried sampler per
        // (library, machine) stretch
        let mut current: Option<(String, String, Sampler)> = None;
        for (i, it) in shard.iter().enumerate() {
            if failed.load(Ordering::Relaxed) {
                return;
            }
            let plan = &plans[it.exp_i];
            let point = &plan.points[it.pt_i];
            let mut run = || -> Result<PointResult> {
                let same = current
                    .as_ref()
                    .is_some_and(|(l, m, _)| *l == plan.exp.library && *m == plan.machine.name);
                if same {
                    // carry simulated cache state into the next point
                    current.as_mut().unwrap().2.reset_warm();
                } else {
                    // a library/machine switch starts a fresh chain
                    let library = crate::libraries::by_name(&plan.exp.library)
                        .ok_or_else(|| anyhow!("unknown library '{}'", plan.exp.library))?;
                    let mut s = Sampler::new(library, plan.machine.clone());
                    if let Some(seed) = cfg.seed {
                        s = s.deterministic(seed);
                    }
                    current = Some((plan.exp.library.clone(), plan.machine.name.clone(), s));
                }
                let sampler = &mut current.as_mut().unwrap().2;
                let shared = !crate::libraries::RUST_LIBRARIES
                    .contains(&plan.exp.library.as_str());
                let _guard = shared.then(|| SHARED_BACKEND_LOCK.lock().unwrap());
                let r = execute_point_on(sampler, plan.exp, point)?;
                executed.fetch_add(1, Ordering::Relaxed);
                Ok(r)
            };
            match run() {
                Ok(r) => {
                    if let (Some(c), Some(key)) = (&cache, keys[shard_i][i].as_ref()) {
                        if let Err(e) = c.store(key, &r) {
                            eprintln!(
                                "warning: result-cache write failed ({e:#}); continuing uncached"
                            );
                        }
                    }
                    *slots[it.exp_i][it.pt_i].lock().unwrap() = Some(r);
                }
                Err(e) => {
                    failed.store(true, Ordering::Relaxed);
                    record_first_err(&first_err, it.exp_i, it.pt_i, e);
                    return;
                }
            }
        }
    };
    if shards.len() <= 1 {
        if !shards.is_empty() {
            run_shard(0);
        }
    } else {
        std::thread::scope(|s| {
            for i in 0..shards.len() {
                let f = &run_shard;
                s.spawn(move || f(i));
            }
        });
    }

    if let Some((_, _, e)) = first_err.lock().unwrap().take() {
        return Err(e);
    }

    let reports = merge_reports(plans, &slots)?;
    let scheduled_hits: usize =
        probe_hits.iter().map(|h| h.load(Ordering::Relaxed)).sum();
    let fully_cached = plans
        .iter()
        .zip(&probe_hits)
        .filter(|(p, h)| h.load(Ordering::Relaxed) == p.points.len())
        .count();
    let stats = BatchStats {
        experiments: plans.len(),
        fully_cached,
        executed: executed.load(Ordering::Relaxed),
        cache_hits: scheduled_hits,
        scheduled_hits,
        jobs,
        warm: true,
        host: crate::util::hostid::hostname().to_string(),
    };
    Ok((reports, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;

    #[test]
    fn batch_preserves_input_order() {
        let mut exps = Vec::new();
        for n in [16i64, 24, 32] {
            let mut e = dgemm_experiment(n);
            e.nreps = 2;
            exps.push(e);
        }
        let cfg = EngineConfig::default().with_jobs(3);
        let (reports, stats) = run_batch_stats(&cfg, &exps).unwrap();
        assert_eq!(reports.len(), 3);
        for (r, n) in reports.iter().zip([16i64, 24, 32]) {
            assert_eq!(r.experiment.name, format!("dgemm{n}"));
            assert_eq!(r.points.len(), 1);
            assert_eq!(r.points[0].records.len(), 2);
        }
        assert_eq!(stats.executed, 3);
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.experiments, 3);
        assert_eq!(stats.fully_cached, 0);
        assert_eq!(stats.jobs, 3);
        assert!(!stats.warm);
    }

    #[test]
    fn bad_experiment_fails_whole_batch_with_its_error() {
        let mut bad = dgemm_experiment(16);
        bad.library = "essl".into();
        let cfg = EngineConfig::default().with_jobs(2);
        let err = run_batch_stats(&cfg, &[dgemm_experiment(16), bad]).unwrap_err();
        assert!(err.to_string().contains("essl"), "{err}");
    }

    #[test]
    fn jobs_zero_means_serial() {
        let cfg = EngineConfig::default();
        let (reports, stats) = run_batch_stats(&cfg, &[dgemm_experiment(16)]).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(stats.jobs, 1);
    }

    #[test]
    fn probe_schedules_hits_and_skips_fully_cached_experiments() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_batch_probe_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = EngineConfig::default().with_jobs(2).with_cache(&dir);
        let mut a = dgemm_experiment(16);
        a.nreps = 2;
        let mut b = dgemm_experiment(24);
        b.nreps = 2;
        let (_, s1) = run_batch_stats(&cfg, &[a.clone()]).unwrap();
        assert_eq!((s1.executed, s1.cache_hits), (1, 0));
        // a is fully cached (skipped); b enqueues its single miss
        let (reports, s2) = run_batch_stats(&cfg, &[a, b]).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(s2.executed, 1);
        assert_eq!(s2.cache_hits, 1);
        assert_eq!(s2.scheduled_hits, 1, "hit must be found before enqueue");
        assert_eq!(s2.experiments, 2);
        assert_eq!(s2.fully_cached, 1);
        let line = s2.summary_line();
        assert!(line.contains("1/2 experiment(s) fully cached"), "{line}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trusted_only_rejects_contended_entries_until_remeasured_serially() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_batch_trust_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut exps = Vec::new();
        for n in [16i64, 24, 32] {
            exps.push(dgemm_experiment(n));
        }
        // measured with a 3-wide pool: entries carry jobs=3 provenance
        let parallel = EngineConfig::default().with_jobs(3).with_cache(&dir);
        let (_, s1) = run_batch_stats(&parallel, &exps).unwrap();
        assert_eq!((s1.executed, s1.cache_hits), (3, 0));
        // a permissive re-run serves them...
        let (_, s2) = run_batch_stats(&parallel, &exps).unwrap();
        assert_eq!((s2.executed, s2.cache_hits), (0, 3));
        // ...a trusted-only serial run re-measures them all...
        let serial = EngineConfig::default().with_cache(&dir).with_trusted_only(true);
        let (_, s3) = run_batch_stats(&serial, &exps).unwrap();
        assert_eq!((s3.executed, s3.cache_hits), (3, 0));
        // ...and its jobs=1 entries now satisfy the trust gate
        let (_, s4) = run_batch_stats(&serial, &exps).unwrap();
        assert_eq!((s4.executed, s4.cache_hits), (0, 3));
        assert_eq!(s4.fully_cached, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_batch_counts_and_marks_its_stats() {
        let mut exps = Vec::new();
        for n in [16i64, 24, 32, 40] {
            let mut e = dgemm_experiment(n);
            e.nreps = 2;
            exps.push(e);
        }
        let cfg = EngineConfig::default().with_jobs(2).with_warm(true).with_seed(1);
        let (reports, stats) = run_batch_stats(&cfg, &exps).unwrap();
        assert_eq!(reports.len(), 4);
        assert_eq!(stats.executed, 4);
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.jobs, 2);
        assert!(stats.warm);
        assert!(stats.summary_line().contains("[warm]"));
    }

    #[test]
    fn warm_shard_replays_from_cache_all_or_nothing() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_batch_warmcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut exps = Vec::new();
        for n in [16i64, 24, 32] {
            exps.push(dgemm_experiment(n));
        }
        let cfg = EngineConfig::default().with_warm(true).with_seed(3).with_cache(&dir);
        let (first, s1) = run_batch_stats(&cfg, &exps).unwrap();
        assert_eq!((s1.executed, s1.cache_hits), (3, 0));
        // full replay: the single jobs=1 shard is entirely cached
        let (second, s2) = run_batch_stats(&cfg, &exps).unwrap();
        assert_eq!((s2.executed, s2.cache_hits), (0, 3));
        assert_eq!(s2.fully_cached, 3);
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(
                crate::coordinator::io::report_to_json(a).to_string_pretty(),
                crate::coordinator::io::report_to_json(b).to_string_pretty(),
                "seeded warm replay must be byte-identical"
            );
        }
        // breaking the chain anywhere re-executes the whole shard: a
        // different experiment list means different chained keys
        let extended: Vec<Experiment> =
            [24i64, 16, 32].iter().map(|&n| dgemm_experiment(n)).collect();
        let (_, s3) = run_batch_stats(&cfg, &extended).unwrap();
        assert_eq!((s3.executed, s3.cache_hits), (3, 0), "reordered prefix must miss");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
