//! Batch scheduling: the core fan-out/merge loop shared by single-
//! experiment runs and whole-campaign batches. Every experiment is
//! validated and unrolled up front; the result cache is probed *before*
//! anything is enqueued, so fully-cached experiments bypass the worker
//! pool entirely and partially-cached ones enqueue only their misses;
//! the remaining points of all experiments go into one [`WorkQueue`];
//! a pool of OS threads drains it; results are merged back into
//! per-experiment [`Report`]s strictly in point order, so parallel
//! output is structurally identical to serial execution.

use super::cache::ResultCache;
use super::queue::WorkQueue;
use super::{execute_point, BatchStats, EngineConfig};
use crate::coordinator::experiment::{Experiment, UnrolledPoint};
use crate::coordinator::report::{PointResult, Report};
use crate::perfmodel::MachineModel;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One experiment's resolved execution plan.
struct Plan<'a> {
    exp: &'a Experiment,
    machine: MachineModel,
    points: Vec<UnrolledPoint>,
}

/// One schedulable unit: point `pt_i` of experiment `exp_i`.
#[derive(Clone, Copy)]
struct Item {
    exp_i: usize,
    pt_i: usize,
}

/// Run a batch of experiments through the worker pool; returns one
/// report per experiment (in input order) plus execution statistics.
pub fn run_batch_stats(
    cfg: &EngineConfig,
    exps: &[Experiment],
) -> Result<(Vec<Report>, BatchStats)> {
    // -- phase 1: validate and unroll everything before spawning
    let mut plans = Vec::with_capacity(exps.len());
    for exp in exps {
        let machine = MachineModel::by_name(&exp.machine)
            .ok_or_else(|| anyhow!("unknown machine '{}'", exp.machine))?;
        // fail fast on unknown libraries before any worker spawns; the
        // workers re-resolve per point so every point gets a library
        // instance with fresh thread-count state, exactly like serial
        crate::libraries::by_name(&exp.library)
            .ok_or_else(|| anyhow!("unknown library '{}'", exp.library))?;
        let points = exp.unroll()?;
        plans.push(Plan { exp, machine, points });
    }
    let cache = match &cfg.cache_dir {
        Some(dir) => Some(ResultCache::open(dir)?.with_trusted_only(cfg.trusted_only)),
        None => None,
    };

    // One slot per point: the probe and the workers fill them by index,
    // which makes the merge deterministic regardless of completion
    // order.
    let slots: Vec<Vec<Mutex<Option<PointResult>>>> = plans
        .iter()
        .map(|p| (0..p.points.len()).map(|_| Mutex::new(None)).collect())
        .collect();
    // Fingerprints, computed once and shared by the probe and the
    // workers' store path.
    let keys: Vec<Vec<Option<String>>> = plans
        .iter()
        .map(|p| {
            p.points
                .iter()
                .map(|pt| {
                    cache.as_ref().map(|_| {
                        ResultCache::fingerprint(
                            &p.exp.library,
                            p.machine.name,
                            p.exp.nreps,
                            pt,
                        )
                    })
                })
                .collect()
        })
        .collect();

    // -- phase 2: probe the cache, then shard only the misses
    let mut scheduled_hits = 0usize;
    let mut fully_cached = 0usize;
    let mut items: Vec<Item> = Vec::new();
    for (exp_i, plan) in plans.iter().enumerate() {
        let mut misses = 0usize;
        for (pt_i, point) in plan.points.iter().enumerate() {
            let hit = match (&cache, &keys[exp_i][pt_i]) {
                (Some(c), Some(k)) => c.lookup(k, point.expected_records(plan.exp.nreps)),
                _ => None,
            };
            match hit {
                Some(r) => {
                    *slots[exp_i][pt_i].lock().unwrap() = Some(r);
                    scheduled_hits += 1;
                }
                None => {
                    items.push(Item { exp_i, pt_i });
                    misses += 1;
                }
            }
        }
        if misses == 0 {
            fully_cached += 1;
        }
    }
    let enqueued = items.len();
    let jobs = cfg.jobs.max(1).min(enqueued.max(1));
    // provenance recorded on every entry this run stores: the actual
    // worker-pool width the misses execute under
    let cache = cache.map(|c| c.with_provenance(jobs));
    let queue = WorkQueue::new(items);

    let executed = AtomicUsize::new(0);
    let worker_hits = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    // Keep the failure at the lowest (experiment, point) index so a
    // parallel run reports the same error a serial run would hit first.
    let first_err: Mutex<Option<(usize, usize, anyhow::Error)>> = Mutex::new(None);

    let process = |item: Item| -> Result<()> {
        let plan = &plans[item.exp_i];
        let point = &plan.points[item.pt_i];
        let expected = point.expected_records(plan.exp.nreps);
        let run = || -> Result<PointResult> {
            let library = crate::libraries::by_name(&plan.exp.library)
                .ok_or_else(|| anyhow!("unknown library '{}'", plan.exp.library))?;
            // The three built-in rust libraries are constructed fresh
            // per by_name call, so each point owns its thread-count
            // state. Registered backends (e.g. xla) are one shared
            // instance whose set_threads would race across workers —
            // serialize those points so their measurements stay
            // identical to serial execution.
            static SHARED_BACKEND_LOCK: Mutex<()> = Mutex::new(());
            let shared = !crate::libraries::RUST_LIBRARIES
                .contains(&plan.exp.library.as_str());
            let _guard = shared.then(|| SHARED_BACKEND_LOCK.lock().unwrap());
            let r = execute_point(&library, &plan.machine, plan.exp, point)?;
            executed.fetch_add(1, Ordering::Relaxed);
            Ok(r)
        };
        let result = if let (Some(c), Some(key)) = (&cache, &keys[item.exp_i][item.pt_i]) {
            // re-probe: a concurrent run may have stored this point
            // between the scheduling probe and now
            if let Some(hit) = c.lookup(key, expected) {
                worker_hits.fetch_add(1, Ordering::Relaxed);
                hit
            } else {
                let r = run()?;
                // a full/read-only cache must not discard a measurement
                // that already succeeded — degrade to uncached
                if let Err(e) = c.store(key, &r) {
                    eprintln!("warning: result-cache write failed ({e:#}); continuing uncached");
                }
                r
            }
        } else {
            run()?
        };
        *slots[item.exp_i][item.pt_i].lock().unwrap() = Some(result);
        Ok(())
    };
    let worker = || {
        while let Some(item) = queue.pop() {
            if failed.load(Ordering::Relaxed) {
                break;
            }
            if let Err(e) = process(item) {
                failed.store(true, Ordering::Relaxed);
                let mut guard = first_err.lock().unwrap();
                let replace = match &*guard {
                    None => true,
                    Some((ei, pi, _)) => (item.exp_i, item.pt_i) < (*ei, *pi),
                };
                if replace {
                    *guard = Some((item.exp_i, item.pt_i, e));
                }
            }
        }
    };
    // a fully-cached batch enqueues nothing — don't spin up a pool
    // just to watch an empty queue
    if enqueued > 0 {
        if jobs <= 1 {
            worker();
        } else {
            std::thread::scope(|s| {
                for _ in 0..jobs {
                    s.spawn(&worker);
                }
            });
        }
    }

    if let Some((_, _, e)) = first_err.lock().unwrap().take() {
        return Err(e);
    }

    // -- phase 3: deterministic in-order merge
    let mut reports = Vec::with_capacity(plans.len());
    for (plan, row) in plans.iter().zip(&slots) {
        let mut results = Vec::with_capacity(row.len());
        for (pt_i, slot) in row.iter().enumerate() {
            let r = slot.lock().unwrap().take().ok_or_else(|| {
                anyhow!("engine produced no result for point {pt_i} of '{}'", plan.exp.name)
            })?;
            results.push(r);
        }
        reports.push(Report::assemble(plan.exp.clone(), plan.machine.clone(), results)?);
    }
    let stats = BatchStats {
        experiments: plans.len(),
        fully_cached,
        executed: executed.load(Ordering::Relaxed),
        cache_hits: scheduled_hits + worker_hits.load(Ordering::Relaxed),
        scheduled_hits,
        jobs,
    };
    Ok((reports, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;

    #[test]
    fn batch_preserves_input_order() {
        let mut exps = Vec::new();
        for n in [16i64, 24, 32] {
            let mut e = dgemm_experiment(n);
            e.nreps = 2;
            exps.push(e);
        }
        let cfg = EngineConfig::default().with_jobs(3);
        let (reports, stats) = run_batch_stats(&cfg, &exps).unwrap();
        assert_eq!(reports.len(), 3);
        for (r, n) in reports.iter().zip([16i64, 24, 32]) {
            assert_eq!(r.experiment.name, format!("dgemm{n}"));
            assert_eq!(r.points.len(), 1);
            assert_eq!(r.points[0].records.len(), 2);
        }
        assert_eq!(stats.executed, 3);
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.experiments, 3);
        assert_eq!(stats.fully_cached, 0);
        assert_eq!(stats.jobs, 3);
    }

    #[test]
    fn bad_experiment_fails_whole_batch_with_its_error() {
        let mut bad = dgemm_experiment(16);
        bad.library = "essl".into();
        let cfg = EngineConfig::default().with_jobs(2);
        let err = run_batch_stats(&cfg, &[dgemm_experiment(16), bad]).unwrap_err();
        assert!(err.to_string().contains("essl"), "{err}");
    }

    #[test]
    fn jobs_zero_means_serial() {
        let cfg = EngineConfig::default();
        let (reports, stats) = run_batch_stats(&cfg, &[dgemm_experiment(16)]).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(stats.jobs, 1);
    }

    #[test]
    fn probe_schedules_hits_and_skips_fully_cached_experiments() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_batch_probe_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = EngineConfig::default().with_jobs(2).with_cache(&dir);
        let mut a = dgemm_experiment(16);
        a.nreps = 2;
        let mut b = dgemm_experiment(24);
        b.nreps = 2;
        let (_, s1) = run_batch_stats(&cfg, &[a.clone()]).unwrap();
        assert_eq!((s1.executed, s1.cache_hits), (1, 0));
        // a is fully cached (skipped); b enqueues its single miss
        let (reports, s2) = run_batch_stats(&cfg, &[a, b]).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(s2.executed, 1);
        assert_eq!(s2.cache_hits, 1);
        assert_eq!(s2.scheduled_hits, 1, "hit must be found before enqueue");
        assert_eq!(s2.experiments, 2);
        assert_eq!(s2.fully_cached, 1);
        let line = s2.summary_line();
        assert!(line.contains("1/2 experiment(s) fully cached"), "{line}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trusted_only_rejects_contended_entries_until_remeasured_serially() {
        let dir = std::env::temp_dir()
            .join(format!("elaps_batch_trust_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut exps = Vec::new();
        for n in [16i64, 24, 32] {
            exps.push(dgemm_experiment(n));
        }
        // measured with a 3-wide pool: entries carry jobs=3 provenance
        let parallel = EngineConfig::default().with_jobs(3).with_cache(&dir);
        let (_, s1) = run_batch_stats(&parallel, &exps).unwrap();
        assert_eq!((s1.executed, s1.cache_hits), (3, 0));
        // a permissive re-run serves them...
        let (_, s2) = run_batch_stats(&parallel, &exps).unwrap();
        assert_eq!((s2.executed, s2.cache_hits), (0, 3));
        // ...a trusted-only serial run re-measures them all...
        let serial = EngineConfig::default().with_cache(&dir).with_trusted_only(true);
        let (_, s3) = run_batch_stats(&serial, &exps).unwrap();
        assert_eq!((s3.executed, s3.cache_hits), (3, 0));
        // ...and its jobs=1 entries now satisfy the trust gate
        let (_, s4) = run_batch_stats(&serial, &exps).unwrap();
        assert_eq!((s4.executed, s4.cache_hits), (0, 3));
        assert_eq!(s4.fully_cached, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
