//! Cache lifecycle: statistics, garbage collection and clearing for
//! the engine's content-addressed result cache (the `elaps cache
//! {stats,gc,clear}` subcommands).
//!
//! The cache grows without bound while campaigns run; this module adds
//! the introspection and eviction the ROADMAP called for: entry/byte
//! counts with provenance classes and an age histogram, an LRU sweep
//! (by mtime, which served hits bump via `ResultCache::touch`) that
//! deletes oldest entries until the cache fits a byte budget, and a
//! full clear.
//!
//! All operations are safe against concurrent engine runs: entries are
//! whole files written atomically (temp + rename), so a sweep can only
//! ever remove complete entries, and an entry that vanishes mid-scan
//! (deleted by a racing gc/clear, or replaced by a store) is simply
//! skipped. Deleting an entry a worker is about to re-store is
//! harmless — the point is re-measured on the next miss.

use crate::coordinator::io;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

/// Age-histogram buckets: label and exclusive upper bound in seconds.
pub const AGE_BUCKETS: [(&str, u64); 5] = [
    ("< 1 min", 60),
    ("< 1 hour", 3_600),
    ("< 1 day", 86_400),
    ("< 7 days", 604_800),
    ("older", u64::MAX),
];

/// Writer temp files older than this are considered abandoned by a
/// crashed process and swept by `gc`/`clear`.
const STALE_TMP_AGE: Duration = Duration::from_secs(3_600);

/// A snapshot of the cache's contents.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entry files present.
    pub entries: usize,
    /// Total bytes of all entry files.
    pub total_bytes: u64,
    /// Entries proven measured without contention (`jobs ≤ 1`).
    pub trusted: usize,
    /// Entries measured under worker contention (`jobs > 1`).
    pub contended: usize,
    /// Entries measured in warm execution mode (sampler state carried
    /// across points; disjoint key space from cold entries).
    pub warm: usize,
    /// Legacy pre-envelope entries (provenance unknown).
    pub legacy: usize,
    /// Files that parse as neither envelope nor legacy entry.
    pub unreadable: usize,
    /// Writer temp files currently present.
    pub tmp_files: usize,
    /// Entry count per [`AGE_BUCKETS`] bucket (by `created_unix` when
    /// recorded, mtime otherwise).
    pub ages: [usize; AGE_BUCKETS.len()],
    /// Entries per measuring host (the schema-3 envelope's `host`
    /// provenance); pre-schema-3, legacy and unreadable entries count
    /// under `"(unknown)"`. The ROADMAP's size-aware-stats item for
    /// shared multi-host caches.
    pub by_host: BTreeMap<String, usize>,
}

impl CacheStats {
    /// Multi-line human-readable rendering (the `cache stats` output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s += &format!("  entries:     {}\n", self.entries);
        s += &format!("  bytes:       {}\n", self.total_bytes);
        s += &format!("  trusted:     {}  (jobs <= 1 — publication-quality timings)\n", self.trusted);
        s += &format!("  contended:   {}  (jobs > 1 — wall times inflated by contention)\n", self.contended);
        s += &format!("  warm:        {}  (sampler state carried across points)\n", self.warm);
        s += &format!("  legacy:      {}  (pre-envelope, provenance unknown)\n", self.legacy);
        s += &format!("  unreadable:  {}\n", self.unreadable);
        s += &format!("  tmp files:   {}\n", self.tmp_files);
        s += "  age histogram:\n";
        for (i, (label, _)) in AGE_BUCKETS.iter().enumerate() {
            s += &format!("    {label:<9} {}\n", self.ages[i]);
        }
        if !self.by_host.is_empty() {
            s += "  per-host:\n";
            for (host, n) in &self.by_host {
                s += &format!("    {host:<16} {n}\n");
            }
        }
        s
    }
}

/// The outcome of one `gc` sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcOutcome {
    /// Entries present when the sweep started.
    pub scanned: usize,
    /// Entries deleted (oldest recency first).
    pub deleted: usize,
    /// Total entry bytes before the sweep.
    pub bytes_before: u64,
    /// Total entry bytes after the sweep.
    pub bytes_after: u64,
    /// Abandoned writer temp files removed.
    pub tmp_removed: usize,
}

/// One scanned entry file.
struct EntryFile {
    path: PathBuf,
    bytes: u64,
    /// LRU recency. Taken from *mtime*, not atime: served hits bump
    /// mtime explicitly (`ResultCache::touch`), while atime is frozen
    /// on `noatime` mounts and stale for up to a day on the `relatime`
    /// default — an atime-ordered sweep on such mounts evicts by write
    /// age and throws out the hottest entries first.
    recency: SystemTime,
    /// Age reference for the stats histogram.
    mtime: SystemTime,
}

/// List the cache directory's entry (`*.json`) and temp (`*.tmp`)
/// files. Errors if `dir` is not a directory; tolerates entries
/// vanishing mid-scan.
fn scan(dir: &Path) -> Result<(Vec<EntryFile>, Vec<PathBuf>)> {
    if !dir.is_dir() {
        bail!("no cache directory at {}", dir.display());
    }
    let mut entries = Vec::new();
    let mut tmps = Vec::new();
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("reading cache dir {}", dir.display()))?;
    for e in rd.filter_map(|e| e.ok()) {
        let path = e.path();
        match path.extension().and_then(|x| x.to_str()) {
            Some("json") => {
                // may vanish between read_dir and metadata (racing gc)
                let Ok(md) = e.metadata() else { continue };
                if !md.is_file() {
                    continue;
                }
                let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                entries.push(EntryFile { path, bytes: md.len(), recency: mtime, mtime });
            }
            Some("tmp") => tmps.push(path),
            _ => {}
        }
    }
    Ok((entries, tmps))
}

/// Gather [`CacheStats`] for the cache at `dir`.
pub fn cache_stats(dir: &Path) -> Result<CacheStats> {
    let (entries, tmps) = scan(dir)?;
    let now = SystemTime::now();
    let mut st = CacheStats { tmp_files: tmps.len(), ..Default::default() };
    for ent in &entries {
        // entries may vanish between scan and read — skip, don't fail
        let Ok(text) = std::fs::read_to_string(&ent.path) else { continue };
        st.entries += 1;
        st.total_bytes += ent.bytes;
        let env = Json::parse(&text).ok().as_ref().and_then(io::cache_envelope_from_json);
        let created = env.as_ref().and_then(|e| e.created_unix);
        let host = env
            .as_ref()
            .and_then(|e| e.host.clone())
            .unwrap_or_else(|| "(unknown)".to_string());
        *st.by_host.entry(host).or_insert(0) += 1;
        match env {
            None => st.unreadable += 1,
            Some(e) => {
                if e.warm {
                    st.warm += 1;
                }
                match e.jobs {
                    Some(j) if j <= 1 => st.trusted += 1,
                    Some(_) => st.contended += 1,
                    None => st.legacy += 1,
                }
            }
        }
        let age_secs = match created {
            Some(t) => now
                .duration_since(SystemTime::UNIX_EPOCH)
                .map(|d| d.as_secs().saturating_sub(t))
                .unwrap_or(0),
            None => now.duration_since(ent.mtime).map(|d| d.as_secs()).unwrap_or(0),
        };
        let bucket = AGE_BUCKETS
            .iter()
            .position(|&(_, bound)| age_secs < bound)
            .unwrap_or(AGE_BUCKETS.len() - 1);
        st.ages[bucket] += 1;
    }
    Ok(st)
}

/// Remove writer temp files abandoned for more than [`STALE_TMP_AGE`];
/// fresh ones are spared — a live writer may be between its write and
/// rename. Returns the number removed.
fn sweep_stale_tmps(tmps: Vec<PathBuf>) -> usize {
    let mut removed = 0;
    for tmp in tmps {
        let stale = std::fs::metadata(&tmp)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .is_some_and(|age| age >= STALE_TMP_AGE);
        if stale && std::fs::remove_file(&tmp).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Shrink the cache below `max_bytes`, deleting least-recently-used
/// entries first (mtime recency — see [`EntryFile::recency`]; ties
/// broken by path for determinism). Also sweeps writer temp files
/// abandoned for more
/// than an hour. Entries deleted concurrently by another process count
/// as freed.
pub fn gc_max_bytes(dir: &Path, max_bytes: u64) -> Result<GcOutcome> {
    let (mut entries, tmps) = scan(dir)?;
    let mut out = GcOutcome { scanned: entries.len(), ..Default::default() };
    out.tmp_removed = sweep_stale_tmps(tmps);
    entries.sort_by(|a, b| a.recency.cmp(&b.recency).then_with(|| a.path.cmp(&b.path)));
    let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
    out.bytes_before = total;
    for ent in &entries {
        if total <= max_bytes {
            break;
        }
        match std::fs::remove_file(&ent.path) {
            Ok(()) => {}
            // already gone (racing gc/clear): its bytes are freed too
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| format!("deleting {}", ent.path.display()))
            }
        }
        total = total.saturating_sub(ent.bytes);
        out.deleted += 1;
    }
    out.bytes_after = total;
    Ok(out)
}

/// Delete entries older than `max_age` — age measured from the
/// envelope's `created_unix` (the store time the measuring run
/// recorded) where present, file mtime otherwise (legacy and unreadable
/// entries). The `elaps cache gc --max-age DUR` sweep: unlike the LRU
/// byte-budget sweep, this one expires *measurements*, so a stale
/// library build's timings age out of a shared cache even while re-runs
/// keep touching (and thereby LRU-refreshing) them. Also sweeps
/// abandoned writer temp files.
pub fn gc_max_age(dir: &Path, max_age: Duration) -> Result<GcOutcome> {
    let (entries, tmps) = scan(dir)?;
    let mut out = GcOutcome { scanned: entries.len(), ..Default::default() };
    out.tmp_removed = sweep_stale_tmps(tmps);
    let now = SystemTime::now();
    let now_unix = now
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
    out.bytes_before = total;
    for ent in &entries {
        // prefer the recorded store time; a future-dated created_unix
        // (clock skew) counts as age 0, never as expired
        let age_secs = std::fs::read_to_string(&ent.path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .as_ref()
            .and_then(io::cache_envelope_from_json)
            .and_then(|env| env.created_unix)
            .map(|t| now_unix.saturating_sub(t))
            .unwrap_or_else(|| {
                now.duration_since(ent.mtime).map(|d| d.as_secs()).unwrap_or(0)
            });
        if age_secs <= max_age.as_secs() {
            continue;
        }
        match std::fs::remove_file(&ent.path) {
            Ok(()) => {}
            // already gone (racing gc/clear): its bytes are freed too
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| format!("deleting {}", ent.path.display()))
            }
        }
        total = total.saturating_sub(ent.bytes);
        out.deleted += 1;
    }
    out.bytes_after = total;
    Ok(out)
}

/// Delete every cache entry, plus abandoned temp files. Fresh temp
/// files are left alone — a live writer may be between its write and
/// rename, and deleting its temp would fail that store. Returns the
/// number of entries removed.
pub fn clear_cache(dir: &Path) -> Result<usize> {
    let (entries, tmps) = scan(dir)?;
    let mut removed = 0;
    for ent in &entries {
        match std::fs::remove_file(&ent.path) {
            Ok(()) => removed += 1,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| format!("deleting {}", ent.path.display()))
            }
        }
    }
    sweep_stale_tmps(tmps);
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("elaps_gc_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Write a fake entry of `bytes` bytes with atime+mtime `age_secs`
    /// in the past.
    fn put_entry(dir: &Path, name: &str, bytes: usize, age_secs: u64) {
        let path = dir.join(format!("{name}.json"));
        std::fs::write(&path, "x".repeat(bytes)).unwrap();
        let t = SystemTime::now() - Duration::from_secs(age_secs);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_times(std::fs::FileTimes::new().set_accessed(t).set_modified(t)).unwrap();
    }

    #[test]
    fn missing_dir_is_an_error() {
        let dir = tmpdir("missing").join("nope");
        assert!(cache_stats(&dir).is_err());
        assert!(gc_max_bytes(&dir, 0).is_err());
        assert!(clear_cache(&dir).is_err());
    }

    #[test]
    fn stats_counts_and_age_buckets() {
        let dir = tmpdir("stats");
        put_entry(&dir, "fresh", 10, 0);
        put_entry(&dir, "hour_old", 20, 2_000);
        put_entry(&dir, "ancient", 30, 2 * 604_800);
        std::fs::write(dir.join("leftover.tmp"), "partial").unwrap();
        let st = cache_stats(&dir).unwrap();
        assert_eq!(st.entries, 3);
        assert_eq!(st.total_bytes, 60);
        // raw "xxx…" files are unreadable entries, not errors
        assert_eq!(st.unreadable, 3);
        assert_eq!(st.tmp_files, 1);
        assert_eq!(st.ages[0], 1, "{:?}", st.ages); // < 1 min
        assert_eq!(st.ages[1], 1); // < 1 hour
        assert_eq!(st.ages[4], 1); // older
        assert!(st.render().contains("entries:     3"));
        // raw files carry no host provenance
        assert_eq!(st.by_host.get("(unknown)"), Some(&3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_break_entries_down_by_host() {
        let dir = tmpdir("byhost");
        let entry = |host: &str| {
            format!(
                r#"{{"schema":3,"jobs":1,"warm":false,"host":"{host}","worker":"{host}#1-0",
                   "result":{{"range_value":0,"nthreads":1,"sum_iters":1,
                              "calls_per_iter":1,"records":[]}}}}"#
            )
        };
        std::fs::write(dir.join("a1.json"), entry("nodeA")).unwrap();
        std::fs::write(dir.join("a2.json"), entry("nodeA")).unwrap();
        std::fs::write(dir.join("b1.json"), entry("nodeB")).unwrap();
        // a schema-2 (pre-host) envelope counts as unknown
        std::fs::write(dir.join("old.json"), envelope_json(1_700_000_000)).unwrap();
        let st = cache_stats(&dir).unwrap();
        assert_eq!(st.entries, 4);
        assert_eq!(st.by_host.get("nodeA"), Some(&2));
        assert_eq!(st.by_host.get("nodeB"), Some(&1));
        assert_eq!(st.by_host.get("(unknown)"), Some(&1));
        let text = st.render();
        assert!(text.contains("per-host:"), "{text}");
        assert!(text.contains("nodeA"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_deletes_oldest_first_until_under_budget() {
        let dir = tmpdir("lru");
        put_entry(&dir, "oldest", 100, 3_000);
        put_entry(&dir, "middle", 100, 2_000);
        put_entry(&dir, "newest", 100, 1_000);
        let out = gc_max_bytes(&dir, 150).unwrap();
        assert_eq!(out.scanned, 3);
        assert_eq!(out.deleted, 2);
        assert_eq!(out.bytes_before, 300);
        assert_eq!(out.bytes_after, 100);
        assert!(!dir.join("oldest.json").exists());
        assert!(!dir.join("middle.json").exists());
        assert!(dir.join("newest.json").exists());
        // already under budget: a second sweep deletes nothing
        let out2 = gc_max_bytes(&dir, 150).unwrap();
        assert_eq!(out2.deleted, 0);
        assert_eq!(out2.bytes_after, 100);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sweep order must come from mtime (the recency that
    /// `ResultCache::touch` bumps on served hits) and ignore atime
    /// entirely: on `relatime`/`noatime` mounts atime is stale or
    /// frozen, and an atime-ordered sweep would evict whatever the
    /// mount happened to record — here, the *hot* entry. The entries
    /// are built with deliberately contradictory timestamps so the test
    /// fails under either atime semantics if atime sneaks back in.
    #[test]
    fn gc_recency_comes_from_mtime_not_atime() {
        let dir = tmpdir("mtime_recency");
        let now = SystemTime::now();
        let old = now - Duration::from_secs(5_000);
        let set = |name: &str, atime: SystemTime, mtime: SystemTime| {
            let path = dir.join(format!("{name}.json"));
            std::fs::write(&path, "x".repeat(100)).unwrap();
            let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
            f.set_times(std::fs::FileTimes::new().set_accessed(atime).set_modified(mtime))
                .unwrap();
        };
        // "hot": served recently (touch bumped mtime) but the scan-time
        // atime is ancient; "cold": written long ago, atime fresh as a
        // strictly-atime mount would report after a read-only scan
        set("hot", old, now);
        set("cold", now, old);
        let out = gc_max_bytes(&dir, 150).unwrap();
        assert_eq!(out.deleted, 1);
        assert!(!dir.join("cold.json").exists(), "mtime-old entry must go first");
        assert!(dir.join("hot.json").exists(), "recently served entry must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A minimal valid schema-2 envelope with the given store time.
    fn envelope_json(created_unix: u64) -> String {
        format!(
            r#"{{"schema":2,"jobs":1,"warm":false,"created_unix":{created_unix},
               "result":{{"range_value":0,"nthreads":1,"sum_iters":1,
                          "calls_per_iter":1,"records":[]}}}}"#
        )
    }

    #[test]
    fn gc_max_age_expires_by_created_unix_with_mtime_fallback() {
        let dir = tmpdir("maxage");
        let now = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .unwrap()
            .as_secs();
        std::fs::write(dir.join("old.json"), envelope_json(now - 10_000)).unwrap();
        std::fs::write(dir.join("fresh.json"), envelope_json(now)).unwrap();
        // created_unix takes precedence over file times: a *recently
        // touched* file with an old store time still expires
        let touched = dir.join("touched.json");
        std::fs::write(&touched, envelope_json(now - 10_000)).unwrap();
        // (fs write just set mtime to now)
        // mtime fallback: a non-envelope entry ages by its file time
        put_entry(&dir, "legacyold", 10, 10_000);
        let out = gc_max_age(&dir, Duration::from_secs(3_600)).unwrap();
        assert_eq!(out.scanned, 4);
        assert_eq!(out.deleted, 3, "old, touched and legacyold expire");
        assert!(dir.join("fresh.json").exists());
        assert!(!dir.join("old.json").exists());
        assert!(!touched.exists());
        assert!(!dir.join("legacyold.json").exists());
        // nothing left past the cutoff: a second sweep is a no-op
        let out2 = gc_max_age(&dir, Duration::from_secs(3_600)).unwrap();
        assert_eq!(out2.deleted, 0);
        assert_eq!(out2.bytes_after, out2.bytes_before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_max_age_sweeps_stale_tmps_and_errors_on_missing_dir() {
        let dir = tmpdir("maxage_tmps");
        let stale = dir.join("stale.tmp");
        std::fs::write(&stale, "crashed writer").unwrap();
        let t = SystemTime::now() - Duration::from_secs(7_200);
        let f = std::fs::OpenOptions::new().write(true).open(&stale).unwrap();
        f.set_times(std::fs::FileTimes::new().set_accessed(t).set_modified(t)).unwrap();
        let out = gc_max_age(&dir, Duration::from_secs(60)).unwrap();
        assert_eq!(out.tmp_removed, 1);
        assert!(!stale.exists());
        assert!(gc_max_age(&dir.join("nope"), Duration::ZERO).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_sweeps_stale_tmp_files_only() {
        let dir = tmpdir("tmps");
        std::fs::write(dir.join("fresh.tmp"), "busy writer").unwrap();
        let stale = dir.join("stale.tmp");
        std::fs::write(&stale, "crashed writer").unwrap();
        let t = SystemTime::now() - Duration::from_secs(7_200);
        let f = std::fs::OpenOptions::new().write(true).open(&stale).unwrap();
        f.set_times(std::fs::FileTimes::new().set_accessed(t).set_modified(t)).unwrap();
        let out = gc_max_bytes(&dir, u64::MAX).unwrap();
        assert_eq!(out.tmp_removed, 1);
        assert!(dir.join("fresh.tmp").exists());
        assert!(!stale.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_removes_entries_and_stale_tmps_but_spares_live_writers() {
        let dir = tmpdir("clear");
        put_entry(&dir, "a", 10, 0);
        put_entry(&dir, "b", 10, 0);
        // a fresh tmp may belong to a live writer mid-store: spared
        std::fs::write(dir.join("live.tmp"), "x").unwrap();
        // an hours-old tmp is an abandoned writer: swept
        let stale = dir.join("stale.tmp");
        std::fs::write(&stale, "y").unwrap();
        let t = SystemTime::now() - Duration::from_secs(7_200);
        let f = std::fs::OpenOptions::new().write(true).open(&stale).unwrap();
        f.set_times(std::fs::FileTimes::new().set_accessed(t).set_modified(t)).unwrap();
        assert_eq!(clear_cache(&dir).unwrap(), 2);
        let st = cache_stats(&dir).unwrap();
        assert_eq!((st.entries, st.tmp_files), (0, 1));
        assert!(dir.join("live.tmp").exists());
        assert!(!stale.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
