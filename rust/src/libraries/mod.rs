//! Kernel library backends — the "libraries" compared by the paper's
//! experiments (OpenBLAS, MKL, ESSL, LAPACK, RECSY, libFLAME …),
//! substituted by from-scratch algorithmic variants per DESIGN.md
//! §Substitutions 1:
//!
//! * `rustref`       — unblocked/naive algorithms (netlib LAPACK analog),
//! * `rustblocked`   — cache-blocked algorithms with the packed gemm
//!   microkernel (OpenBLAS / libFLAME analog),
//! * `rustrecursive` — recursive algorithms (RECSY analog),
//! * `xla`           — JAX/Pallas kernels AOT-compiled to HLO, executed
//!   via PJRT (vendor-optimized analog; see [`crate::runtime`]).
//!
//! A backend executes parsed kernel calls ([`crate::kernels::ArgValues`])
//! against operand slices resolved by the sampler's memory manager.

use crate::kernels::{ArgValues, DataDir};
use crate::linalg::lapack as lp;
use crate::linalg::{blas2, blas3, Diag, Side, Trans, Uplo};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A resolved data operand: pointer + length into sampler memory.
///
/// Raw pointers (not slices) because BLAS semantics allow *input*
/// operands to alias each other while Rust references must not;
/// [`OperandSet::new`] rejects overlap between any *output* operand and
/// any other operand, which restores soundness for the slices we hand
/// out.
#[derive(Debug, Clone, Copy)]
pub struct RawOperand {
    pub ptr: *mut f64,
    pub len: usize,
    pub dir: DataDir,
}

/// The set of operands for one kernel call.
pub struct OperandSet {
    ops: Vec<RawOperand>,
}

unsafe impl Send for OperandSet {}

impl OperandSet {
    /// Build an operand set, validating that no writable operand
    /// overlaps any other operand.
    pub fn new(ops: Vec<RawOperand>) -> Result<OperandSet> {
        for (i, a) in ops.iter().enumerate() {
            if !matches!(a.dir, DataDir::Out | DataDir::InOut) {
                continue;
            }
            let (a0, a1) = (a.ptr as usize, a.ptr as usize + a.len * 8);
            for (j, b) in ops.iter().enumerate() {
                if i == j {
                    continue;
                }
                let (b0, b1) = (b.ptr as usize, b.ptr as usize + b.len * 8);
                if a0 < b1 && b0 < a1 {
                    bail!("operand {i} (writable) overlaps operand {j}");
                }
            }
        }
        Ok(OperandSet { ops })
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Immutable view of operand `i`.
    pub fn get(&self, i: usize) -> &[f64] {
        let op = &self.ops[i];
        unsafe { std::slice::from_raw_parts(op.ptr, op.len) }
    }

    /// Mutable view of operand `i` (sound: constructor rejected
    /// overlap of writable operands with anything else).
    #[allow(clippy::mut_from_ref)]
    pub fn get_mut(&self, i: usize) -> &mut [f64] {
        let op = &self.ops[i];
        debug_assert!(matches!(op.dir, DataDir::Out | DataDir::InOut));
        unsafe { std::slice::from_raw_parts_mut(op.ptr, op.len) }
    }
}

/// Algorithmic variant backing a rust library.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Unblocked,
    Blocked,
    Recursive,
}

/// A kernel library backend.
pub trait KernelLibrary: Send + Sync {
    fn name(&self) -> &str;
    /// Execute one parsed call against its operands.
    fn execute(&self, av: &ArgValues, ops: &OperandSet) -> Result<()>;
    /// Set the library-internal thread count (cf. OPENBLAS_NUM_THREADS).
    fn set_threads(&self, n: usize);
    fn threads(&self) -> usize;
    /// Fraction of the kernel's work that parallelizes inside the
    /// library (Amdahl parameter used by the simulated-threads mode).
    fn parallel_fraction(&self, kernel: &str) -> f64 {
        match kernel {
            "dgemm" | "dsyrk" | "dtrmm" => 0.98,
            "dtrsm" | "dgetrf" | "dgesv" | "dpotrf" | "dposv" | "dpotrs" | "dtrtri" => 0.92,
            "dsyev" => 0.60,
            "dsyevd" => 0.85,
            "dsyevx" => 0.90,
            "dsyevr" => 0.93,
            "dtrsyl" => 0.50,
            _ => 0.0, // blas-2 and below: memory bound, no speedup
        }
    }
}

/// The three from-scratch rust libraries.
pub struct RustLibrary {
    name: &'static str,
    variant: Variant,
    nthreads: AtomicUsize,
}

impl RustLibrary {
    pub fn new(name: &'static str, variant: Variant) -> RustLibrary {
        RustLibrary { name, variant, nthreads: AtomicUsize::new(1) }
    }
}

fn tr(c: char) -> Result<Trans> {
    Trans::from_char(c).ok_or_else(|| anyhow!("bad trans flag '{c}'"))
}
fn ul(c: char) -> Result<Uplo> {
    Uplo::from_char(c).ok_or_else(|| anyhow!("bad uplo flag '{c}'"))
}
fn sd(c: char) -> Result<Side> {
    Side::from_char(c).ok_or_else(|| anyhow!("bad side flag '{c}'"))
}
fn dg(c: char) -> Result<Diag> {
    Diag::from_char(c).ok_or_else(|| anyhow!("bad diag flag '{c}'"))
}

impl KernelLibrary for RustLibrary {
    fn name(&self) -> &str {
        self.name
    }

    fn set_threads(&self, n: usize) {
        self.nthreads.store(n.max(1), Ordering::Relaxed);
    }

    fn threads(&self) -> usize {
        self.nthreads.load(Ordering::Relaxed)
    }

    fn execute(&self, av: &ArgValues, ops: &OperandSet) -> Result<()> {
        dispatch(self.variant, av, ops)
    }
}

/// Shared dispatch: map a parsed call onto the [`crate::linalg`]
/// substrate according to the algorithmic variant.
pub fn dispatch(variant: Variant, av: &ArgValues, ops: &OperandSet) -> Result<()> {
    let name = av.sig.name;
    match name {
        "dgemm" => {
            let (m, n, k) = (av.dim("m"), av.dim("n"), av.dim("k"));
            let gemm = match variant {
                Variant::Unblocked => blas3::dgemm_naive,
                Variant::Blocked => blas3::dgemm_blocked,
                Variant::Recursive => blas3::dgemm_recursive,
            };
            gemm(
                tr(av.flag("transa"))?, tr(av.flag("transb"))?, m, n, k, av.num("alpha"),
                ops.get(0), av.dim("lda"), ops.get(1), av.dim("ldb"), av.num("beta"),
                ops.get_mut(2), av.dim("ldc"),
            );
            Ok(())
        }
        "dtrsm" => {
            let (m, n) = (av.dim("m"), av.dim("n"));
            let (side, uplo, trans, diag) = (
                sd(av.flag("side"))?, ul(av.flag("uplo"))?, tr(av.flag("transa"))?,
                dg(av.flag("diag"))?,
            );
            match variant {
                Variant::Unblocked => blas3::dtrsm_unblocked(
                    side, uplo, trans, diag, m, n, av.num("alpha"), ops.get(0),
                    av.dim("lda"), ops.get_mut(1), av.dim("ldb"),
                ),
                _ => blas3::dtrsm_blocked(
                    side, uplo, trans, diag, m, n, av.num("alpha"), ops.get(0),
                    av.dim("lda"), ops.get_mut(1), av.dim("ldb"), 64,
                ),
            }
            Ok(())
        }
        "dtrmm" => {
            blas3::dtrmm(
                sd(av.flag("side"))?, ul(av.flag("uplo"))?, tr(av.flag("transa"))?,
                dg(av.flag("diag"))?, av.dim("m"), av.dim("n"), av.num("alpha"),
                ops.get(0), av.dim("lda"), ops.get_mut(1), av.dim("ldb"),
            );
            Ok(())
        }
        "dsyrk" => {
            blas3::dsyrk(
                ul(av.flag("uplo"))?, tr(av.flag("trans"))?, av.dim("n"), av.dim("k"),
                av.num("alpha"), ops.get(0), av.dim("lda"), av.num("beta"),
                ops.get_mut(1), av.dim("ldc"),
            );
            Ok(())
        }
        "dgemv" => {
            blas2::dgemv(
                tr(av.flag("trans"))?, av.dim("m"), av.dim("n"), av.num("alpha"),
                ops.get(0), av.dim("lda"), ops.get(1), av.dim("incx"), av.num("beta"),
                ops.get_mut(2), av.dim("incy"),
            );
            Ok(())
        }
        "dtrsv" => {
            blas2::dtrsv(
                ul(av.flag("uplo"))?, tr(av.flag("trans"))?, dg(av.flag("diag"))?,
                av.dim("n"), ops.get(0), av.dim("lda"), ops.get_mut(1), av.dim("incx"),
            );
            Ok(())
        }
        "dgetrf" => {
            let (m, n) = (av.dim("m"), av.dim("n"));
            let mut ipiv = vec![0usize; m.min(n)];
            let a = ops.get_mut(0);
            match variant {
                Variant::Unblocked => lp::dgetrf_unblocked(m, n, a, av.dim("lda"), &mut ipiv),
                _ => lp::dgetrf(m, n, a, av.dim("lda"), &mut ipiv),
            }
            .map_err(|e| anyhow!("dgetrf: {e}"))
        }
        "dgesv" => {
            let (n, nrhs) = (av.dim("n"), av.dim("nrhs"));
            let mut ipiv = vec![0usize; n];
            let a = ops.get_mut(0);
            let b = ops.get_mut(1);
            match variant {
                Variant::Unblocked => {
                    lp::dgetrf_unblocked(n, n, a, av.dim("lda"), &mut ipiv)
                        .map_err(|e| anyhow!("dgesv: {e}"))?;
                    lp::dgetrs(Trans::No, n, nrhs, a, av.dim("lda"), &ipiv, b, av.dim("ldb"));
                    Ok(())
                }
                _ => lp::dgesv(n, nrhs, a, av.dim("lda"), &mut ipiv, b, av.dim("ldb"))
                    .map(|_| ())
                    .map_err(|e| anyhow!("dgesv: {e}")),
            }
        }
        "dpotrf" => {
            let n = av.dim("n");
            let a = ops.get_mut(0);
            match variant {
                Variant::Unblocked => lp::dpotrf_unblocked(ul(av.flag("uplo"))?, n, a, av.dim("lda")),
                _ => lp::dpotrf(ul(av.flag("uplo"))?, n, a, av.dim("lda")),
            }
            .map_err(|e| anyhow!("dpotrf: {e}"))
        }
        "dpotrs" => {
            lp::dpotrs(
                ul(av.flag("uplo"))?, av.dim("n"), av.dim("nrhs"), ops.get(0),
                av.dim("lda"), ops.get_mut(1), av.dim("ldb"),
            );
            Ok(())
        }
        "dposv" => {
            let uplo = ul(av.flag("uplo"))?;
            let (n, nrhs) = (av.dim("n"), av.dim("nrhs"));
            let a = ops.get_mut(0);
            let b = ops.get_mut(1);
            match variant {
                Variant::Unblocked => {
                    lp::dpotrf_unblocked(uplo, n, a, av.dim("lda"))
                        .map_err(|e| anyhow!("dposv: {e}"))?;
                    lp::dpotrs(uplo, n, nrhs, a, av.dim("lda"), b, av.dim("ldb"));
                    Ok(())
                }
                _ => lp::dposv(uplo, n, nrhs, a, av.dim("lda"), b, av.dim("ldb"))
                    .map_err(|e| anyhow!("dposv: {e}")),
            }
        }
        "dtrtri" | "dtrti2" => {
            let n = av.dim("n");
            let a = ops.get_mut(0);
            let (uplo, diag) = (ul(av.flag("uplo"))?, dg(av.flag("diag"))?);
            let r = if name == "dtrti2" {
                lp::dtrti2(uplo, diag, n, a, av.dim("lda"))
            } else {
                match variant {
                    Variant::Unblocked => lp::dtrti2(uplo, diag, n, a, av.dim("lda")),
                    _ => lp::dtrtri(uplo, diag, n, a, av.dim("lda")),
                }
            };
            r.map_err(|e| anyhow!("{name}: {e}"))
        }
        "dsyev" | "dsyevd" | "dsyevx" | "dsyevr" => {
            let n = av.dim("n");
            let lda = av.dim("lda");
            let want_v = av.flag("jobz") == 'V';
            // validate the leading dimension before the solver mutates
            // A: the eigenvector writeback below slices
            // `a[j*lda..j*lda+n]`, which corrupts neighboring columns
            // (or panics mid-slice) when lda < n
            if lda < n {
                bail!("{name}: lda ({lda}) must be >= n ({n})");
            }
            let a = ops.get_mut(0);
            if n > 0 && a.len() < (n - 1) * lda + n {
                bail!(
                    "{name}: operand A has {} elements, need at least {} for n={n}, lda={lda}",
                    a.len(),
                    (n - 1) * lda + n
                );
            }
            let w = ops.get_mut(1);
            if w.len() < n {
                bail!("{name}: operand W has {} elements, need at least n={n}", w.len());
            }
            let res = match name {
                "dsyev" => lp::dsyev(n, a, lda, want_v),
                "dsyevd" => lp::dsyevd(n, a, lda, want_v),
                "dsyevx" => lp::dsyevx(n, a, lda, want_v),
                _ => lp::dsyevr(n, a, lda, want_v),
            }
            .map_err(|e| anyhow!("{name}: {e}"))?;
            w[..n].copy_from_slice(&res.values);
            if let Some(vecs) = res.vectors {
                // overwrite A with the eigenvectors (LAPACK jobz='V')
                for j in 0..n {
                    a[j * lda..j * lda + n].copy_from_slice(&vecs[j * n..(j + 1) * n]);
                }
            }
            Ok(())
        }
        "dtrsyl" => {
            let (m, n) = (av.dim("m"), av.dim("n"));
            if av.flag("transa") != 'N' || av.flag("transb") != 'N' {
                bail!("dtrsyl: only N/N supported");
            }
            let c = ops.get_mut(2);
            match variant {
                Variant::Unblocked => lp::dtrsyl_unblocked(
                    m, n, ops.get(0), av.dim("lda"), ops.get(1), av.dim("ldb"), c,
                    av.dim("ldc"),
                ),
                Variant::Blocked => lp::dtrsyl_blocked(
                    m, n, ops.get(0), av.dim("lda"), ops.get(1), av.dim("ldb"), c,
                    av.dim("ldc"), 64, 64,
                ),
                Variant::Recursive => lp::dtrsyl_recursive(
                    m, n, ops.get(0), av.dim("lda"), ops.get(1), av.dim("ldb"), c,
                    av.dim("ldc"),
                ),
            }
            .map_err(|e| anyhow!("dtrsyl: {e}"))
        }
        other => bail!("kernel '{other}' not implemented by rust libraries"),
    }
}

use std::collections::HashMap;
use std::sync::{OnceLock, RwLock};

static EXTRA: OnceLock<RwLock<HashMap<String, Arc<dyn KernelLibrary>>>> = OnceLock::new();

fn extra() -> &'static RwLock<HashMap<String, Arc<dyn KernelLibrary>>> {
    EXTRA.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Register an additional backend (used by [`crate::runtime`] to make
/// the `xla` PJRT backend resolvable by name once artifacts are
/// loaded).
pub fn register(name: &str, lib: Arc<dyn KernelLibrary>) {
    extra().write().unwrap().insert(name.to_string(), lib);
}

/// Construct/resolve a library backend by name. The three rust
/// libraries are always available; others (e.g. `xla`) must have been
/// [`register`]ed.
pub fn by_name(name: &str) -> Option<Arc<dyn KernelLibrary>> {
    match name {
        "rustref" => Some(Arc::new(RustLibrary::new("rustref", Variant::Unblocked))),
        "rustblocked" => Some(Arc::new(RustLibrary::new("rustblocked", Variant::Blocked))),
        "rustrecursive" => {
            Some(Arc::new(RustLibrary::new("rustrecursive", Variant::Recursive)))
        }
        other => extra().read().unwrap().get(other).cloned(),
    }
}

/// Names of the always-available rust libraries.
pub const RUST_LIBRARIES: &[&str] = &["rustref", "rustblocked", "rustrecursive"];

/// All backend names resolvable by [`by_name`] right now: the three
/// built-in rust libraries followed by any [`register`]ed extras
/// (sorted), e.g. `xla` once its runtime artifacts are loaded.
pub fn available_libraries() -> Vec<String> {
    let mut names: Vec<String> = RUST_LIBRARIES.iter().map(|s| s.to_string()).collect();
    let mut extras: Vec<String> = extra().read().unwrap().keys().cloned().collect();
    extras.sort();
    names.extend(extras);
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{lookup, ArgValue};
    use crate::linalg::Matrix;
    use crate::util::rng::Xoshiro256;

    fn args(sig_name: &str, toks: &[&str]) -> ArgValues {
        let sig = lookup(sig_name).unwrap();
        let values: Vec<ArgValue> = sig
            .args
            .iter()
            .zip(toks)
            .map(|((_, role), t)| match role {
                crate::kernels::ArgRole::Flag(_) => ArgValue::Char(t.chars().next().unwrap()),
                crate::kernels::ArgRole::Dim
                | crate::kernels::ArgRole::Ld
                | crate::kernels::ArgRole::Inc => ArgValue::Size(t.parse().unwrap()),
                crate::kernels::ArgRole::Scalar => ArgValue::Num(t.parse().unwrap()),
                crate::kernels::ArgRole::Data(_) => ArgValue::Data(t.to_string()),
            })
            .collect();
        ArgValues { sig, values }
    }

    fn opset(bufs: &mut [(&mut Vec<f64>, DataDir)]) -> OperandSet {
        OperandSet::new(
            bufs.iter_mut()
                .map(|(b, d)| RawOperand { ptr: b.as_mut_ptr(), len: b.len(), dir: *d })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn all_rust_libraries_run_gemm_identically_shaped() {
        let mut rng = Xoshiro256::seeded(200);
        let n = 40;
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let expect = a.matmul(&b);
        let ns = n.to_string();
        for lib_name in RUST_LIBRARIES {
            let lib = by_name(lib_name).unwrap();
            let av = args(
                "dgemm",
                &["N", "N", &ns, &ns, &ns, "1.0", "A", &ns, "B", &ns, "0.0", "C", &ns],
            );
            let mut abuf = a.data.clone();
            let mut bbuf = b.data.clone();
            let mut cbuf = vec![0.0; n * n];
            let ops = opset(&mut [
                (&mut abuf, DataDir::In),
                (&mut bbuf, DataDir::In),
                (&mut cbuf, DataDir::InOut),
            ]);
            lib.execute(&av, &ops).unwrap();
            let c = Matrix { m: n, n, data: cbuf };
            assert!(c.max_abs_diff(&expect) < 1e-10, "{lib_name}");
        }
    }

    #[test]
    fn gesv_via_library() {
        let mut rng = Xoshiro256::seeded(201);
        let n = 20;
        let a0 = Matrix::random_spd(n, &mut rng);
        let x = Matrix::random(n, 3, &mut rng);
        let b0 = a0.matmul(&x);
        let lib = by_name("rustblocked").unwrap();
        let av = args("dgesv", &["20", "3", "A", "20", "B", "20"]);
        let mut abuf = a0.data.clone();
        let mut bbuf = b0.data.clone();
        let ops = opset(&mut [(&mut abuf, DataDir::InOut), (&mut bbuf, DataDir::InOut)]);
        lib.execute(&av, &ops).unwrap();
        let sol = Matrix { m: n, n: 3, data: bbuf };
        assert!(sol.max_abs_diff(&x) < 1e-9);
    }

    #[test]
    fn syev_via_library_writes_w_and_vectors() {
        let mut rng = Xoshiro256::seeded(202);
        let n = 10;
        let a0 = Matrix::random_spd(n, &mut rng);
        let lib = by_name("rustref").unwrap();
        let av = args("dsyev", &["V", "L", "10", "A", "10", "W"]);
        let mut abuf = a0.data.clone();
        let mut wbuf = vec![0.0; n];
        let ops = opset(&mut [(&mut abuf, DataDir::InOut), (&mut wbuf, DataDir::Out)]);
        lib.execute(&av, &ops).unwrap();
        for w in wbuf.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(wbuf[0] > 0.0); // SPD
    }

    #[test]
    fn syev_rejects_lda_smaller_than_n() {
        let mut rng = Xoshiro256::seeded(203);
        let n = 10;
        let a0 = Matrix::random_spd(n, &mut rng);
        let lib = by_name("rustref").unwrap();
        // lda=8 < n=10: must error cleanly, not corrupt columns or
        // panic mid-slice in the eigenvector writeback
        let av = args("dsyev", &["V", "L", "10", "A", "8", "W"]);
        let mut abuf = a0.data.clone();
        let snapshot = abuf.clone();
        let mut wbuf = vec![0.0; n];
        let ops = opset(&mut [(&mut abuf, DataDir::InOut), (&mut wbuf, DataDir::Out)]);
        let err = lib.execute(&av, &ops).unwrap_err();
        assert!(err.to_string().contains("lda"), "{err}");
        // validation fires before the solver touches A
        assert_eq!(abuf, snapshot);
    }

    #[test]
    fn syev_rejects_short_operand_buffers() {
        let lib = by_name("rustref").unwrap();
        let av = args("dsyev", &["N", "L", "10", "A", "10", "W"]);
        let mut abuf = vec![0.0; 50]; // needs 10*10
        let mut wbuf = vec![0.0; 10];
        let ops = opset(&mut [(&mut abuf, DataDir::InOut), (&mut wbuf, DataDir::Out)]);
        let err = lib.execute(&av, &ops).unwrap_err();
        assert!(err.to_string().contains("operand A"), "{err}");
    }

    #[test]
    fn available_libraries_lists_builtins_first() {
        let names = available_libraries();
        assert!(names.len() >= RUST_LIBRARIES.len());
        assert_eq!(&names[..RUST_LIBRARIES.len()], RUST_LIBRARIES);
        for name in &names {
            assert!(by_name(name).is_some(), "{name} listed but not resolvable");
        }
    }

    #[test]
    fn overlapping_writable_operands_rejected() {
        let mut buf = vec![0.0f64; 100];
        let p = buf.as_mut_ptr();
        let r = OperandSet::new(vec![
            RawOperand { ptr: p, len: 60, dir: DataDir::In },
            RawOperand { ptr: unsafe { p.add(50) }, len: 50, dir: DataDir::InOut },
        ]);
        assert!(r.is_err());
        // disjoint is fine
        let r2 = OperandSet::new(vec![
            RawOperand { ptr: p, len: 50, dir: DataDir::In },
            RawOperand { ptr: unsafe { p.add(50) }, len: 50, dir: DataDir::InOut },
        ]);
        assert!(r2.is_ok());
        // read-read overlap is fine
        let r3 = OperandSet::new(vec![
            RawOperand { ptr: p, len: 60, dir: DataDir::In },
            RawOperand { ptr: unsafe { p.add(10) }, len: 50, dir: DataDir::In },
        ]);
        assert!(r3.is_ok());
    }

    #[test]
    fn trsyl_variants_match() {
        let mut rng = Xoshiro256::seeded(203);
        let n = 24;
        let a = Matrix::random_triangular(n, crate::linalg::Uplo::Upper, &mut rng);
        let b = Matrix::random_triangular(n, crate::linalg::Uplo::Upper, &mut rng);
        let c0 = Matrix::random(n, n, &mut rng);
        let ns = n.to_string();
        let mut results = vec![];
        for lib_name in RUST_LIBRARIES {
            let lib = by_name(lib_name).unwrap();
            let av = args(
                "dtrsyl",
                &["N", "N", "1", &ns, &ns, "A", &ns, "B", &ns, "C", &ns],
            );
            let mut abuf = a.data.clone();
            let mut bbuf = b.data.clone();
            let mut cbuf = c0.data.clone();
            let ops = opset(&mut [
                (&mut abuf, DataDir::In),
                (&mut bbuf, DataDir::In),
                (&mut cbuf, DataDir::InOut),
            ]);
            lib.execute(&av, &ops).unwrap();
            results.push(Matrix { m: n, n, data: cbuf });
        }
        assert!(results[0].max_abs_diff(&results[1]) < 1e-9);
        assert!(results[0].max_abs_diff(&results[2]) < 1e-9);
    }

    #[test]
    fn unknown_kernel_errors() {
        let lib = by_name("rustref").unwrap();
        // dgemv signature misused on purpose is hard to build; check
        // by_name on bogus library instead
        assert!(by_name("openblas").is_none());
        let _ = lib;
    }
}
