//! Experiment execution (§3.2.1 "submit"): run locally, or through the
//! batch-job spooler that substitutes the paper's LoadLeveler/LSF
//! workflows (DESIGN.md §Substitutions 5).
//!
//! The spooler is multi-host capable: claims are explicit, heartbeat-
//! renewed leases with epoch fencing ([`crate::coordinator::lease`])
//! rather than mtime-staleness guesses, so workers on several machines
//! can drain one spool directory on a shared filesystem and a zombie
//! worker's late publish is rejected instead of corrupting the output.

use super::campaign::{self, Stamp, StampOutcome};
use super::experiment::Experiment;
use super::io;
use super::lease::{self, FenceReason, Lease, PublishOutcome};
use super::report::Report;
use crate::obs::emit::Emitter;
use crate::obs::events::EventKind;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Run an experiment on in-process samplers (the "local" backend).
///
/// One fresh sampler per parameter-range point, exactly as the paper
/// starts the sampler separately per thread count / range value.
/// Routes through the [`crate::engine`] with the process-default
/// configuration — serial and uncached unless the CLI's `--jobs` /
/// `--cache` flags or the `ELAPS_JOBS` / `ELAPS_CACHE` environment
/// variables say otherwise.
pub fn run_local(exp: &Experiment) -> Result<Report> {
    crate::engine::Engine::with_defaults().run(exp)
}

/// Default lease TTL when neither `with_ttl` nor `ELAPS_LEASE_TTL`
/// says otherwise: comfortably above typical job runtimes, so
/// heartbeat-less [`Spooler::serve_one`] stays safe.
const DEFAULT_LEASE_TTL: Duration = Duration::from_secs(300);

/// A job this worker has claimed: the queue entry renamed into
/// `<spool>/running/` plus the lease acquired for it. Produced by
/// [`Spooler::claim_next`]; consumed by [`Spooler::serve_claim`] /
/// [`Spooler::publish`].
#[derive(Debug, Clone)]
pub struct ClaimedJob {
    pub job_id: String,
    /// The lease as acquired. Renewals extend the on-disk expiry
    /// without updating this copy — fencing always re-reads the disk.
    pub lease: Lease,
    /// The claim file in `<spool>/running/`.
    running: PathBuf,
    /// The job file's contents (the experiment JSON).
    pub text: String,
    /// The backpressure slot this claim occupies (only when the
    /// spooler has a `max_leases` cap). Held purely for its drop glue:
    /// the slot frees when the last clone of the claim is dropped, so
    /// its lifetime covers the lease's whole claim-execute-publish
    /// span.
    _slot: Option<SlotGuard>,
}

/// One occupied backpressure slot. Cloned with the claim; the
/// underlying slot is returned to the pool when the last clone drops.
#[derive(Debug, Clone)]
struct SlotGuard {
    /// Held only for its [`SlotRelease`] drop glue.
    _release: Arc<SlotRelease>,
}

#[derive(Debug)]
struct SlotRelease {
    held: Arc<AtomicUsize>,
}

impl Drop for SlotRelease {
    fn drop(&mut self) {
        self.held.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Why [`Spooler::try_claim`] returned without a job.
#[derive(Debug, Clone)]
pub enum ClaimOutcome {
    /// A job was claimed and leased.
    Claimed(ClaimedJob),
    /// The queue is empty (for this pass — a concurrent submit may
    /// land right after).
    Empty,
    /// Jobs are queued, but this host already holds `max_leases` live
    /// leases: claiming must wait until an in-flight job publishes or
    /// a lease expires. A capped host with an *empty* queue reports
    /// [`ClaimOutcome::Empty`] instead, so `--once` pools can exit.
    Backpressured,
}

/// The batch spooler: `submit` drops a job file into `<spool>/queue`;
/// a worker (`elaps worker`, or [`Spooler::serve_one`] in-process)
/// leases it, runs it, and publishes the report to `<spool>/done`.
/// `wait` polls for the report — the same submit → poll → fetch
/// workflow the paper uses with LoadLeveler and LSF, extended with the
/// lease protocol so many hosts can serve one spool.
#[derive(Debug, Clone)]
pub struct Spooler {
    pub dir: PathBuf,
    /// This handle's hostname (lease + provenance identity).
    host: String,
    /// This handle's worker identity (unique per handle).
    worker_id: String,
    /// Lease TTL: how long a claim stays valid without a renewal.
    ttl: Duration,
    /// Per-host lease backpressure: at most this many live leases for
    /// this host at once ([`Spooler::try_claim`]); `None` = unlimited.
    max_leases: Option<usize>,
    /// Slots currently occupied by in-flight claims of this handle and
    /// its clones (a worker pool shares one counter, so in-process
    /// enforcement of `max_leases` is exact; the on-disk lease count
    /// additionally throttles against other processes on this host).
    slots_held: Arc<AtomicUsize>,
    /// Job-lifecycle event emitter, appending to
    /// `<spool>/events/<host>.jsonl` ([`crate::obs`]). Default-on;
    /// `--no-events` / `ELAPS_EVENTS=0` disable it. Never fails a job.
    events: Emitter,
    /// Mirror fence diagnostics to stderr (`elaps worker --verbose`);
    /// the structured `fenced` event is emitted either way.
    verbose: bool,
    /// Claim candidates from the last `<spool>/queue` scan, oldest
    /// first, shared by all clones of this handle so a worker pool
    /// drains one batch per scan instead of re-scanning (and
    /// re-sorting) the whole queue on every claim
    /// ([`Spooler::try_claim`]). Entries may be stale — each claim
    /// re-checks the job under its per-job lease lock.
    claim_batch: Arc<Mutex<VecDeque<String>>>,
}

/// Why [`Spooler::claim_candidate`] did not produce a claim.
enum CandidateOutcome {
    /// The candidate was claimed and leased.
    Claimed(ClaimedJob),
    /// The candidate is no longer claimable (another worker took it
    /// since the scan) — move on to the next one.
    Gone,
    /// This host's live leases (counting every process) are at the
    /// `max_leases` cap, proven by a fresh scan under the host cap
    /// lock. No lease was written.
    AtCap,
}

impl Spooler {
    /// Open (creating if needed) a spool directory. The handle's
    /// identity defaults to this process on this host; the lease TTL
    /// comes from `ELAPS_LEASE_TTL` (e.g. `90s`, `5m`) or defaults to
    /// 300 s.
    pub fn new(dir: impl AsRef<Path>) -> Result<Spooler> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(dir.join("queue"))?;
        std::fs::create_dir_all(dir.join("running"))?;
        std::fs::create_dir_all(dir.join("done"))?;
        std::fs::create_dir_all(dir.join("leases"))?;
        std::fs::create_dir_all(dir.join("stamps"))?;
        let ttl = std::env::var("ELAPS_LEASE_TTL")
            .ok()
            .and_then(|v| crate::util::cli::parse_duration(&v).ok())
            .filter(|d| !d.is_zero())
            .unwrap_or(DEFAULT_LEASE_TTL);
        let host = crate::util::hostid::hostname().to_string();
        let worker_id = crate::util::hostid::new_worker_id();
        let events = Emitter::for_spool(&dir, &host, &worker_id);
        Ok(Spooler {
            dir,
            host,
            worker_id,
            ttl,
            max_leases: None,
            slots_held: Arc::new(AtomicUsize::new(0)),
            events,
            verbose: false,
            claim_batch: Arc::new(Mutex::new(VecDeque::new())),
        })
    }

    /// Override the host identity recorded in leases and provenance
    /// (tests simulate multi-host fleets this way).
    pub fn with_host(mut self, host: impl Into<String>) -> Spooler {
        let host = host.into();
        self.events = self.events.with_host(&host);
        self.host = host;
        self
    }

    /// Override the worker identity.
    pub fn with_worker(mut self, worker_id: impl Into<String>) -> Spooler {
        let worker_id = worker_id.into();
        self.events = self.events.with_worker(&worker_id);
        self.worker_id = worker_id;
        self
    }

    /// Tag this handle's events with a campaign
    /// ([`super::campaign::submit_experiments`] does this for the
    /// submitting client — workers never know the campaign; `elaps
    /// analyze --campaign` joins their events via the campaign record).
    pub fn with_campaign(mut self, tag: &str) -> Spooler {
        self.events = self.events.with_campaign(tag);
        self
    }

    /// Force event emission on or off, overriding `ELAPS_EVENTS` (the
    /// CLI's `--no-events` passes `false`; tests pass `true` to pin
    /// behavior regardless of the environment).
    pub fn with_events(mut self, enabled: bool) -> Spooler {
        self.events = self.events.with_enabled(enabled);
        self
    }

    /// Mirror fence diagnostics to stderr (`elaps worker --verbose`).
    pub fn with_verbose(mut self, verbose: bool) -> Spooler {
        self.verbose = verbose;
        self
    }

    /// Override the lease TTL. Zero is rejected (it would make every
    /// claim instantly reclaimable).
    pub fn with_ttl(mut self, ttl: Duration) -> Spooler {
        if !ttl.is_zero() {
            self.ttl = ttl;
        }
        self
    }

    /// Cap the number of live leases this host may hold at once (the
    /// `elaps worker --max-leases` backpressure). `0` removes the cap.
    /// Worker-pool clones of this handle share one slot counter, so
    /// enforcement within a daemon is cheap and exact; *across*
    /// processes every lease write runs under the host's on-disk cap
    /// lock against a shared counter, resynced by a fresh lease scan
    /// whenever it cannot prove the cap — so an observer scanning
    /// `<spool>/leases/` never counts more than `max` live leases for
    /// this host, no matter how many capped processes share it.
    pub fn with_max_leases(mut self, max: usize) -> Spooler {
        self.max_leases = if max == 0 { None } else { Some(max) };
        self
    }

    pub fn host(&self) -> &str {
        &self.host
    }

    pub fn worker_id(&self) -> &str {
        &self.worker_id
    }

    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    /// The per-host live-lease cap, if any.
    pub fn max_leases(&self) -> Option<usize> {
        self.max_leases
    }

    /// Submit an experiment; returns the job id. The id embeds a
    /// process-local sequence number besides the timestamp, so rapid
    /// submissions from one process can never collide.
    pub fn submit(&self, exp: &Experiment) -> Result<String> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let job_id = format!(
            "{}-{:x}-{}",
            exp.name.replace(['/', ' '], "_"),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = self.dir.join("queue").join(format!("{job_id}.json"));
        let tmp = unique_tmp(&path);
        std::fs::write(&tmp, io::experiment_to_json(exp).to_string_pretty())?;
        std::fs::rename(&tmp, &path)?; // atomic enqueue
        self.events.emit(EventKind::Submitted, &job_id, 0, &[]);
        Ok(job_id)
    }

    /// Atomically claim the oldest-scanned queued job: acquire its
    /// lease (epoch = previous epoch + 1, expiry = now + TTL) and
    /// rename it into `<spool>/running/`, both under the job's lease
    /// lock ([`lease::lock_job`]). The lease is written *before* the
    /// rename so a claimer that crashes between the two steps leaves a
    /// queued job whose lease simply expires — never a lease-less
    /// running job recoverable only by the slow legacy mtime heuristic.
    /// Losing a job to a concurrent worker is not an error — the
    /// claimer just moves on to the next candidate.
    ///
    /// Claims are batched: one queue scan (read_dir + sort) feeds a
    /// candidate list shared by all clones of this handle, so a worker
    /// pool draining an N-job queue scans it O(N / batch) times instead
    /// of once per claim. Candidates may be stale by claim time; each
    /// is re-validated under its per-job lock, and `Empty` is only ever
    /// reported after a fresh scan found nothing claimable.
    ///
    /// With a `max_leases` cap, a claim is refused
    /// ([`ClaimOutcome::Backpressured`]) while this host already holds
    /// that many live leases: the slot is taken *before* the lease is
    /// written and released only after the claim's lease is gone, so an
    /// observer scanning `<spool>/leases/` never counts more than
    /// `max_leases` live leases for this host.
    pub fn try_claim(&self) -> Result<ClaimOutcome> {
        self.try_claim_impl(Option::<fn(&str)>::None)
    }

    /// [`Spooler::try_claim`] with a fault-injection hook fired once,
    /// between the first candidate's lease write and its queue→running
    /// rename — the window where a crashing claimer historically
    /// stranded a lease-less running job. Tests use it to simulate that
    /// crash (by panicking or stealing the queue file) and to observe
    /// the on-disk ordering.
    #[doc(hidden)]
    pub fn try_claim_with_pause(&self, pause: impl FnOnce(&str)) -> Result<ClaimOutcome> {
        self.try_claim_impl(Some(pause))
    }

    fn try_claim_impl<F: FnOnce(&str)>(&self, mut pause: Option<F>) -> Result<ClaimOutcome> {
        // Backpressured only when there is actually something to be
        // backpressured *from*: a capped host with an empty queue is
        // Empty, so --once pools terminate instead of spinning on a
        // neighbor's leases.
        let at_capacity = |spooler: &Spooler| -> Result<ClaimOutcome> {
            Ok(if spooler.queued()? == 0 {
                ClaimOutcome::Empty
            } else {
                ClaimOutcome::Backpressured
            })
        };
        let slot = match self.max_leases {
            None => None,
            Some(cap) => {
                // in-process slot first (exact within a worker pool)
                let mut cur = self.slots_held.load(Ordering::SeqCst);
                loop {
                    if cur >= cap {
                        return at_capacity(self);
                    }
                    match self.slots_held.compare_exchange(
                        cur,
                        cur + 1,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    ) {
                        Ok(_) => break,
                        Err(seen) => cur = seen,
                    }
                }
                // the cross-process arm of the cap — leases of this
                // host written by other processes, or left behind by a
                // crashed claim — is checked under the host cap lock at
                // lease-write time in claim_candidate
                Some(SlotGuard {
                    _release: Arc::new(SlotRelease { held: self.slots_held.clone() }),
                })
            }
        };
        // Drain the shared candidate batch; rescan the queue only when
        // it runs dry (at most once per call — a second dry batch means
        // a racing clone drained the refill, and its claims cover the
        // queue).
        let mut refilled = false;
        loop {
            let candidate = self.claim_batch.lock().unwrap().pop_front();
            let Some(job_id) = candidate else {
                if refilled || !self.refill_claim_batch()? {
                    return Ok(ClaimOutcome::Empty);
                }
                refilled = true;
                continue;
            };
            match self.claim_candidate(&job_id, &mut pause)? {
                CandidateOutcome::Claimed(claimed) => {
                    return Ok(ClaimOutcome::Claimed(ClaimedJob { _slot: slot, ..claimed }));
                }
                CandidateOutcome::Gone => {}
                CandidateOutcome::AtCap => {
                    // the candidate was not consumed — put it back for
                    // whoever claims once capacity frees up
                    self.claim_batch.lock().unwrap().push_front(job_id);
                    return at_capacity(self);
                }
            }
        }
    }

    /// Rescan `<spool>/queue` into the shared candidate batch (sorted
    /// by file name, i.e. submission order within the scan). Returns
    /// whether any candidate is available afterwards. The batch lock is
    /// held across the scan so concurrent dry claimers serialize here
    /// instead of doubling the batch; a batch found already refilled by
    /// the time the lock is acquired is taken as-is.
    fn refill_claim_batch(&self) -> Result<bool> {
        let mut batch = self.claim_batch.lock().unwrap();
        if !batch.is_empty() {
            return Ok(true);
        }
        let mut names: Vec<std::ffi::OsString> = std::fs::read_dir(self.dir.join("queue"))?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .map(|e| e.file_name())
            .collect();
        names.sort();
        batch.extend(names.iter().map(|n| path_job_id(Path::new(n))));
        Ok(!batch.is_empty())
    }

    /// Try to claim one scanned candidate; [`CandidateOutcome::Gone`]
    /// (not an error) when the job is no longer claimable — another
    /// worker took it since the scan. All on-disk steps run under the
    /// job's lease lock, and the lease is written before the
    /// queue→running rename: any job visible in `running/` already has
    /// a lease, and a lease written here is withdrawn if the rename is
    /// lost to a claimer outside the lock (an older binary sharing the
    /// spool).
    fn claim_candidate<F: FnOnce(&str)>(
        &self,
        job_id: &str,
        pause: &mut Option<F>,
    ) -> Result<CandidateOutcome> {
        let queued = self.dir.join("queue").join(format!("{job_id}.json"));
        let running = self.dir.join("running").join(format!("{job_id}.json"));
        let lock = lease::lock_job(&self.dir, job_id)?;
        // Under the lock the job must still be queued: the lease
        // written below names this worker, and writing it over the
        // lease of a job some other worker is already running would
        // fence that worker for nothing.
        if !queued.exists() {
            return Ok(CandidateOutcome::Gone);
        }
        // Acquire the lease. The epoch chains across the job's whole
        // claim history (the previous lease file is left in place by
        // expiry reclaims precisely so this read sees it), which is
        // what fences a previous holder's late publish.
        let epoch = lease::read(&self.dir, job_id).map(|l| l.epoch).unwrap_or(0) + 1;
        let l = Lease {
            job_id: job_id.to_string(),
            worker_id: self.worker_id.clone(),
            host: self.host.clone(),
            epoch,
            expires_unix: lease::now_unix() + self.ttl.as_secs_f64(),
        };
        // Cross-process arm of the `max_leases` cap, taken *before* the
        // lease write it guards: under the host cap lock, prove the cap
        // via the shared counter (cheap) or a fresh lease scan (when
        // the counter cannot prove it), and record the write. Holding
        // the cap lock across the lease write keeps the counter an
        // upper bound on this host's live leases at every instant, so
        // an observer never counts more than `cap` — regardless of how
        // many capped processes share the host.
        let cap_guard = match self.max_leases {
            None => None,
            Some(cap) => match self.cap_acquire(cap)? {
                Some(guard) => Some(guard),
                None => return Ok(CandidateOutcome::AtCap),
            },
        };
        lease::write(&self.dir, &l)?;
        drop(cap_guard);
        if let Some(pause) = pause.take() {
            pause(job_id);
        }
        match std::fs::rename(&queued, &running) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // Lost the rename to a claimer not holding the job
                // lock: withdraw the lease written above, but only if
                // it is still exactly ours — the winner may have
                // re-written it already.
                if lease::read(&self.dir, job_id).as_ref() == Some(&l) {
                    lease::remove(&self.dir, job_id)?;
                    self.cap_release();
                }
                return Ok(CandidateOutcome::Gone);
            }
            Err(e) => return Err(e.into()),
        }
        drop(lock);
        let text = match std::fs::read_to_string(&running) {
            Ok(text) => text,
            // a concurrent recover_stale requeued it already
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(CandidateOutcome::Gone),
            Err(e) => return Err(e.into()),
        };
        self.events.emit(EventKind::Claimed, job_id, epoch, &[]);
        Ok(CandidateOutcome::Claimed(ClaimedJob {
            job_id: job_id.to_string(),
            lease: l,
            running,
            text,
            _slot: None,
        }))
    }

    /// This host's cap-lock and cap-counter sidecars in
    /// `<spool>/leases/`. Dot-prefixed and non-`.json`, so every lease
    /// scan ignores them.
    fn cap_paths(&self) -> (PathBuf, PathBuf) {
        let dir = self.dir.join("leases");
        (
            dir.join(format!(".cap-{}.lock", self.host)),
            dir.join(format!(".cap-{}.count", self.host)),
        )
    }

    /// Take the host cap lock and prove there is room for one more
    /// lease: `None` if this host's live leases (across all processes)
    /// are at `cap` — proven by a fresh `<spool>/leases/` scan, never
    /// by the counter alone, so a drifted counter can cost a scan but
    /// never a wrong refusal. On success the counter is advanced past
    /// the upcoming lease write and the held lock is returned; the
    /// caller writes the lease, then drops the lock.
    ///
    /// The counter only ever over-counts: a crash between the counter
    /// write and the lease write (or a lease expiring away without its
    /// holder) strands an increment, which the next at-cap scan
    /// resyncs. An under-count — the direction that would let an
    /// observer see `cap + 1` — would need a decrement without a
    /// removed lease, and [`Spooler::cap_release`] decrements only
    /// after removing one.
    fn cap_acquire(&self, cap: usize) -> Result<Option<lease::JobLock>> {
        let (lock_path, count_path) = self.cap_paths();
        let guard = lease::flock_path(&lock_path, false)?;
        let counted = std::fs::read_to_string(&count_path)
            .ok()
            .and_then(|t| t.trim().parse::<usize>().ok());
        let live = match counted {
            Some(n) if n < cap => n,
            // missing, unparsable, or cannot prove room: fresh scan
            _ => {
                let fresh = lease::live_leases_for_host(&self.dir, &self.host)?;
                if fresh >= cap {
                    let _ = std::fs::write(&count_path, fresh.to_string());
                    return Ok(None);
                }
                fresh
            }
        };
        std::fs::write(&count_path, (live + 1).to_string())?;
        Ok(Some(guard))
    }

    /// Decrement the host cap counter after removing one of this
    /// host's live leases. A missing or unparsable counter is left
    /// alone — the next at-cap scan resyncs it; guessing here could
    /// under-count, which is the one direction that would break the
    /// observer-visible cap.
    fn cap_release(&self) {
        if self.max_leases.is_none() {
            return;
        }
        let (lock_path, count_path) = self.cap_paths();
        let Ok(_guard) = lease::flock_path(&lock_path, false) else {
            return;
        };
        if let Some(n) = std::fs::read_to_string(&count_path)
            .ok()
            .and_then(|t| t.trim().parse::<usize>().ok())
        {
            let _ = std::fs::write(&count_path, n.saturating_sub(1).to_string());
        }
    }

    /// [`Spooler::try_claim`] flattened to an `Option`: `None` covers
    /// both an empty queue and a backpressured host. Callers that must
    /// distinguish the two (the worker daemon's `--once` loop) use
    /// `try_claim` directly.
    pub fn claim_next(&self) -> Result<Option<ClaimedJob>> {
        Ok(match self.try_claim()? {
            ClaimOutcome::Claimed(c) => Some(c),
            ClaimOutcome::Empty | ClaimOutcome::Backpressured => None,
        })
    }

    /// Heartbeat: extend the claim's on-disk lease by one TTL. Returns
    /// `false` (without touching anything) when the lease is no longer
    /// ours to renew — expired, superseded by a newer epoch, or gone —
    /// at which point the worker should abandon the job: its publish
    /// would be fenced anyway.
    pub fn renew(&self, claim: &ClaimedJob) -> Result<bool> {
        self.renew_impl(claim, || {})
    }

    /// [`Spooler::renew`] with a test hook injected into the historical
    /// race window — after the optimistic check, before the locked
    /// re-verify — so the regression test can deterministically land an
    /// expiry + reclaim + re-acquisition exactly where the unserialized
    /// renew used to write its stale epoch back over the successor's.
    #[doc(hidden)]
    pub fn renew_with_pause(&self, claim: &ClaimedJob, pause: impl FnOnce()) -> Result<bool> {
        self.renew_impl(claim, pause)
    }

    fn renew_impl(&self, claim: &ClaimedJob, pause: impl FnOnce()) -> Result<bool> {
        // Optimistic pre-check without the lock: a lease that is
        // already lost needs nothing serialized.
        let Some(current) = lease::read(&self.dir, &claim.job_id) else {
            return Ok(false);
        };
        if current.worker_id != claim.lease.worker_id
            || current.epoch != claim.lease.epoch
            || current.expired_at(lease::now_unix())
        {
            return Ok(false);
        }
        pause();
        // The renewal is a read-modify-write: between the check above
        // and the write below, an expiry reclaim can hand the job to a
        // new worker at epoch e+1, and writing the stale epoch e back
        // would let *both* workers pass the publish fence. So the
        // decision is re-made under the per-job lease lock against
        // fresh state — claim acquisitions write under the same lock,
        // so the on-disk epoch can never regress.
        let _lock = lease::lock_job(&self.dir, &claim.job_id)?;
        let Some(current) = lease::read(&self.dir, &claim.job_id) else {
            return Ok(false);
        };
        let now = lease::now_unix();
        if current.worker_id != claim.lease.worker_id
            || current.epoch != claim.lease.epoch
            || current.expired_at(now)
        {
            return Ok(false);
        }
        let renewed = Lease { expires_unix: now + self.ttl.as_secs_f64(), ..current };
        lease::write(&self.dir, &renewed)?;
        self.events.emit(EventKind::Heartbeat, &claim.job_id, claim.lease.epoch, &[]);
        Ok(true)
    }

    /// Fenced, atomic publish of a claimed job's report payload.
    ///
    /// The fence: the on-disk lease must still name this claim's
    /// `(worker_id, epoch)` and be unexpired — otherwise the claim was
    /// (or is about to be) reclaimed, and writing would race the
    /// reclaim's re-execution. A fenced publish writes nothing and
    /// reports why ([`FenceReason`]). On success the report lands in
    /// `<spool>/done/` via temp + rename (readers only ever see a
    /// complete report), then the claim and lease are released.
    pub fn publish(&self, claim: &ClaimedJob, payload: &str) -> Result<PublishOutcome> {
        if let Some(reason) = self.fence_reason(claim) {
            self.record_fence(claim, &reason);
            return Ok(PublishOutcome::Fenced(reason));
        }
        let done = self.dir.join("done").join(format!("{}.report.json", claim.job_id));
        let tmp = unique_tmp(&done);
        std::fs::write(&tmp, payload)?;
        // Re-check the fence right before the rename: the payload
        // write above is the slow step (a multi-megabyte report over
        // NFS), and a publisher that stalled in it must not overwrite
        // a successor's already-published report on wake-up. The
        // remaining stall window is the rename syscall itself —
        // at-least-once semantics (last writer wins) still cover it.
        if let Some(reason) = self.fence_reason(claim) {
            let _ = std::fs::remove_file(&tmp);
            self.record_fence(claim, &reason);
            return Ok(PublishOutcome::Fenced(reason));
        }
        std::fs::rename(&tmp, &done)?;
        // Proceed only with what is still ours: if the lease expired in
        // the tiny window since the fence check and a successor already
        // re-acquired the job, its claim and epoch-bumped lease must
        // not be torn down — and the stamp sidecar must not be written
        // either, or a publisher stalled mid-publish could pair its
        // stale stamp with the successor's report. The successor
        // finishes and republishes report *and* stamp (at-least-once,
        // last writer wins).
        let still_ours = lease::read(&self.dir, &claim.job_id)
            .is_some_and(|l| {
                l.worker_id == claim.lease.worker_id && l.epoch == claim.lease.epoch
            });
        let outcome = match crate::util::json::Json::parse(payload) {
            Ok(j) if j.get("error").is_null() => StampOutcome::Ok,
            _ => StampOutcome::Error,
        };
        if still_ours {
            // Stamp sidecar: the O(#jobs) index over done reports that
            // `spool status` and campaign-level wait read instead of
            // the report bodies. Written right after the report (a
            // crash in between leaves a report with "(unknown)"
            // provenance, never a stamp without its report).
            campaign::write_stamp(
                &self.dir,
                &Stamp {
                    job_id: claim.job_id.clone(),
                    host: claim.lease.host.clone(),
                    worker: claim.lease.worker_id.clone(),
                    epoch: claim.lease.epoch,
                    outcome,
                },
            )?;
            // claim file first, lease last (a crash in between leaves
            // a reclaimable claim whose re-execution republishes the
            // same report — consistent)
            match std::fs::remove_file(&claim.running) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
            lease::remove(&self.dir, &claim.job_id)?;
            self.cap_release();
        }
        self.events.emit(
            EventKind::Published,
            &claim.job_id,
            claim.lease.epoch,
            &[("outcome", outcome.as_str().into())],
        );
        Ok(PublishOutcome::Published)
    }

    /// Record a fenced publish: always as a structured `fenced` event,
    /// mirrored to stderr only under `--verbose` — the daemon's
    /// default output stays stable and greppable.
    fn record_fence(&self, claim: &ClaimedJob, reason: &FenceReason) {
        let label = match reason {
            FenceReason::Expired { .. } => "expired",
            FenceReason::Superseded { .. } => "superseded",
            FenceReason::LeaseGone => "lease_gone",
        };
        self.events.emit(
            EventKind::Fenced,
            &claim.job_id,
            claim.lease.epoch,
            &[("reason", label.into())],
        );
        if self.verbose {
            eprintln!(
                "warning: publish of job {} fenced ({reason:?}); a reclaimer owns it",
                claim.job_id
            );
        }
    }

    /// The publish fence, evaluated against the on-disk lease: `None`
    /// while the lease still names this claim's `(worker_id, epoch)`
    /// and is unexpired, otherwise why the publish must be refused.
    fn fence_reason(&self, claim: &ClaimedJob) -> Option<FenceReason> {
        match lease::read(&self.dir, &claim.job_id) {
            Some(l)
                if l.worker_id == claim.lease.worker_id && l.epoch == claim.lease.epoch =>
            {
                if l.expired_at(lease::now_unix()) {
                    Some(FenceReason::Expired { expires_unix: l.expires_unix })
                } else {
                    None
                }
            }
            Some(l) => Some(FenceReason::Superseded {
                current_epoch: l.epoch,
                current_worker: l.worker_id,
            }),
            None => Some(FenceReason::LeaseGone),
        }
    }

    /// The `served_by` provenance stamp folded into every published
    /// report: which host/worker, under which lease epoch, produced it.
    fn served_by_json(&self, epoch: u64) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("host", self.host.as_str())
            .set("worker", self.worker_id.as_str())
            .set("epoch", epoch);
        j
    }

    /// Execute a claimed job and render its report payload (never
    /// errors: a malformed job file is the job's failure, not the
    /// worker's — it is published as an error report like any failed
    /// run, so poison jobs cannot crash-loop the worker).
    fn execute_payload(&self, claim: &ClaimedJob) -> String {
        let result = crate::util::json::Json::parse(&claim.text)
            .map_err(|e| anyhow!("invalid job file: {e}"))
            .and_then(|j| io::experiment_from_json(&j))
            .and_then(|exp| run_local(&exp));
        let mut j = match result {
            Ok(report) => io::report_to_json(&report),
            Err(e) => {
                let mut j = crate::util::json::Json::obj();
                j.set("error", format!("{e:#}"));
                j
            }
        };
        j.set("served_by", self.served_by_json(claim.lease.epoch));
        j.to_string_pretty()
    }

    /// [`Spooler::execute_payload`] bracketed by `serve_started` /
    /// `serve_finished` events, with the thread-local job context set
    /// for the execution span so spool-less layers (the engine's cache
    /// probe) can attribute their events to this job.
    fn execute_payload_observed(&self, claim: &ClaimedJob) -> String {
        let epoch = claim.lease.epoch;
        self.events.emit(EventKind::ServeStarted, &claim.job_id, epoch, &[]);
        let ctx = crate::obs::emit::enter_job(&self.events, &claim.job_id, epoch);
        let payload = self.execute_payload(claim);
        drop(ctx);
        let outcome = match crate::util::json::Json::parse(&payload) {
            Ok(j) if j.get("error").is_null() => "ok",
            _ => "error",
        };
        self.events.emit(
            EventKind::ServeFinished,
            &claim.job_id,
            epoch,
            &[("outcome", outcome.into())],
        );
        payload
    }

    /// Run a claimed job and publish its report. With `heartbeat`, a
    /// sidecar thread renews the lease every TTL/3 while the job
    /// executes, so jobs may outlive a single TTL; without it the job
    /// must finish within one TTL or its publish is fenced (useful in
    /// tests that drive the fence deliberately).
    pub fn serve_claim(&self, claim: &ClaimedJob, heartbeat: bool) -> Result<PublishOutcome> {
        let payload = if heartbeat {
            let stop = AtomicBool::new(false);
            let interval = (self.ttl / 3).max(Duration::from_millis(10));
            std::thread::scope(|s| {
                s.spawn(|| {
                    let mut last = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(10));
                        if last.elapsed() >= interval {
                            last = Instant::now();
                            match self.renew(claim) {
                                // lease cleanly lost (expired,
                                // superseded, gone): stop renewing and
                                // let the publish fence report it
                                Ok(false) => break,
                                Ok(true) => {}
                                // transient fs error (NFS hiccup):
                                // keep the heartbeat alive and retry
                                // on the next tick
                                Err(_) => {}
                            }
                        }
                    }
                });
                let payload = self.execute_payload_observed(claim);
                stop.store(true, Ordering::Relaxed);
                payload
            })
        } else {
            self.execute_payload_observed(claim)
        };
        self.publish(claim, &payload)
    }

    /// Worker side: take one queued job (if any), run it with the
    /// heartbeat keeping the lease alive (so jobs longer than one TTL
    /// are safe on every path), publish the report. Returns the
    /// processed job id; a fenced publish (this worker lost the job to
    /// a reclaim) is recorded as a `fenced` event — and mirrored to
    /// stderr under `--verbose` — the reclaiming worker owns the job
    /// now.
    pub fn serve_one(&self) -> Result<Option<String>> {
        let Some(claim) = self.claim_next()? else {
            return Ok(None);
        };
        let job_id = claim.job_id.clone();
        self.serve_claim(&claim, true)?;
        Ok(Some(job_id))
    }

    /// Requeue jobs whose claims are dead: leased claims whose lease
    /// has **expired** (the lease protocol — `legacy_max_age` plays no
    /// part), and legacy claims (a file in `running/` with no lease,
    /// e.g. from a pre-lease worker) whose claim-file mtime is older
    /// than `legacy_max_age`. Lease files are deliberately left in
    /// place: they carry the fencing epoch the next claimer bumps.
    /// Returns the number of jobs requeued.
    ///
    /// Reclaim gives at-least-once semantics: between a lease's expiry
    /// and its holder noticing, the job can be re-executed; both
    /// executions publish complete reports atomically and the zombie's
    /// is fenced out, so readers still see exactly one report.
    pub fn recover_stale(&self, legacy_max_age: Duration) -> Result<usize> {
        self.recover_stale_impl(legacy_max_age, |_| {})
    }

    /// [`Spooler::recover_stale`] with a fault-injection hook fired per
    /// candidate, between the unlocked staleness pre-check and the
    /// locked re-verify — the window where an unserialized reclaimer
    /// historically raced a concurrent reclaim + re-claim and stole the
    /// successor's live claim. Tests pause a reclaimer there.
    #[doc(hidden)]
    pub fn recover_stale_with_pause(
        &self,
        legacy_max_age: Duration,
        pause: impl FnMut(&str),
    ) -> Result<usize> {
        self.recover_stale_impl(legacy_max_age, pause)
    }

    fn recover_stale_impl(
        &self,
        legacy_max_age: Duration,
        mut pause: impl FnMut(&str),
    ) -> Result<usize> {
        let running = self.dir.join("running");
        let mut recovered = 0;
        for entry in std::fs::read_dir(&running)?.filter_map(|e| e.ok()) {
            let path = entry.path();
            if !path.extension().is_some_and(|x| x == "json") {
                continue;
            }
            let job_id = path_job_id(&path);
            // Unlocked pre-check: skip obviously live claims without
            // touching their job lock. Anything that looks stale is
            // re-verified under the lock below — this check alone
            // proves nothing, because a reclaim + fresh claim can land
            // between it and the rename.
            if !self.claim_is_stale(&entry, &job_id, legacy_max_age) {
                continue;
            }
            pause(&job_id);
            // Re-verify under the job's lease lock, like every other
            // lease read-modify-write: a merely-paused legacy claimer
            // whose job a concurrent reclaimer already requeued (and a
            // fresh worker re-claimed) must not be "reclaimed" again —
            // the claim in running/ now belongs to the new holder.
            let _lock = lease::lock_job(&self.dir, &job_id)?;
            if !self.claim_is_stale(&entry, &job_id, legacy_max_age) {
                continue;
            }
            let dest = self.dir.join("queue").join(path.file_name().unwrap());
            match std::fs::rename(&path, &dest) {
                Ok(()) => recovered += 1,
                // the (not so dead) worker finished or a concurrent
                // reclaimer got there first
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(recovered)
    }

    /// Whether one `running/` claim is reclaimable *right now*: a
    /// leased claim whose lease has expired, or a legacy (lease-less)
    /// claim whose file mtime — re-stat'd on every call, never cached
    /// across a lock acquisition — is older than `legacy_max_age`.
    /// Only a readable, past timestamp counts as stale; future-dated
    /// mtimes (clock skew), unreadable metadata, and a vanished claim
    /// file all count as fresh so live jobs are never stolen on a
    /// hiccup.
    fn claim_is_stale(
        &self,
        entry: &std::fs::DirEntry,
        job_id: &str,
        legacy_max_age: Duration,
    ) -> bool {
        match lease::read(&self.dir, job_id) {
            // leased claim: absolute expiry, mtimes are irrelevant
            Some(l) => l.expired_at(lease::now_unix()),
            // legacy claim: the old mtime heuristic, from fresh
            // metadata (a re-claim's rename into running/ updates the
            // claim's identity; its mtime reflects the new claim file)
            None => entry
                .path()
                .metadata()
                .ok()
                .and_then(|m| m.modified().ok())
                .and_then(|t| t.elapsed().ok())
                .is_some_and(|age| age >= legacy_max_age),
        }
    }

    /// [`Spooler::recover_stale`] restricted to the lease protocol:
    /// requeues only expired leases, never legacy claims.
    pub fn reclaim_expired(&self) -> Result<usize> {
        self.recover_stale(Duration::MAX)
    }

    /// Number of jobs currently waiting in the queue.
    pub fn queued(&self) -> Result<usize> {
        Ok(std::fs::read_dir(self.dir.join("queue"))?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count())
    }

    /// Poll for a finished job's report.
    pub fn fetch(&self, job_id: &str) -> Result<Option<Report>> {
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        if !done.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&done)?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if !j.get("error").is_null() {
            bail!("job {job_id} failed: {}", j.get("error").as_str().unwrap_or("?"));
        }
        Ok(Some(io::report_from_json(&j)?))
    }

    /// Block until a job's report appears, polling with jittered
    /// exponential backoff ([`Backoff`]) — the submit → poll → fetch
    /// workflow of the paper's LoadLeveler/LSF setups.
    pub fn wait(&self, job_id: &str, timeout: Duration) -> Result<Report> {
        let deadline = Instant::now() + timeout;
        let mut backoff = Backoff::new(job_id);
        loop {
            if let Some(report) = self.fetch(job_id)? {
                return Ok(report);
            }
            if !backoff.sleep_until(deadline) {
                bail!("timed out after {timeout:?} waiting for job {job_id}");
            }
        }
    }

    /// Block until *every* job's report exists, with the same jittered
    /// backoff as [`Spooler::wait`]. Each poll is an O(#jobs) existence
    /// scan — no report body is parsed, so waiting on a huge campaign
    /// costs directory metadata only; outcomes are judged afterwards
    /// from the stamp sidecars. Errors on timeout with the jobs still
    /// missing.
    pub fn wait_many(&self, job_ids: &[String], timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let done = self.dir.join("done");
        let mut pending: Vec<&String> = job_ids.iter().collect();
        let mut backoff = Backoff::new(&job_ids.join(","));
        loop {
            pending.retain(|id| !done.join(format!("{id}.report.json")).exists());
            if pending.is_empty() {
                return Ok(());
            }
            if !backoff.sleep_until(deadline) {
                let shown: Vec<&str> =
                    pending.iter().take(5).map(|s| s.as_str()).collect();
                bail!(
                    "timed out after {timeout:?} with {} of {} job(s) unpublished \
                     (first: {})",
                    pending.len(),
                    job_ids.len(),
                    shown.join(", ")
                );
            }
        }
    }

    /// Drain the queue with `jobs` concurrent workers. Each worker gets
    /// its own lease identity and claims jobs until the queue is empty.
    /// Returns the number of jobs served. Under a `max_leases` cap a
    /// backpressured worker thread exits as if the queue were empty;
    /// the threads still holding slots finish the drain.
    pub fn drain(&self, jobs: usize) -> Result<usize> {
        let jobs = jobs.max(1);
        let served = AtomicUsize::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let spoolers: Vec<Spooler> = (0..jobs)
            .map(|i| self.clone().with_worker(format!("{}/d{i}", self.worker_id)))
            .collect();
        std::thread::scope(|s| {
            for sp in &spoolers {
                let served = &served;
                let first_err = &first_err;
                s.spawn(move || loop {
                    match sp.serve_one() {
                        Ok(Some(_)) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let mut guard = first_err.lock().unwrap();
                            if guard.is_none() {
                                *guard = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(served.load(Ordering::Relaxed))
    }

    /// The worker daemon loop behind `elaps worker`: `workers` threads,
    /// each cycling serve → heartbeat → publish with expiry reclaim
    /// between claims. Runs until the queue stays empty (`once`) or
    /// until `shutdown` is raised (the SIGTERM flag) — in-flight jobs
    /// are finished and published either way: the drain is graceful.
    /// `legacy_max_age` additionally reclaims pre-lease claims by
    /// mtime; `None` turns that heuristic off.
    /// Returns the number of jobs this pool published.
    pub fn run_worker_pool(
        &self,
        workers: usize,
        once: bool,
        legacy_max_age: Option<Duration>,
        shutdown: &AtomicBool,
    ) -> Result<usize> {
        let workers = workers.max(1);
        let served = AtomicUsize::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let spoolers: Vec<Spooler> = (0..workers)
            .map(|i| self.clone().with_worker(format!("{}/w{i}", self.worker_id)))
            .collect();
        let legacy = legacy_max_age.unwrap_or(Duration::MAX);
        std::thread::scope(|s| {
            for sp in &spoolers {
                let served = &served;
                let first_err = &first_err;
                s.spawn(move || {
                    let run = || -> Result<()> {
                        let mut backoff = Backoff::new(sp.worker_id());
                        loop {
                            if shutdown.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                            sp.recover_stale(legacy)?;
                            match sp.try_claim()? {
                                ClaimOutcome::Claimed(claim) => {
                                    if sp.serve_claim(&claim, true)?.published() {
                                        served.fetch_add(1, Ordering::Relaxed);
                                    }
                                    // progress: next stall starts gentle
                                    backoff = Backoff::new(sp.worker_id());
                                }
                                ClaimOutcome::Empty => {
                                    if once {
                                        return Ok(());
                                    }
                                    // idle poll, responsive to shutdown
                                    for _ in 0..10 {
                                        if shutdown.load(Ordering::Relaxed) {
                                            return Ok(());
                                        }
                                        std::thread::sleep(Duration::from_millis(20));
                                    }
                                }
                                ClaimOutcome::Backpressured => {
                                    // jobs remain but the host is at
                                    // its lease cap: wait for a slot
                                    // even under --once (our own
                                    // in-flight jobs will free one —
                                    // exiting here would strand the
                                    // queue). Jittered backoff, not a
                                    // fixed tick: capped pools on many
                                    // hosts must not rescan a shared
                                    // NFS spool in lockstep.
                                    if shutdown.load(Ordering::Relaxed) {
                                        return Ok(());
                                    }
                                    let stalled = Instant::now();
                                    backoff.sleep_until(
                                        Instant::now() + Duration::from_secs(1),
                                    );
                                    // host-scoped (no job): how long
                                    // this worker sat at the lease cap
                                    sp.events.emit(
                                        EventKind::Backpressured,
                                        "",
                                        0,
                                        &[(
                                            "stall_ns",
                                            (stalled.elapsed().as_nanos() as u64).into(),
                                        )],
                                    );
                                }
                            }
                        }
                    };
                    if let Err(e) = run() {
                        let mut guard = first_err.lock().unwrap();
                        if guard.is_none() {
                            *guard = Some(e);
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(served.load(Ordering::Relaxed))
    }

    /// Submit, serve in-process, and fetch — the blocking convenience
    /// used by tests and the CLI's `--batch` mode without a separate
    /// worker process.
    pub fn run_through_queue(&self, exp: &Experiment) -> Result<Report> {
        let id = self.submit(exp)?;
        self.serve_one()?;
        self.fetch(&id)?
            .ok_or_else(|| anyhow!("job {id} did not produce a report"))
    }
}

/// Jittered exponential backoff for spool polling: 10 ms doubling,
/// sleeps drawn uniformly from [base/2, base], capped at 1 s. The
/// jitter desynchronizes many clients polling one shared (NFS) spool,
/// so stampedes don't hammer the fileserver in lockstep; the RNG seed
/// is deterministic per (key, process) — reproducible traces, yet
/// different clients spread out.
pub struct Backoff {
    rng: crate::util::rng::Xoshiro256,
    base: Duration,
}

impl Backoff {
    pub fn new(key: &str) -> Backoff {
        let mut seed = 0xcbf2_9ce4_8422_2325u64;
        for b in key.bytes() {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x0000_0100_0000_01b3);
        }
        Backoff {
            rng: crate::util::rng::Xoshiro256::seeded(seed ^ std::process::id() as u64),
            base: Duration::from_millis(10),
        }
    }

    /// Sleep one jittered step, never past `deadline`. Returns `false`
    /// (without sleeping) once the deadline has passed.
    pub fn sleep_until(&mut self, deadline: Instant) -> bool {
        let now = Instant::now();
        if now >= deadline {
            return false;
        }
        let jittered = self.base.mul_f64(self.rng.range_f64(0.5, 1.0));
        std::thread::sleep(jittered.min(deadline - now));
        self.base = (self.base * 2).min(Duration::from_secs(1));
        true
    }
}

/// Job id of a spool file (`<id>.json` → `<id>`).
fn path_job_id(path: &Path) -> String {
    path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default()
}

/// A sibling temp path unique across processes *and* within this
/// process, for atomic write+rename publishes.
pub(crate) fn unique_tmp(path: &Path) -> PathBuf {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    path.with_extension(format!(
        "{}.{}.tmp",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::coordinator::report::Metric;
    use crate::coordinator::stats::Stat;

    #[test]
    fn local_run_end_to_end() {
        let mut exp = dgemm_experiment(60);
        exp.nreps = 3;
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.points[0].records.len(), 3);
        let gflops = report.series(Metric::Gflops, Stat::Max)[0].1;
        assert!(gflops > 0.01, "{gflops}");
    }

    #[test]
    fn local_run_with_range() {
        let mut exp = dgemm_experiment(0);
        exp.calls = dgemm_experiment(0).calls;
        // rebuild with a symbolic size
        let exp = {
            use crate::coordinator::experiment::{Call, CallArg, Experiment, RangeDef};
            Experiment {
                name: "range".into(),
                nreps: 2,
                range: Some(RangeDef::new("n", vec![20, 40])),
                calls: vec![Call::new(
                    "dgemm",
                    vec![
                        CallArg::Flag('N'),
                        CallArg::Flag('N'),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::Scalar(1.0),
                        CallArg::Data("A".into()),
                        CallArg::sym("n"),
                        CallArg::Data("B".into()),
                        CallArg::sym("n"),
                        CallArg::Scalar(0.0),
                        CallArg::Data("C".into()),
                        CallArg::sym("n"),
                    ],
                )
                .unwrap()],
                ..Default::default()
            }
        };
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[1].range_value, 40);
    }

    #[test]
    fn unknown_library_rejected() {
        let mut exp = dgemm_experiment(10);
        exp.library = "essl".into();
        assert!(run_local(&exp).is_err());
    }

    #[test]
    fn spooler_roundtrip() {
        let dir = std::env::temp_dir().join(format!("elaps_spool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let mut exp = dgemm_experiment(30);
        exp.nreps = 2;
        let report = spool.run_through_queue(&exp).unwrap();
        assert_eq!(report.points[0].records.len(), 2);
        // queue drained
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn claim_acquires_lease_and_publish_releases_it() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_lease_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap().with_host("hostA");
        let id = spool.submit(&dgemm_experiment(16)).unwrap();
        let claim = spool.claim_next().unwrap().unwrap();
        assert_eq!(claim.job_id, id);
        assert_eq!(claim.lease.epoch, 1, "first acquisition");
        assert_eq!(claim.lease.host, "hostA");
        let on_disk = lease::read(&dir, &id).unwrap();
        assert_eq!(on_disk, claim.lease);
        assert!(!on_disk.expired_at(lease::now_unix()), "fresh lease");
        // renewal extends the on-disk expiry
        assert!(spool.renew(&claim).unwrap());
        assert!(lease::read(&dir, &id).unwrap().expires_unix >= on_disk.expires_unix);
        // publish succeeds and releases claim + lease
        let outcome = spool.serve_claim(&claim, false).unwrap();
        assert_eq!(outcome, PublishOutcome::Published);
        assert!(lease::read(&dir, &id).is_none(), "lease released");
        assert!(!dir.join("running").join(format!("{id}.json")).exists());
        let report = spool.fetch(&id).unwrap().unwrap();
        assert_eq!(report.points.len(), 1);
        // the done payload carries the served_by provenance stamp
        let raw =
            std::fs::read_to_string(dir.join("done").join(format!("{id}.report.json")))
                .unwrap();
        assert!(raw.contains("served_by"), "{raw}");
        assert!(raw.contains("hostA"), "{raw}");
        // publishing also wrote the stamp sidecar (the O(#jobs) index)
        let stamp = campaign::read_stamp(&dir, &id).unwrap();
        assert_eq!(stamp.host, "hostA");
        assert_eq!(stamp.epoch, 1);
        assert_eq!(stamp.outcome, StampOutcome::Ok);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_many_blocks_until_every_report_exists() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_waitmany_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..3).map(|_| spool.submit(&dgemm_experiment(12)).unwrap()).collect();
        // nothing served yet: an expired deadline names the missing jobs
        let err = spool.wait_many(&ids, Duration::ZERO).unwrap_err();
        assert!(err.to_string().contains("3 of 3"), "{err}");
        std::thread::scope(|s| {
            s.spawn(|| {
                spool.drain(2).unwrap();
            });
            spool.wait_many(&ids, Duration::from_secs(60)).unwrap();
        });
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
        }
        // an empty id set is trivially satisfied
        spool.wait_many(&[], Duration::ZERO).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crashed_worker_job_is_recovered() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_recover_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(20)).unwrap();
        // simulate a pre-lease worker that claimed the job and then
        // crashed: a claim file with no lease (the legacy path)
        std::fs::rename(
            dir.join("queue").join(format!("{id}.json")),
            dir.join("running").join(format!("{id}.json")),
        )
        .unwrap();
        assert_eq!(spool.serve_one().unwrap(), None, "claimed job must be invisible");
        // a fresh legacy claim is not stale yet
        assert_eq!(spool.recover_stale(std::time::Duration::from_secs(3600)).unwrap(), 0);
        // the pure lease reclaim never touches legacy claims
        assert_eq!(spool.reclaim_expired().unwrap(), 0);
        // with zero mtime tolerance it is recovered and servable again
        assert_eq!(spool.recover_stale(std::time::Duration::ZERO).unwrap(), 1);
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
        assert!(spool.fetch(&id).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poison_job_becomes_error_report_not_worker_crash() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_poison_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        std::fs::write(dir.join("queue").join("poison.json"), "{not json").unwrap();
        // the worker must survive and publish the failure as a report
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some("poison"));
        let err = spool.fetch("poison").unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
        assert_eq!(spool.serve_one().unwrap(), None, "poison job must not requeue");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_serves_all_jobs_with_concurrent_workers() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_drain_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..4).map(|_| spool.submit(&dgemm_experiment(16)).unwrap()).collect();
        assert_eq!(ids.iter().collect::<std::collections::BTreeSet<_>>().len(), 4);
        assert_eq!(spool.drain(3).unwrap(), 4);
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
            assert!(lease::read(&dir, id).is_none(), "{id}: lease released");
        }
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_polls_with_backoff_until_served() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_wait_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(16)).unwrap();
        let report = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(30));
                spool.serve_one().unwrap();
            });
            spool.wait(&id, Duration::from_secs(30)).unwrap()
        });
        assert_eq!(report.points.len(), 1);
        // waiting on a job nobody serves times out
        let id2 = spool.submit(&dgemm_experiment(16)).unwrap();
        let err = spool.wait(&id2, Duration::from_millis(40)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_pool_once_drains_queue_and_respects_shutdown() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_pool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..3).map(|_| spool.submit(&dgemm_experiment(12)).unwrap()).collect();
        let shutdown = AtomicBool::new(false);
        let served = spool.run_worker_pool(2, true, None, &shutdown).unwrap();
        assert_eq!(served, 3);
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
        }
        // a pre-raised shutdown flag exits without claiming anything
        let id = spool.submit(&dgemm_experiment(12)).unwrap();
        shutdown.store(true, Ordering::Relaxed);
        assert_eq!(spool.run_worker_pool(2, false, None, &shutdown).unwrap(), 0);
        assert_eq!(spool.queued().unwrap(), 1);
        assert!(spool.fetch(&id).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
