//! Experiment execution (§3.2.1 "submit"): run locally, or through the
//! batch-job spooler that substitutes the paper's LoadLeveler/LSF
//! workflows (DESIGN.md §Substitutions 5).
//!
//! The spooler is multi-host capable: claims are explicit, heartbeat-
//! renewed leases with epoch fencing ([`crate::coordinator::lease`])
//! rather than mtime-staleness guesses, so workers on several machines
//! can drain one spool directory on a shared filesystem and a zombie
//! worker's late publish is rejected instead of corrupting the output.

use super::experiment::Experiment;
use super::io;
use super::lease::{self, FenceReason, Lease, PublishOutcome};
use super::report::Report;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Run an experiment on in-process samplers (the "local" backend).
///
/// One fresh sampler per parameter-range point, exactly as the paper
/// starts the sampler separately per thread count / range value.
/// Routes through the [`crate::engine`] with the process-default
/// configuration — serial and uncached unless the CLI's `--jobs` /
/// `--cache` flags or the `ELAPS_JOBS` / `ELAPS_CACHE` environment
/// variables say otherwise.
pub fn run_local(exp: &Experiment) -> Result<Report> {
    crate::engine::Engine::with_defaults().run(exp)
}

/// Default lease TTL when neither `with_ttl` nor `ELAPS_LEASE_TTL`
/// says otherwise: comfortably above typical job runtimes, so
/// heartbeat-less [`Spooler::serve_one`] stays safe.
const DEFAULT_LEASE_TTL: Duration = Duration::from_secs(300);

/// A job this worker has claimed: the queue entry renamed into
/// `<spool>/running/` plus the lease acquired for it. Produced by
/// [`Spooler::claim_next`]; consumed by [`Spooler::serve_claim`] /
/// [`Spooler::publish`].
#[derive(Debug, Clone)]
pub struct ClaimedJob {
    pub job_id: String,
    /// The lease as acquired. Renewals extend the on-disk expiry
    /// without updating this copy — fencing always re-reads the disk.
    pub lease: Lease,
    /// The claim file in `<spool>/running/`.
    running: PathBuf,
    /// The job file's contents (the experiment JSON).
    pub text: String,
}

/// The batch spooler: `submit` drops a job file into `<spool>/queue`;
/// a worker (`elaps worker`, or [`Spooler::serve_one`] in-process)
/// leases it, runs it, and publishes the report to `<spool>/done`.
/// `wait` polls for the report — the same submit → poll → fetch
/// workflow the paper uses with LoadLeveler and LSF, extended with the
/// lease protocol so many hosts can serve one spool.
#[derive(Debug, Clone)]
pub struct Spooler {
    pub dir: PathBuf,
    /// This handle's hostname (lease + provenance identity).
    host: String,
    /// This handle's worker identity (unique per handle).
    worker_id: String,
    /// Lease TTL: how long a claim stays valid without a renewal.
    ttl: Duration,
}

impl Spooler {
    /// Open (creating if needed) a spool directory. The handle's
    /// identity defaults to this process on this host; the lease TTL
    /// comes from `ELAPS_LEASE_TTL` (e.g. `90s`, `5m`) or defaults to
    /// 300 s.
    pub fn new(dir: impl AsRef<Path>) -> Result<Spooler> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(dir.join("queue"))?;
        std::fs::create_dir_all(dir.join("running"))?;
        std::fs::create_dir_all(dir.join("done"))?;
        std::fs::create_dir_all(dir.join("leases"))?;
        let ttl = std::env::var("ELAPS_LEASE_TTL")
            .ok()
            .and_then(|v| crate::util::cli::parse_duration(&v).ok())
            .filter(|d| !d.is_zero())
            .unwrap_or(DEFAULT_LEASE_TTL);
        Ok(Spooler {
            dir,
            host: crate::util::hostid::hostname().to_string(),
            worker_id: crate::util::hostid::new_worker_id(),
            ttl,
        })
    }

    /// Override the host identity recorded in leases and provenance
    /// (tests simulate multi-host fleets this way).
    pub fn with_host(mut self, host: impl Into<String>) -> Spooler {
        self.host = host.into();
        self
    }

    /// Override the worker identity.
    pub fn with_worker(mut self, worker_id: impl Into<String>) -> Spooler {
        self.worker_id = worker_id.into();
        self
    }

    /// Override the lease TTL. Zero is rejected (it would make every
    /// claim instantly reclaimable).
    pub fn with_ttl(mut self, ttl: Duration) -> Spooler {
        if !ttl.is_zero() {
            self.ttl = ttl;
        }
        self
    }

    pub fn host(&self) -> &str {
        &self.host
    }

    pub fn worker_id(&self) -> &str {
        &self.worker_id
    }

    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    /// Submit an experiment; returns the job id. The id embeds a
    /// process-local sequence number besides the timestamp, so rapid
    /// submissions from one process can never collide.
    pub fn submit(&self, exp: &Experiment) -> Result<String> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let job_id = format!(
            "{}-{:x}-{}",
            exp.name.replace(['/', ' '], "_"),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = self.dir.join("queue").join(format!("{job_id}.json"));
        let tmp = unique_tmp(&path);
        std::fs::write(&tmp, io::experiment_to_json(exp).to_string_pretty())?;
        std::fs::rename(&tmp, &path)?; // atomic enqueue
        Ok(job_id)
    }

    /// Atomically claim the oldest queued job: rename it into
    /// `<spool>/running/` and acquire its lease (epoch = previous
    /// epoch + 1, expiry = now + TTL). Losing the rename race to a
    /// concurrent worker is not an error — the claimer just moves on
    /// to the next queue entry.
    pub fn claim_next(&self) -> Result<Option<ClaimedJob>> {
        let queue = self.dir.join("queue");
        let mut entries: Vec<_> = std::fs::read_dir(&queue)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort_by_key(|e| e.file_name());
        for entry in entries {
            let job_id = path_job_id(&entry.path());
            let running = self.dir.join("running").join(format!("{job_id}.json"));
            match std::fs::rename(entry.path(), &running) {
                Ok(()) => {}
                // another worker claimed it between read_dir and rename
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            }
            let text = match std::fs::read_to_string(&running) {
                Ok(text) => text,
                // a concurrent recover_stale requeued it already
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            // Acquire the lease. The epoch chains across the job's
            // whole claim history (the previous lease file is left in
            // place by expiry reclaims precisely so this read sees it),
            // which is what fences a previous holder's late publish.
            let epoch = lease::read(&self.dir, &job_id).map(|l| l.epoch).unwrap_or(0) + 1;
            let l = Lease {
                job_id: job_id.clone(),
                worker_id: self.worker_id.clone(),
                host: self.host.clone(),
                epoch,
                expires_unix: lease::now_unix() + self.ttl.as_secs_f64(),
            };
            lease::write(&self.dir, &l)?;
            return Ok(Some(ClaimedJob { job_id, lease: l, running, text }));
        }
        Ok(None)
    }

    /// Heartbeat: extend the claim's on-disk lease by one TTL. Returns
    /// `false` (without touching anything) when the lease is no longer
    /// ours to renew — expired, superseded by a newer epoch, or gone —
    /// at which point the worker should abandon the job: its publish
    /// would be fenced anyway.
    pub fn renew(&self, claim: &ClaimedJob) -> Result<bool> {
        let Some(current) = lease::read(&self.dir, &claim.job_id) else {
            return Ok(false);
        };
        let now = lease::now_unix();
        if current.worker_id != claim.lease.worker_id
            || current.epoch != claim.lease.epoch
            || current.expired_at(now)
        {
            return Ok(false);
        }
        let renewed = Lease { expires_unix: now + self.ttl.as_secs_f64(), ..current };
        lease::write(&self.dir, &renewed)?;
        Ok(true)
    }

    /// Fenced, atomic publish of a claimed job's report payload.
    ///
    /// The fence: the on-disk lease must still name this claim's
    /// `(worker_id, epoch)` and be unexpired — otherwise the claim was
    /// (or is about to be) reclaimed, and writing would race the
    /// reclaim's re-execution. A fenced publish writes nothing and
    /// reports why ([`FenceReason`]). On success the report lands in
    /// `<spool>/done/` via temp + rename (readers only ever see a
    /// complete report), then the claim and lease are released.
    pub fn publish(&self, claim: &ClaimedJob, payload: &str) -> Result<PublishOutcome> {
        let fence = match lease::read(&self.dir, &claim.job_id) {
            Some(l)
                if l.worker_id == claim.lease.worker_id && l.epoch == claim.lease.epoch =>
            {
                if l.expired_at(lease::now_unix()) {
                    Some(FenceReason::Expired { expires_unix: l.expires_unix })
                } else {
                    None
                }
            }
            Some(l) => Some(FenceReason::Superseded {
                current_epoch: l.epoch,
                current_worker: l.worker_id,
            }),
            None => Some(FenceReason::LeaseGone),
        };
        if let Some(reason) = fence {
            return Ok(PublishOutcome::Fenced(reason));
        }
        let done = self.dir.join("done").join(format!("{}.report.json", claim.job_id));
        let tmp = unique_tmp(&done);
        std::fs::write(&tmp, payload)?;
        std::fs::rename(&tmp, &done)?;
        // Release only what is still ours: if the lease expired in the
        // tiny window since the fence check and a successor already
        // re-acquired the job, its claim and epoch-bumped lease must
        // not be torn down — the successor finishes and republishes
        // the same report (at-least-once, last writer wins).
        let still_ours = lease::read(&self.dir, &claim.job_id)
            .is_some_and(|l| {
                l.worker_id == claim.lease.worker_id && l.epoch == claim.lease.epoch
            });
        if still_ours {
            // claim file first, lease last (a crash in between leaves
            // a reclaimable claim whose re-execution republishes the
            // same report — consistent)
            match std::fs::remove_file(&claim.running) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
            lease::remove(&self.dir, &claim.job_id)?;
        }
        Ok(PublishOutcome::Published)
    }

    /// The `served_by` provenance stamp folded into every published
    /// report: which host/worker, under which lease epoch, produced it.
    fn served_by_json(&self, epoch: u64) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("host", self.host.as_str())
            .set("worker", self.worker_id.as_str())
            .set("epoch", epoch);
        j
    }

    /// Execute a claimed job and render its report payload (never
    /// errors: a malformed job file is the job's failure, not the
    /// worker's — it is published as an error report like any failed
    /// run, so poison jobs cannot crash-loop the worker).
    fn execute_payload(&self, claim: &ClaimedJob) -> String {
        let result = crate::util::json::Json::parse(&claim.text)
            .map_err(|e| anyhow!("invalid job file: {e}"))
            .and_then(|j| io::experiment_from_json(&j))
            .and_then(|exp| run_local(&exp));
        let mut j = match result {
            Ok(report) => io::report_to_json(&report),
            Err(e) => {
                let mut j = crate::util::json::Json::obj();
                j.set("error", format!("{e:#}"));
                j
            }
        };
        j.set("served_by", self.served_by_json(claim.lease.epoch));
        j.to_string_pretty()
    }

    /// Run a claimed job and publish its report. With `heartbeat`, a
    /// sidecar thread renews the lease every TTL/3 while the job
    /// executes, so jobs may outlive a single TTL; without it the job
    /// must finish within one TTL or its publish is fenced (useful in
    /// tests that drive the fence deliberately).
    pub fn serve_claim(&self, claim: &ClaimedJob, heartbeat: bool) -> Result<PublishOutcome> {
        let payload = if heartbeat {
            let stop = AtomicBool::new(false);
            let interval = (self.ttl / 3).max(Duration::from_millis(10));
            std::thread::scope(|s| {
                s.spawn(|| {
                    let mut last = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(10));
                        if last.elapsed() >= interval {
                            last = Instant::now();
                            match self.renew(claim) {
                                // lease cleanly lost (expired,
                                // superseded, gone): stop renewing and
                                // let the publish fence report it
                                Ok(false) => break,
                                Ok(true) => {}
                                // transient fs error (NFS hiccup):
                                // keep the heartbeat alive and retry
                                // on the next tick
                                Err(_) => {}
                            }
                        }
                    }
                });
                let payload = self.execute_payload(claim);
                stop.store(true, Ordering::Relaxed);
                payload
            })
        } else {
            self.execute_payload(claim)
        };
        self.publish(claim, &payload)
    }

    /// Worker side: take one queued job (if any), run it with the
    /// heartbeat keeping the lease alive (so jobs longer than one TTL
    /// are safe on every path), publish the report. Returns the
    /// processed job id; a fenced publish (this worker lost the job to
    /// a reclaim) is reported on stderr — the reclaiming worker owns
    /// the job now.
    pub fn serve_one(&self) -> Result<Option<String>> {
        let Some(claim) = self.claim_next()? else {
            return Ok(None);
        };
        let job_id = claim.job_id.clone();
        if let PublishOutcome::Fenced(reason) = self.serve_claim(&claim, true)? {
            eprintln!(
                "warning: publish of job {job_id} fenced ({reason:?}); a reclaimer owns it"
            );
        }
        Ok(Some(job_id))
    }

    /// Requeue jobs whose claims are dead: leased claims whose lease
    /// has **expired** (the lease protocol — `legacy_max_age` plays no
    /// part), and legacy claims (a file in `running/` with no lease,
    /// e.g. from a pre-lease worker) whose claim-file mtime is older
    /// than `legacy_max_age`. Lease files are deliberately left in
    /// place: they carry the fencing epoch the next claimer bumps.
    /// Returns the number of jobs requeued.
    ///
    /// Reclaim gives at-least-once semantics: between a lease's expiry
    /// and its holder noticing, the job can be re-executed; both
    /// executions publish complete reports atomically and the zombie's
    /// is fenced out, so readers still see exactly one report.
    pub fn recover_stale(&self, legacy_max_age: Duration) -> Result<usize> {
        let running = self.dir.join("running");
        let now = lease::now_unix();
        let mut recovered = 0;
        for entry in std::fs::read_dir(&running)?.filter_map(|e| e.ok()) {
            let path = entry.path();
            if !path.extension().is_some_and(|x| x == "json") {
                continue;
            }
            let job_id = path_job_id(&path);
            let stale = match lease::read(&self.dir, &job_id) {
                // leased claim: absolute expiry, mtimes are irrelevant
                Some(l) => l.expired_at(now),
                // legacy claim: fall back to the old mtime heuristic.
                // Only a readable, past timestamp older than
                // legacy_max_age is stale; future-dated mtimes (clock
                // skew) and unreadable metadata count as fresh so live
                // jobs are never stolen on a hiccup.
                None => entry
                    .metadata()
                    .ok()
                    .and_then(|m| m.modified().ok())
                    .and_then(|t| t.elapsed().ok())
                    .is_some_and(|age| age >= legacy_max_age),
            };
            if !stale {
                continue;
            }
            let dest = self.dir.join("queue").join(path.file_name().unwrap());
            match std::fs::rename(&path, &dest) {
                Ok(()) => recovered += 1,
                // the (not so dead) worker finished or a concurrent
                // reclaimer got there first
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(recovered)
    }

    /// [`Spooler::recover_stale`] restricted to the lease protocol:
    /// requeues only expired leases, never legacy claims.
    pub fn reclaim_expired(&self) -> Result<usize> {
        self.recover_stale(Duration::MAX)
    }

    /// Number of jobs currently waiting in the queue.
    pub fn queued(&self) -> Result<usize> {
        Ok(std::fs::read_dir(self.dir.join("queue"))?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count())
    }

    /// Poll for a finished job's report.
    pub fn fetch(&self, job_id: &str) -> Result<Option<Report>> {
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        if !done.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&done)?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if !j.get("error").is_null() {
            bail!("job {job_id} failed: {}", j.get("error").as_str().unwrap_or("?"));
        }
        Ok(Some(io::report_from_json(&j)?))
    }

    /// Block until a job's report appears, polling with jittered
    /// exponential backoff (10 ms doubling, sleeps drawn uniformly
    /// from [base/2, base], capped at 1 s) — the submit → poll → fetch
    /// workflow of the paper's LoadLeveler/LSF setups. The jitter
    /// desynchronizes many clients waiting on one shared (NFS) spool,
    /// so poll stampedes don't hammer the fileserver in lockstep.
    pub fn wait(&self, job_id: &str, timeout: Duration) -> Result<Report> {
        let deadline = Instant::now() + timeout;
        // deterministic per (job, process): reproducible traces, yet
        // different clients spread out
        let mut seed = 0xcbf2_9ce4_8422_2325u64;
        for b in job_id.bytes() {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut rng = crate::util::rng::Xoshiro256::seeded(seed ^ std::process::id() as u64);
        let mut base = Duration::from_millis(10);
        loop {
            if let Some(report) = self.fetch(job_id)? {
                return Ok(report);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("timed out after {timeout:?} waiting for job {job_id}");
            }
            let jittered = base.mul_f64(rng.range_f64(0.5, 1.0));
            std::thread::sleep(jittered.min(deadline - now));
            base = (base * 2).min(Duration::from_secs(1));
        }
    }

    /// Drain the queue with `jobs` concurrent workers. Each worker gets
    /// its own lease identity and claims jobs until the queue is empty.
    /// Returns the number of jobs served.
    pub fn drain(&self, jobs: usize) -> Result<usize> {
        let jobs = jobs.max(1);
        let served = AtomicUsize::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let spoolers: Vec<Spooler> = (0..jobs)
            .map(|i| self.clone().with_worker(format!("{}/d{i}", self.worker_id)))
            .collect();
        std::thread::scope(|s| {
            for sp in &spoolers {
                let served = &served;
                let first_err = &first_err;
                s.spawn(move || loop {
                    match sp.serve_one() {
                        Ok(Some(_)) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let mut guard = first_err.lock().unwrap();
                            if guard.is_none() {
                                *guard = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(served.load(Ordering::Relaxed))
    }

    /// The worker daemon loop behind `elaps worker`: `workers` threads,
    /// each cycling serve → heartbeat → publish with expiry reclaim
    /// between claims. Runs until the queue stays empty (`once`) or
    /// until `shutdown` is raised (the SIGTERM flag) — in-flight jobs
    /// are finished and published either way: the drain is graceful.
    /// `legacy_max_age` additionally reclaims pre-lease claims by
    /// mtime; `None` turns that heuristic off.
    /// Returns the number of jobs this pool published.
    pub fn run_worker_pool(
        &self,
        workers: usize,
        once: bool,
        legacy_max_age: Option<Duration>,
        shutdown: &AtomicBool,
    ) -> Result<usize> {
        let workers = workers.max(1);
        let served = AtomicUsize::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let spoolers: Vec<Spooler> = (0..workers)
            .map(|i| self.clone().with_worker(format!("{}/w{i}", self.worker_id)))
            .collect();
        let legacy = legacy_max_age.unwrap_or(Duration::MAX);
        std::thread::scope(|s| {
            for sp in &spoolers {
                let served = &served;
                let first_err = &first_err;
                s.spawn(move || {
                    let run = || -> Result<()> {
                        loop {
                            if shutdown.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                            sp.recover_stale(legacy)?;
                            match sp.claim_next()? {
                                Some(claim) => {
                                    if sp.serve_claim(&claim, true)?.published() {
                                        served.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                None => {
                                    if once {
                                        return Ok(());
                                    }
                                    // idle poll, responsive to shutdown
                                    for _ in 0..10 {
                                        if shutdown.load(Ordering::Relaxed) {
                                            return Ok(());
                                        }
                                        std::thread::sleep(Duration::from_millis(20));
                                    }
                                }
                            }
                        }
                    };
                    if let Err(e) = run() {
                        let mut guard = first_err.lock().unwrap();
                        if guard.is_none() {
                            *guard = Some(e);
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(served.load(Ordering::Relaxed))
    }

    /// Submit, serve in-process, and fetch — the blocking convenience
    /// used by tests and the CLI's `--batch` mode without a separate
    /// worker process.
    pub fn run_through_queue(&self, exp: &Experiment) -> Result<Report> {
        let id = self.submit(exp)?;
        self.serve_one()?;
        self.fetch(&id)?
            .ok_or_else(|| anyhow!("job {id} did not produce a report"))
    }
}

/// Job id of a spool file (`<id>.json` → `<id>`).
fn path_job_id(path: &Path) -> String {
    path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default()
}

/// A sibling temp path unique across processes *and* within this
/// process, for atomic write+rename publishes.
pub(crate) fn unique_tmp(path: &Path) -> PathBuf {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    path.with_extension(format!(
        "{}.{}.tmp",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::coordinator::report::Metric;
    use crate::coordinator::stats::Stat;

    #[test]
    fn local_run_end_to_end() {
        let mut exp = dgemm_experiment(60);
        exp.nreps = 3;
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.points[0].records.len(), 3);
        let gflops = report.series(Metric::Gflops, Stat::Max)[0].1;
        assert!(gflops > 0.01, "{gflops}");
    }

    #[test]
    fn local_run_with_range() {
        let mut exp = dgemm_experiment(0);
        exp.calls = dgemm_experiment(0).calls;
        // rebuild with a symbolic size
        let exp = {
            use crate::coordinator::experiment::{Call, CallArg, Experiment, RangeDef};
            Experiment {
                name: "range".into(),
                nreps: 2,
                range: Some(RangeDef::new("n", vec![20, 40])),
                calls: vec![Call::new(
                    "dgemm",
                    vec![
                        CallArg::Flag('N'),
                        CallArg::Flag('N'),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::Scalar(1.0),
                        CallArg::Data("A".into()),
                        CallArg::sym("n"),
                        CallArg::Data("B".into()),
                        CallArg::sym("n"),
                        CallArg::Scalar(0.0),
                        CallArg::Data("C".into()),
                        CallArg::sym("n"),
                    ],
                )
                .unwrap()],
                ..Default::default()
            }
        };
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[1].range_value, 40);
    }

    #[test]
    fn unknown_library_rejected() {
        let mut exp = dgemm_experiment(10);
        exp.library = "essl".into();
        assert!(run_local(&exp).is_err());
    }

    #[test]
    fn spooler_roundtrip() {
        let dir = std::env::temp_dir().join(format!("elaps_spool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let mut exp = dgemm_experiment(30);
        exp.nreps = 2;
        let report = spool.run_through_queue(&exp).unwrap();
        assert_eq!(report.points[0].records.len(), 2);
        // queue drained
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn claim_acquires_lease_and_publish_releases_it() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_lease_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap().with_host("hostA");
        let id = spool.submit(&dgemm_experiment(16)).unwrap();
        let claim = spool.claim_next().unwrap().unwrap();
        assert_eq!(claim.job_id, id);
        assert_eq!(claim.lease.epoch, 1, "first acquisition");
        assert_eq!(claim.lease.host, "hostA");
        let on_disk = lease::read(&dir, &id).unwrap();
        assert_eq!(on_disk, claim.lease);
        assert!(!on_disk.expired_at(lease::now_unix()), "fresh lease");
        // renewal extends the on-disk expiry
        assert!(spool.renew(&claim).unwrap());
        assert!(lease::read(&dir, &id).unwrap().expires_unix >= on_disk.expires_unix);
        // publish succeeds and releases claim + lease
        let outcome = spool.serve_claim(&claim, false).unwrap();
        assert_eq!(outcome, PublishOutcome::Published);
        assert!(lease::read(&dir, &id).is_none(), "lease released");
        assert!(!dir.join("running").join(format!("{id}.json")).exists());
        let report = spool.fetch(&id).unwrap().unwrap();
        assert_eq!(report.points.len(), 1);
        // the done payload carries the served_by provenance stamp
        let raw =
            std::fs::read_to_string(dir.join("done").join(format!("{id}.report.json")))
                .unwrap();
        assert!(raw.contains("served_by"), "{raw}");
        assert!(raw.contains("hostA"), "{raw}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crashed_worker_job_is_recovered() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_recover_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(20)).unwrap();
        // simulate a pre-lease worker that claimed the job and then
        // crashed: a claim file with no lease (the legacy path)
        std::fs::rename(
            dir.join("queue").join(format!("{id}.json")),
            dir.join("running").join(format!("{id}.json")),
        )
        .unwrap();
        assert_eq!(spool.serve_one().unwrap(), None, "claimed job must be invisible");
        // a fresh legacy claim is not stale yet
        assert_eq!(spool.recover_stale(std::time::Duration::from_secs(3600)).unwrap(), 0);
        // the pure lease reclaim never touches legacy claims
        assert_eq!(spool.reclaim_expired().unwrap(), 0);
        // with zero mtime tolerance it is recovered and servable again
        assert_eq!(spool.recover_stale(std::time::Duration::ZERO).unwrap(), 1);
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
        assert!(spool.fetch(&id).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poison_job_becomes_error_report_not_worker_crash() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_poison_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        std::fs::write(dir.join("queue").join("poison.json"), "{not json").unwrap();
        // the worker must survive and publish the failure as a report
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some("poison"));
        let err = spool.fetch("poison").unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
        assert_eq!(spool.serve_one().unwrap(), None, "poison job must not requeue");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_serves_all_jobs_with_concurrent_workers() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_drain_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..4).map(|_| spool.submit(&dgemm_experiment(16)).unwrap()).collect();
        assert_eq!(ids.iter().collect::<std::collections::BTreeSet<_>>().len(), 4);
        assert_eq!(spool.drain(3).unwrap(), 4);
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
            assert!(lease::read(&dir, id).is_none(), "{id}: lease released");
        }
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_polls_with_backoff_until_served() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_wait_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(16)).unwrap();
        let report = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(30));
                spool.serve_one().unwrap();
            });
            spool.wait(&id, Duration::from_secs(30)).unwrap()
        });
        assert_eq!(report.points.len(), 1);
        // waiting on a job nobody serves times out
        let id2 = spool.submit(&dgemm_experiment(16)).unwrap();
        let err = spool.wait(&id2, Duration::from_millis(40)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_pool_once_drains_queue_and_respects_shutdown() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_pool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..3).map(|_| spool.submit(&dgemm_experiment(12)).unwrap()).collect();
        let shutdown = AtomicBool::new(false);
        let served = spool.run_worker_pool(2, true, None, &shutdown).unwrap();
        assert_eq!(served, 3);
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
        }
        // a pre-raised shutdown flag exits without claiming anything
        let id = spool.submit(&dgemm_experiment(12)).unwrap();
        shutdown.store(true, Ordering::Relaxed);
        assert_eq!(spool.run_worker_pool(2, false, None, &shutdown).unwrap(), 0);
        assert_eq!(spool.queued().unwrap(), 1);
        assert!(spool.fetch(&id).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
