//! Experiment execution (§3.2.1 "submit"): run locally, or through the
//! batch-job spooler that substitutes the paper's LoadLeveler/LSF
//! workflows (DESIGN.md §Substitutions 5).

use super::experiment::Experiment;
use super::io;
use super::report::{PointResult, Report};
use crate::perfmodel::MachineModel;
use crate::sampler::Sampler;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Run an experiment on in-process samplers (the "local" backend).
///
/// One fresh sampler per parameter-range point, exactly as the paper
/// starts the sampler separately per thread count / range value.
pub fn run_local(exp: &Experiment) -> Result<Report> {
    let machine = MachineModel::by_name(&exp.machine)
        .ok_or_else(|| anyhow!("unknown machine '{}'", exp.machine))?;
    let points = exp.unroll()?;
    let mut results = Vec::with_capacity(points.len());
    for p in &points {
        let library = crate::libraries::by_name(&exp.library)
            .ok_or_else(|| anyhow!("unknown library '{}'", exp.library))?;
        let mut sampler = Sampler::new(library, machine.clone());
        let records = sampler
            .run_script(&p.script)
            .with_context(|| format!("point {} of '{}'", p.range_value, exp.name))?;
        let expected = p.expected_records(exp.nreps);
        if records.len() != expected {
            bail!(
                "point {}: sampler produced {} records, expected {expected}",
                p.range_value,
                records.len()
            );
        }
        results.push(PointResult {
            range_value: p.range_value,
            nthreads: p.nthreads,
            sum_iters: p.sum_iters,
            calls_per_iter: p.calls_per_iter,
            records,
        });
    }
    Report::assemble(exp.clone(), machine, results)
}

/// The batch spooler: `submit` drops a job file into `<spool>/queue`;
/// a worker (`elaps worker`, or [`serve_one`] in-process) picks it up,
/// runs it, and writes the report to `<spool>/done`. `wait` polls for
/// the report — the same submit → poll → fetch workflow the paper uses
/// with LoadLeveler and LSF.
pub struct Spooler {
    pub dir: PathBuf,
}

impl Spooler {
    pub fn new(dir: impl AsRef<Path>) -> Result<Spooler> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(dir.join("queue"))?;
        std::fs::create_dir_all(dir.join("running"))?;
        std::fs::create_dir_all(dir.join("done"))?;
        Ok(Spooler { dir })
    }

    /// Submit an experiment; returns the job id.
    pub fn submit(&self, exp: &Experiment) -> Result<String> {
        let job_id = format!(
            "{}-{:x}",
            exp.name.replace(['/', ' '], "_"),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        );
        let path = self.dir.join("queue").join(format!("{job_id}.json"));
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, io::experiment_to_json(exp).to_string_pretty())?;
        std::fs::rename(&tmp, &path)?; // atomic enqueue
        Ok(job_id)
    }

    /// Worker side: take one queued job (if any), run it, write the
    /// report. Returns the processed job id.
    pub fn serve_one(&self) -> Result<Option<String>> {
        let queue = self.dir.join("queue");
        let mut entries: Vec<_> = std::fs::read_dir(&queue)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort_by_key(|e| e.file_name());
        let Some(entry) = entries.into_iter().next() else {
            return Ok(None);
        };
        let job_id = entry
            .path()
            .file_stem()
            .unwrap()
            .to_string_lossy()
            .to_string();
        let running = self.dir.join("running").join(format!("{job_id}.json"));
        std::fs::rename(entry.path(), &running)?; // claim
        let text = std::fs::read_to_string(&running)?;
        let exp = io::experiment_from_json(
            &crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?,
        )?;
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        match run_local(&exp) {
            Ok(report) => {
                std::fs::write(&done, io::report_to_json(&report).to_string_pretty())?;
            }
            Err(e) => {
                let mut j = crate::util::json::Json::obj();
                j.set("error", format!("{e:#}"));
                std::fs::write(&done, j.to_string_pretty())?;
            }
        }
        std::fs::remove_file(&running)?;
        Ok(Some(job_id))
    }

    /// Poll for a finished job's report.
    pub fn fetch(&self, job_id: &str) -> Result<Option<Report>> {
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        if !done.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&done)?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if !j.get("error").is_null() {
            bail!("job {job_id} failed: {}", j.get("error").as_str().unwrap_or("?"));
        }
        Ok(Some(io::report_from_json(&j)?))
    }

    /// Submit, serve in-process, and fetch — the blocking convenience
    /// used by tests and the CLI's `--batch` mode without a separate
    /// worker process.
    pub fn run_through_queue(&self, exp: &Experiment) -> Result<Report> {
        let id = self.submit(exp)?;
        self.serve_one()?;
        self.fetch(&id)?
            .ok_or_else(|| anyhow!("job {id} did not produce a report"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::coordinator::report::Metric;
    use crate::coordinator::stats::Stat;

    #[test]
    fn local_run_end_to_end() {
        let mut exp = dgemm_experiment(60);
        exp.nreps = 3;
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.points[0].records.len(), 3);
        let gflops = report.series(Metric::Gflops, Stat::Max)[0].1;
        assert!(gflops > 0.01, "{gflops}");
    }

    #[test]
    fn local_run_with_range() {
        let mut exp = dgemm_experiment(0);
        exp.calls = dgemm_experiment(0).calls;
        // rebuild with a symbolic size
        let exp = {
            use crate::coordinator::experiment::{Call, CallArg, Experiment, RangeDef};
            Experiment {
                name: "range".into(),
                nreps: 2,
                range: Some(RangeDef::new("n", vec![20, 40])),
                calls: vec![Call::new(
                    "dgemm",
                    vec![
                        CallArg::Flag('N'),
                        CallArg::Flag('N'),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::Scalar(1.0),
                        CallArg::Data("A".into()),
                        CallArg::sym("n"),
                        CallArg::Data("B".into()),
                        CallArg::sym("n"),
                        CallArg::Scalar(0.0),
                        CallArg::Data("C".into()),
                        CallArg::sym("n"),
                    ],
                )
                .unwrap()],
                ..Default::default()
            }
        };
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[1].range_value, 40);
    }

    #[test]
    fn unknown_library_rejected() {
        let mut exp = dgemm_experiment(10);
        exp.library = "essl".into();
        assert!(run_local(&exp).is_err());
    }

    #[test]
    fn spooler_roundtrip() {
        let dir = std::env::temp_dir().join(format!("elaps_spool_{}", std::process::id()));
        let spool = Spooler::new(&dir).unwrap();
        let mut exp = dgemm_experiment(30);
        exp.nreps = 2;
        let report = spool.run_through_queue(&exp).unwrap();
        assert_eq!(report.points[0].records.len(), 2);
        // queue drained
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
