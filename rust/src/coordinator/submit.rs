//! Experiment execution (§3.2.1 "submit"): run locally, or through the
//! batch-job spooler that substitutes the paper's LoadLeveler/LSF
//! workflows (DESIGN.md §Substitutions 5).

use super::experiment::Experiment;
use super::io;
use super::report::Report;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Run an experiment on in-process samplers (the "local" backend).
///
/// One fresh sampler per parameter-range point, exactly as the paper
/// starts the sampler separately per thread count / range value.
/// Routes through the [`crate::engine`] with the process-default
/// configuration — serial and uncached unless the CLI's `--jobs` /
/// `--cache` flags or the `ELAPS_JOBS` / `ELAPS_CACHE` environment
/// variables say otherwise.
pub fn run_local(exp: &Experiment) -> Result<Report> {
    crate::engine::Engine::with_defaults().run(exp)
}

/// The batch spooler: `submit` drops a job file into `<spool>/queue`;
/// a worker (`elaps worker`, or [`serve_one`] in-process) picks it up,
/// runs it, and writes the report to `<spool>/done`. `wait` polls for
/// the report — the same submit → poll → fetch workflow the paper uses
/// with LoadLeveler and LSF.
pub struct Spooler {
    pub dir: PathBuf,
}

impl Spooler {
    pub fn new(dir: impl AsRef<Path>) -> Result<Spooler> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(dir.join("queue"))?;
        std::fs::create_dir_all(dir.join("running"))?;
        std::fs::create_dir_all(dir.join("done"))?;
        Ok(Spooler { dir })
    }

    /// Submit an experiment; returns the job id. The id embeds a
    /// process-local sequence number besides the timestamp, so rapid
    /// submissions from one process can never collide.
    pub fn submit(&self, exp: &Experiment) -> Result<String> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let job_id = format!(
            "{}-{:x}-{}",
            exp.name.replace(['/', ' '], "_"),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = self.dir.join("queue").join(format!("{job_id}.json"));
        let tmp = unique_tmp(&path);
        std::fs::write(&tmp, io::experiment_to_json(exp).to_string_pretty())?;
        std::fs::rename(&tmp, &path)?; // atomic enqueue
        Ok(job_id)
    }

    /// Atomically claim the oldest queued job by renaming it into
    /// `<spool>/running/`, and return its contents. Losing the rename
    /// race to a concurrent worker (or having the fresh claim stolen by
    /// a concurrent `recover_stale`) is not an error — the claimer just
    /// moves on to the next queue entry.
    fn claim_next(&self) -> Result<Option<(String, PathBuf, String)>> {
        let queue = self.dir.join("queue");
        let mut entries: Vec<_> = std::fs::read_dir(&queue)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort_by_key(|e| e.file_name());
        for entry in entries {
            let job_id = path_job_id(&entry.path());
            let running = self.dir.join("running").join(format!("{job_id}.json"));
            match std::fs::rename(entry.path(), &running) {
                Ok(()) => {}
                // another worker claimed it between read_dir and rename
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            }
            let text = match std::fs::read_to_string(&running) {
                Ok(text) => text,
                // a concurrent recover_stale requeued it already
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            // rename preserves the submit-time mtime; atomically
            // rewrite the claim so recover_stale measures staleness
            // from the claim, not from submission (best-effort — a
            // failed touch only makes the job recoverable earlier, and
            // the tmp+rename means it can never truncate the claim)
            let touch = unique_tmp(&running);
            if std::fs::write(&touch, &text).is_ok() {
                let _ = std::fs::rename(&touch, &running);
            }
            return Ok(Some((job_id, running, text)));
        }
        Ok(None)
    }

    /// Move jobs stranded in `<spool>/running/` by crashed workers back
    /// into the queue. A job is considered stale once its claim file
    /// has not been touched for `max_age`. Returns the number of jobs
    /// recovered.
    ///
    /// Recovery gives at-least-once semantics: a job whose runtime
    /// exceeds `max_age` may be recovered while still running and
    /// executed twice (both executions publish complete reports
    /// atomically; the last one wins). Pick `max_age` above the longest
    /// expected job; true exactly-once needs worker heartbeats (see
    /// ROADMAP "remote/multi-host workers").
    pub fn recover_stale(&self, max_age: Duration) -> Result<usize> {
        let running = self.dir.join("running");
        let mut recovered = 0;
        for entry in std::fs::read_dir(&running)?.filter_map(|e| e.ok()) {
            let path = entry.path();
            if !path.extension().is_some_and(|x| x == "json") {
                continue;
            }
            let age = entry
                .metadata()
                .ok()
                .and_then(|m| m.modified().ok())
                .and_then(|t| t.elapsed().ok());
            // only a readable, past timestamp older than max_age is
            // stale; future-dated mtimes (clock skew) and unreadable
            // metadata count as fresh so live jobs are never stolen
            // on a hiccup
            if !age.is_some_and(|a| a >= max_age) {
                continue;
            }
            let dest = self.dir.join("queue").join(path.file_name().unwrap());
            match std::fs::rename(&path, &dest) {
                Ok(()) => recovered += 1,
                // the (not so crashed) worker finished or re-claimed it
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(recovered)
    }

    /// Worker side: take one queued job (if any), run it, write the
    /// report. Returns the processed job id.
    pub fn serve_one(&self) -> Result<Option<String>> {
        let Some((job_id, running, text)) = self.claim_next()? else {
            return Ok(None);
        };
        // A malformed job file is the job's failure, not the worker's:
        // publish it as an error report like any failed run, so poison
        // jobs cannot crash-loop the worker through recover_stale.
        let result = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("invalid job file: {e}"))
            .and_then(|j| io::experiment_from_json(&j))
            .and_then(|exp| run_local(&exp));
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        let payload = match result {
            Ok(report) => io::report_to_json(&report).to_string_pretty(),
            Err(e) => {
                let mut j = crate::util::json::Json::obj();
                j.set("error", format!("{e:#}"));
                j.to_string_pretty()
            }
        };
        // atomic publish: if a duplicate worker (after recover_stale)
        // races us, readers still only ever see one complete report
        let tmp = unique_tmp(&done);
        std::fs::write(&tmp, payload)?;
        std::fs::rename(&tmp, &done)?;
        // the claim may already be gone if recover_stale requeued this
        // job and another worker finished it — our report is still valid
        match std::fs::remove_file(&running) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e.into()),
        }
        Ok(Some(job_id))
    }

    /// Number of jobs currently waiting in the queue.
    pub fn queued(&self) -> Result<usize> {
        Ok(std::fs::read_dir(self.dir.join("queue"))?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count())
    }

    /// Poll for a finished job's report.
    pub fn fetch(&self, job_id: &str) -> Result<Option<Report>> {
        let done = self.dir.join("done").join(format!("{job_id}.report.json"));
        if !done.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&done)?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if !j.get("error").is_null() {
            bail!("job {job_id} failed: {}", j.get("error").as_str().unwrap_or("?"));
        }
        Ok(Some(io::report_from_json(&j)?))
    }

    /// Block until a job's report appears, polling with exponential
    /// backoff (10 ms doubling up to 1 s — the submit → poll → fetch
    /// workflow of the paper's LoadLeveler/LSF setups, without busy-
    /// spinning on the filesystem).
    pub fn wait(&self, job_id: &str, timeout: Duration) -> Result<Report> {
        let deadline = Instant::now() + timeout;
        let mut delay = Duration::from_millis(10);
        loop {
            if let Some(report) = self.fetch(job_id)? {
                return Ok(report);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("timed out after {timeout:?} waiting for job {job_id}");
            }
            std::thread::sleep(delay.min(deadline - now));
            delay = (delay * 2).min(Duration::from_secs(1));
        }
    }

    /// Drain the queue with `jobs` concurrent workers (the multi-worker
    /// spooler loop behind `elaps worker --jobs N`). Each worker claims
    /// jobs via the atomic rename until the queue is empty. Returns the
    /// number of jobs served.
    pub fn drain(&self, jobs: usize) -> Result<usize> {
        let jobs = jobs.max(1);
        let served = AtomicUsize::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|s| {
            for _ in 0..jobs {
                s.spawn(|| loop {
                    match self.serve_one() {
                        Ok(Some(_)) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let mut guard = first_err.lock().unwrap();
                            if guard.is_none() {
                                *guard = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(served.load(Ordering::Relaxed))
    }

    /// Submit, serve in-process, and fetch — the blocking convenience
    /// used by tests and the CLI's `--batch` mode without a separate
    /// worker process.
    pub fn run_through_queue(&self, exp: &Experiment) -> Result<Report> {
        let id = self.submit(exp)?;
        self.serve_one()?;
        self.fetch(&id)?
            .ok_or_else(|| anyhow!("job {id} did not produce a report"))
    }
}

/// Job id of a spool file (`<id>.json` → `<id>`).
fn path_job_id(path: &Path) -> String {
    path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default()
}

/// A sibling temp path unique across processes *and* within this
/// process, for atomic write+rename publishes.
fn unique_tmp(path: &Path) -> PathBuf {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    path.with_extension(format!(
        "{}.{}.tmp",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::coordinator::report::Metric;
    use crate::coordinator::stats::Stat;

    #[test]
    fn local_run_end_to_end() {
        let mut exp = dgemm_experiment(60);
        exp.nreps = 3;
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.points[0].records.len(), 3);
        let gflops = report.series(Metric::Gflops, Stat::Max)[0].1;
        assert!(gflops > 0.01, "{gflops}");
    }

    #[test]
    fn local_run_with_range() {
        let mut exp = dgemm_experiment(0);
        exp.calls = dgemm_experiment(0).calls;
        // rebuild with a symbolic size
        let exp = {
            use crate::coordinator::experiment::{Call, CallArg, Experiment, RangeDef};
            Experiment {
                name: "range".into(),
                nreps: 2,
                range: Some(RangeDef::new("n", vec![20, 40])),
                calls: vec![Call::new(
                    "dgemm",
                    vec![
                        CallArg::Flag('N'),
                        CallArg::Flag('N'),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::sym("n"),
                        CallArg::Scalar(1.0),
                        CallArg::Data("A".into()),
                        CallArg::sym("n"),
                        CallArg::Data("B".into()),
                        CallArg::sym("n"),
                        CallArg::Scalar(0.0),
                        CallArg::Data("C".into()),
                        CallArg::sym("n"),
                    ],
                )
                .unwrap()],
                ..Default::default()
            }
        };
        let report = run_local(&exp).unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[1].range_value, 40);
    }

    #[test]
    fn unknown_library_rejected() {
        let mut exp = dgemm_experiment(10);
        exp.library = "essl".into();
        assert!(run_local(&exp).is_err());
    }

    #[test]
    fn spooler_roundtrip() {
        let dir = std::env::temp_dir().join(format!("elaps_spool_{}", std::process::id()));
        let spool = Spooler::new(&dir).unwrap();
        let mut exp = dgemm_experiment(30);
        exp.nreps = 2;
        let report = spool.run_through_queue(&exp).unwrap();
        assert_eq!(report.points[0].records.len(), 2);
        // queue drained
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crashed_worker_job_is_recovered() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_recover_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(20)).unwrap();
        // simulate a worker that claimed the job and then crashed
        std::fs::rename(
            dir.join("queue").join(format!("{id}.json")),
            dir.join("running").join(format!("{id}.json")),
        )
        .unwrap();
        assert_eq!(spool.serve_one().unwrap(), None, "claimed job must be invisible");
        // a fresh claim is not stale yet
        assert_eq!(spool.recover_stale(std::time::Duration::from_secs(3600)).unwrap(), 0);
        // with zero tolerance it is recovered and servable again
        assert_eq!(spool.recover_stale(std::time::Duration::ZERO).unwrap(), 1);
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some(id.as_str()));
        assert!(spool.fetch(&id).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poison_job_becomes_error_report_not_worker_crash() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_poison_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        std::fs::write(dir.join("queue").join("poison.json"), "{not json").unwrap();
        // the worker must survive and publish the failure as a report
        assert_eq!(spool.serve_one().unwrap().as_deref(), Some("poison"));
        let err = spool.fetch("poison").unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
        assert_eq!(spool.serve_one().unwrap(), None, "poison job must not requeue");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_serves_all_jobs_with_concurrent_workers() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_drain_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let ids: Vec<String> =
            (0..4).map(|_| spool.submit(&dgemm_experiment(16)).unwrap()).collect();
        assert_eq!(ids.iter().collect::<std::collections::BTreeSet<_>>().len(), 4);
        assert_eq!(spool.drain(3).unwrap(), 4);
        for id in &ids {
            assert!(spool.fetch(id).unwrap().is_some(), "{id}");
        }
        assert_eq!(spool.serve_one().unwrap(), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_polls_with_backoff_until_served() {
        let dir =
            std::env::temp_dir().join(format!("elaps_spool_wait_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = Spooler::new(&dir).unwrap();
        let id = spool.submit(&dgemm_experiment(16)).unwrap();
        let report = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(30));
                spool.serve_one().unwrap();
            });
            spool.wait(&id, Duration::from_secs(30)).unwrap()
        });
        assert_eq!(report.points.len(), 1);
        // waiting on a job nobody serves times out
        let id2 = spool.submit(&dgemm_experiment(16)).unwrap();
        let err = spool.wait(&id2, Duration::from_millis(40)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
