//! The coordinator — the paper's middle layer (the `elaps` Python
//! package, §3.2), in Rust: the [`Experiment`] abstraction with
//! repetitions, operand varying and parameter-/sum-/OpenMP-ranges, its
//! execution on [`crate::sampler::Sampler`]s (locally or through the
//! batch spooler), and [`Report`]s with metrics, statistics and plots.

pub mod symbolic;
pub mod experiment;
pub mod stats;
pub mod report;
pub mod plot;
pub mod io;
pub mod lease;
pub mod campaign;
pub mod ledger;
pub mod submit;

pub use campaign::{CampaignManifest, CampaignStatus, ManifestEntry, Stamp, StampOutcome};
pub use experiment::{Call, CallArg, DataGen, Experiment, RangeDef, Vary};
pub use lease::{FenceReason, Lease, PublishOutcome, SpoolStatus};
pub use ledger::{CampaignIndex, JobEntry, RetryOutcome};
pub use plot::Figure;
pub use report::{Metric, PointResult, Report};
pub use stats::Stat;
pub use submit::{run_local, Backoff, ClaimOutcome, ClaimedJob, Spooler};
pub use symbolic::Expr;
