//! The campaign ledger: an embedded canonical store for campaign
//! bookkeeping, replacing the file-per-fact pattern (one JSON record
//! merged under a flock per submission) with an append-only, fsync'd,
//! CRC-framed event log per campaign plus a compacting index snapshot.
//!
//! * **Ledger** — `<spool>/ledger/<tag>.log`. One record per line:
//!   `"{crc32:08x} {len} {payload}\n"`, where the payload is a compact
//!   JSON [`Event`] (the existing obs taxonomy, extended with the
//!   `submitted`/`retried`/`dead_lettered` client facts). Appends are a
//!   single `O_APPEND` write followed by an fsync, so concurrent
//!   submitters serialize through the kernel's append offset instead of
//!   a flock'd read-merge-write, and a torn tail (crash mid-append) is
//!   detected by the frame: a line without its newline is an in-flight
//!   write, a framed line whose CRC or length disagrees is skipped and
//!   counted, never an error.
//! * **Index snapshot** — `<spool>/ledger/<tag>.index.json`, replaced
//!   atomically. It folds the ledger (by byte cursor, so a refresh
//!   ingests only what was appended since) together with completion
//!   probes of the still-pending jobs. `elaps submit`/`wait`/`spool
//!   status` become O(changed-since-snapshot): a million-job campaign
//!   with ten unfinished jobs costs ten existence probes per poll, not
//!   a million-entry directory scan.
//! * **Operational verbs** — [`retry_errors`] resubmits error-stamped
//!   jobs exactly once (recorded as `retried` ledger facts, guarded by
//!   the campaign tag lock across processes) and dead-letters jobs
//!   whose retry chain exhausted its attempt budget; [`compact`]
//!   persists the folded snapshot and optionally archives a fully
//!   ingested ledger.
//!
//! The directory scan remains available as the `--no-ledger` fallback,
//! and the two paths are held to a differential bar: a ledger-backed
//! and a file-backed campaign must yield byte-identical reports and
//! identical `spool status --json` (rust/tests/ledger_roundtrip.rs).

use super::campaign::{self, CampaignStatus, StampOutcome};
use super::experiment::Experiment;
use super::io;
use super::lease;
use super::submit::{unique_tmp, Spooler};
use crate::obs::events::{Event, EventKind};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Default retry budget: an original attempt plus two retries.
pub const DEFAULT_MAX_ATTEMPTS: u64 = 3;

pub fn ledger_dir(spool: &Path) -> PathBuf {
    spool.join("ledger")
}

pub fn ledger_path(spool: &Path, tag: &str) -> PathBuf {
    ledger_dir(spool).join(format!("{tag}.log"))
}

pub fn index_path(spool: &Path, tag: &str) -> PathBuf {
    ledger_dir(spool).join(format!("{tag}.index.json"))
}

/// Sidecar holding the campaign's archive generation (a decimal
/// counter bumped each time compaction moves the log away). Refresh
/// reads it in O(1) to learn that its byte cursor refers to a log that
/// no longer exists — a length check alone cannot tell once a
/// recreated post-archive log outgrows the old cursor.
fn generation_path(spool: &Path, tag: &str) -> PathBuf {
    ledger_dir(spool).join(format!("{tag}.gen"))
}

fn read_generation(spool: &Path, tag: &str) -> u64 {
    std::fs::read_to_string(generation_path(spool, tag))
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Whether a campaign has a ledger (the discriminator `wait`/`fetch`/
/// `analyze` use to pick the ledger path over the record file). An
/// archived campaign still counts: compaction moves the log away but
/// leaves the index snapshot, which answers every query the log would.
pub fn has_ledger(spool: &Path, tag: &str) -> bool {
    campaign::validate_tag(tag).is_ok()
        && (ledger_path(spool, tag).is_file() || index_path(spool, tag).is_file())
}

// ---------------------------------------------------------------- CRC

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven. Hand
/// rolled: the vendored crate set has no checksum crate, and 8 lines of
/// const fn beat a dependency.
const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { 0xEDB8_8320 ^ (crc >> 1) } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

// ------------------------------------------------------------ framing

/// Frame one record payload as a ledger line. The CRC and explicit
/// length let a reader reject a corrupted or spliced line without
/// trusting the payload's own syntax.
pub fn frame_record(payload: &str) -> String {
    format!("{:08x} {} {payload}\n", crc32(payload.as_bytes()), payload.len())
}

/// Parse one complete (newline-stripped) ledger line back into its
/// payload. `None` for any framing violation: missing fields, a length
/// mismatch (a spliced or truncated write), or a CRC mismatch (bit
/// rot). The payload is returned verbatim for the caller to parse.
pub fn parse_frame(line: &str) -> Option<&str> {
    let (crc_hex, rest) = line.split_once(' ')?;
    let (len, payload) = rest.split_once(' ')?;
    if payload.len() != len.parse::<usize>().ok()? {
        return None;
    }
    if crc32(payload.as_bytes()) != u32::from_str_radix(crc_hex, 16).ok()? {
        return None;
    }
    Some(payload)
}

/// The result of scanning (a suffix of) a ledger: every recoverable
/// fact in append order, the count of complete-but-unreadable lines,
/// and the byte offset up to which the text was consumed — the cursor
/// an incremental reader stores and resumes from.
#[derive(Debug, Clone, Default)]
pub struct LedgerScan {
    pub events: Vec<Event>,
    pub skipped: usize,
    /// Bytes consumed: the offset just past the last complete line. A
    /// trailing line without its newline (an in-flight append) is left
    /// for the next scan.
    pub bytes: u64,
}

/// Parse ledger text. Everything after the last newline is an
/// in-flight append and is ignored (and excluded from
/// [`LedgerScan::bytes`]); complete lines failing the frame or the
/// event schema are counted in `skipped`.
pub fn parse_ledger_text(text: &str) -> LedgerScan {
    let mut scan = LedgerScan::default();
    let complete = match text.rfind('\n') {
        Some(i) => &text[..i + 1],
        None => "",
    };
    scan.bytes = complete.len() as u64;
    for line in complete.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = parse_frame(line)
            .and_then(|payload| Json::parse(payload).ok())
            .and_then(|j| Event::from_json(&j));
        match parsed {
            Some(ev) => scan.events.push(ev),
            None => scan.skipped += 1,
        }
    }
    scan
}

/// Read a ledger from a byte cursor (0 = the whole file). A missing
/// file (archived, nothing appended since) scans as empty with the
/// cursor unchanged; a file *shorter* than the cursor was archived and
/// then appended to, and is scanned from its start. The returned
/// [`LedgerScan::bytes`] is the new absolute cursor.
pub fn read_ledger_from(path: &Path, offset: u64) -> Result<LedgerScan> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(LedgerScan { bytes: offset, ..Default::default() });
        }
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    if (bytes.len() as u64) < offset {
        // A ledger only shrinks when compaction archived it away: a
        // file shorter than the cursor is a fresh post-archive log
        // whose facts are all new (the archived prefix is already
        // folded into the snapshot) — read it from the start.
        return Ok(parse_ledger_text(&String::from_utf8_lossy(&bytes)));
    }
    let tail = String::from_utf8_lossy(&bytes[offset as usize..]);
    let mut scan = parse_ledger_text(&tail);
    scan.bytes += offset;
    Ok(scan)
}

/// Append facts to a campaign ledger: one framed line per event,
/// written with a single `O_APPEND` write and fsync'd before
/// returning. Atomic appends are what let concurrent submitters
/// serialize without a lock — the kernel orders the writes, and the
/// frame detects the (local-fs-rare, NFS-possible) interleaved tail.
pub fn append(spool: &Path, tag: &str, events: &[Event]) -> Result<()> {
    campaign::validate_tag(tag)?;
    std::fs::create_dir_all(ledger_dir(spool))?;
    let path = ledger_path(spool, tag);
    let mut buf = String::new();
    for ev in events {
        buf.push_str(&frame_record(&ev.to_json().to_string_compact()));
    }
    let mut file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .with_context(|| format!("opening ledger {}", path.display()))?;
    file.write_all(buf.as_bytes())?;
    file.sync_all()?;
    Ok(())
}

// -------------------------------------------------------- fact makers

fn now_ns() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0)
}

fn next_seq() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// A client-side ledger fact, stamped with this spooler's identity.
fn fact(spool: &Spooler, tag: &str, kind: EventKind, job_id: &str) -> Event {
    Event {
        kind,
        job_id: job_id.to_string(),
        campaign: tag.to_string(),
        host: spool.host().to_string(),
        worker: spool.worker_id().to_string(),
        epoch: 0,
        t_unix_ns: now_ns(),
        seq: next_seq(),
        extra: BTreeMap::new(),
    }
}

// -------------------------------------------------------------- index

/// One job's folded state in the campaign index.
#[derive(Debug, Clone, PartialEq)]
pub struct JobEntry {
    pub job_id: String,
    /// Position in the retry chain: 1 for an original submission.
    pub attempt: u64,
    /// The submitted experiment (from the `submitted` fact), kept so
    /// `elaps retry` can resubmit without the original file. Dropped
    /// once the job finishes ok — only failures need it again.
    pub experiment: Option<Json>,
    /// Whether a published report exists. Terminal: reports persist.
    pub done: bool,
    /// Outcome from the publish stamp; `None` while pending, or done
    /// with a missing/unreadable stamp (outcome unknown).
    pub outcome: Option<StampOutcome>,
    pub host: String,
    pub worker: String,
    pub epoch: u64,
    /// The failed job this one was resubmitted for.
    pub retry_of: Option<String>,
    /// The resubmission that replaced this failed job — the
    /// exactly-once guard: a job with `retried_to` is never resubmitted
    /// again.
    pub retried_to: Option<String>,
    /// Dead-lettered: the retry chain exhausted its attempt budget.
    pub dead: bool,
}

impl JobEntry {
    fn new(job_id: &str) -> JobEntry {
        JobEntry {
            job_id: job_id.to_string(),
            attempt: 1,
            experiment: None,
            done: false,
            outcome: None,
            host: String::new(),
            worker: String::new(),
            epoch: 0,
            retry_of: None,
            retried_to: None,
            dead: false,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt_str = |s: &Option<String>| match s {
            Some(v) => Json::Str(v.clone()),
            None => Json::Null,
        };
        let mut j = Json::obj();
        j.set("job_id", self.job_id.as_str())
            .set("attempt", self.attempt)
            .set(
                "experiment",
                self.experiment.clone().unwrap_or(Json::Null),
            )
            .set("done", self.done)
            .set(
                "outcome",
                match self.outcome {
                    Some(o) => Json::Str(o.as_str().to_string()),
                    None => Json::Null,
                },
            )
            .set("host", self.host.as_str())
            .set("worker", self.worker.as_str())
            .set("epoch", self.epoch)
            .set("retry_of", opt_str(&self.retry_of))
            .set("retried_to", opt_str(&self.retried_to))
            .set("dead", self.dead);
        j
    }

    fn from_json(j: &Json) -> Option<JobEntry> {
        let opt_str = |v: &Json| v.as_str().map(String::from);
        Some(JobEntry {
            job_id: j.get("job_id").as_str()?.to_string(),
            attempt: j.get("attempt").as_u64()?,
            experiment: match j.get("experiment") {
                Json::Null => None,
                other => Some(other.clone()),
            },
            done: j.get("done").as_bool()?,
            outcome: j.get("outcome").as_str().and_then(StampOutcome::by_name),
            host: j.get("host").as_str()?.to_string(),
            worker: j.get("worker").as_str()?.to_string(),
            epoch: j.get("epoch").as_u64()?,
            retry_of: opt_str(j.get("retry_of")),
            retried_to: opt_str(j.get("retried_to")),
            dead: j.get("dead").as_bool()?,
        })
    }
}

/// The compacting index snapshot over one campaign's ledger: folded
/// job states in submission order plus the ledger byte cursor. Loaded
/// from `<tag>.index.json`, refreshed by ingesting only the ledger
/// bytes appended since and probing only the still-pending jobs, and
/// saved back via atomic replace — a reader mid-compaction sees the
/// old snapshot or the new one, each self-consistent with its cursor.
#[derive(Debug, Clone, Default)]
pub struct CampaignIndex {
    pub campaign: String,
    pub jobs: BTreeMap<String, JobEntry>,
    /// Job ids in first-appearance (submission) order.
    pub order: Vec<String>,
    /// Absolute byte cursor into the ledger: everything before it has
    /// been folded into `jobs`.
    pub ledger_bytes: u64,
    /// Archive generation the cursor belongs to (see
    /// [`generation_path`]); 0 until the first archive.
    pub generation: u64,
    /// Complete-but-unreadable ledger lines skipped so far.
    pub skipped: usize,
}

impl CampaignIndex {
    /// Load the snapshot, or start empty (first read, or a snapshot
    /// from a newer writer we cannot parse — the ledger replays).
    pub fn load(spool: &Path, tag: &str) -> Result<CampaignIndex> {
        campaign::validate_tag(tag)?;
        let fresh = CampaignIndex { campaign: tag.to_string(), ..Default::default() };
        let text = match std::fs::read_to_string(index_path(spool, tag)) {
            Ok(t) => t,
            Err(_) => return Ok(fresh),
        };
        let Ok(j) = Json::parse(&text) else {
            return Ok(fresh);
        };
        let mut idx = fresh;
        idx.ledger_bytes = j.get("ledger_bytes").as_u64().unwrap_or(0);
        idx.generation = j.get("generation").as_u64().unwrap_or(0);
        idx.skipped = j.get("skipped").as_u64().unwrap_or(0) as usize;
        for ej in j.get("jobs").as_arr().unwrap_or(&[]) {
            if let Some(e) = JobEntry::from_json(ej) {
                idx.order.push(e.job_id.clone());
                idx.jobs.insert(e.job_id.clone(), e);
            }
        }
        Ok(idx)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("v", 1u64)
            .set("campaign", self.campaign.as_str())
            .set("ledger_bytes", self.ledger_bytes)
            .set("generation", self.generation)
            .set("skipped", self.skipped as u64)
            .set(
                "jobs",
                Json::Arr(
                    self.order
                        .iter()
                        .filter_map(|id| self.jobs.get(id))
                        .map(JobEntry::to_json)
                        .collect(),
                ),
            );
        j
    }

    /// Persist the snapshot (atomic replace).
    pub fn save(&self, spool: &Path) -> Result<()> {
        std::fs::create_dir_all(ledger_dir(spool))?;
        let path = index_path(spool, &self.campaign);
        let tmp = unique_tmp(&path);
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn entry_mut(&mut self, job_id: &str) -> &mut JobEntry {
        match self.jobs.entry(job_id.to_string()) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => {
                self.order.push(job_id.to_string());
                v.insert(JobEntry::new(job_id))
            }
        }
    }

    /// Fold one ledger fact. Facts may arrive in either intra-append
    /// order (`retried` before or after the new job's `submitted`);
    /// unknown kinds are tolerated per the event compatibility rule.
    fn apply(&mut self, ev: &Event) {
        match ev.kind {
            EventKind::Submitted => {
                let attempt = ev.extra.get("attempt").and_then(|v| v.as_u64());
                let exp = ev.extra.get("experiment").cloned();
                let e = self.entry_mut(&ev.job_id);
                if let Some(a) = attempt {
                    e.attempt = a;
                }
                if exp.is_some() {
                    e.experiment = exp;
                }
            }
            EventKind::Retried => {
                let of = ev.extra.get("of").and_then(|v| v.as_str()).map(String::from);
                let attempt = ev.extra.get("attempt").and_then(|v| v.as_u64());
                {
                    let e = self.entry_mut(&ev.job_id);
                    e.retry_of = of.clone();
                    if let Some(a) = attempt {
                        e.attempt = a;
                    }
                }
                if let Some(of) = of {
                    self.entry_mut(&of).retried_to = Some(ev.job_id.clone());
                }
            }
            EventKind::DeadLettered => {
                self.entry_mut(&ev.job_id).dead = true;
            }
            _ => {}
        }
    }

    /// Bring the index up to date: ingest the ledger from the byte
    /// cursor, then probe completion for the still-pending jobs only —
    /// O(appended bytes + pending jobs), independent of campaign size.
    pub fn refresh(&mut self, spool: &Path) -> Result<()> {
        // Archive coherence: if compaction moved the log away since
        // this snapshot's cursor was taken, the cursor refers to a
        // dead file — and a recreated log may have grown past it,
        // which the shrink check in `read_ledger_from` cannot see.
        // The `.gen` sidecar makes detection O(1); the snapshot
        // compaction persisted is authoritative up to the archive
        // point, so adopt it, or failing that re-fold the fresh log
        // from its start (`apply` is idempotent over replayed facts).
        let gen_on_disk = read_generation(spool, &self.campaign);
        if gen_on_disk > self.generation {
            match Self::load(spool, &self.campaign) {
                Ok(disk) if disk.generation == gen_on_disk => *self = disk,
                _ => {
                    self.ledger_bytes = 0;
                    self.generation = gen_on_disk;
                }
            }
        }
        let scan = read_ledger_from(&ledger_path(spool, &self.campaign), self.ledger_bytes)?;
        for ev in &scan.events {
            self.apply(ev);
        }
        self.ledger_bytes = scan.bytes;
        self.skipped += scan.skipped;
        for id in &self.order {
            let entry = self.jobs.get_mut(id).unwrap();
            if entry.done {
                continue;
            }
            if !spool.join("done").join(format!("{id}.report.json")).exists() {
                continue;
            }
            entry.done = true;
            match campaign::read_stamp(spool, id) {
                Some(s) => {
                    entry.outcome = Some(s.outcome);
                    entry.host = s.host;
                    entry.worker = s.worker;
                    entry.epoch = s.epoch;
                }
                None => entry.outcome = None,
            }
            if entry.outcome == Some(StampOutcome::Ok) {
                entry.experiment = None; // only failures are resubmitted
            }
        }
        Ok(())
    }

    /// Job ids in submission order (the ledger twin of
    /// [`campaign::campaign_jobs`]).
    pub fn job_ids(&self) -> Vec<String> {
        self.order.clone()
    }

    /// Jobs not yet seen done — the only ones a `wait` needs to poll.
    pub fn pending_ids(&self) -> Vec<String> {
        self.order
            .iter()
            .filter(|id| self.jobs.get(*id).is_some_and(|e| !e.done))
            .cloned()
            .collect()
    }

    /// Dead-lettered jobs, in submission order.
    pub fn dead_letters(&self) -> Vec<&JobEntry> {
        self.order
            .iter()
            .filter_map(|id| self.jobs.get(id))
            .filter(|e| e.dead)
            .collect()
    }

    /// Campaign status from the index: done outcomes are folded state
    /// (no per-job I/O); only the pending jobs are existence-probed,
    /// via the same probe order as the directory-scan path.
    pub fn status(&self, spool: &Path) -> CampaignStatus {
        let pending = self.pending_ids();
        let mut st = campaign::status_of_jobs(spool, &pending);
        st.total = self.order.len();
        for id in &self.order {
            let Some(e) = self.jobs.get(id) else { continue };
            if !e.done {
                continue;
            }
            match e.outcome {
                Some(StampOutcome::Ok) => st.done_ok += 1,
                Some(StampOutcome::Error) => st.done_error += 1,
                None => st.done_unknown += 1,
            }
        }
        st
    }
}

// --------------------------------------------------------- operations

/// Ledger-mode submit: enqueue the experiments and append one
/// `submitted` fact per job (carrying the experiment itself, so a
/// later `elaps retry` can resubmit a failure without the original
/// file). The ledger *is* the campaign record — no flock'd JSON merge.
pub fn submit_experiments(spool: &Spooler, tag: &str, exps: &[Experiment]) -> Result<Vec<String>> {
    campaign::validate_tag(tag)?;
    std::fs::create_dir_all(ledger_dir(&spool.dir))?;
    let tagged = spool.clone().with_campaign(tag);
    let mut ids = Vec::with_capacity(exps.len());
    for exp in exps {
        let id = tagged.submit(exp)?;
        let mut ev = fact(spool, tag, EventKind::Submitted, &id);
        ev.extra.insert("attempt".into(), 1u64.into());
        ev.extra.insert("experiment".into(), io::experiment_to_json(exp));
        append(&spool.dir, tag, &[ev])?;
        ids.push(id);
    }
    Ok(ids)
}

/// The job ids of a campaign: from the ledger index when the campaign
/// has a ledger (and `use_ledger` allows it), else from the record
/// file — so pre-ledger campaigns keep working unchanged.
pub fn campaign_jobs_resolved(spool: &Path, tag: &str, use_ledger: bool) -> Result<Vec<String>> {
    if use_ledger && has_ledger(spool, tag) {
        let mut idx = CampaignIndex::load(spool, tag)?;
        idx.refresh(spool)?;
        let _ = idx.save(spool);
        return Ok(idx.job_ids());
    }
    campaign::campaign_jobs(spool, tag)
}

/// What [`retry_errors`] did.
#[derive(Debug, Clone, Default)]
pub struct RetryOutcome {
    /// `(failed job, resubmitted job)` pairs, in submission order.
    pub resubmitted: Vec<(String, String)>,
    /// Jobs dead-lettered this pass (attempt budget exhausted).
    pub dead_lettered: Vec<String>,
    /// Error jobs skipped because their experiment is not in the
    /// ledger (facts lost to corruption) — listed, never silently
    /// dropped.
    pub unrecoverable: Vec<String>,
}

/// Resubmit every error-stamped job of a campaign exactly once.
///
/// Runs under the campaign tag lock, so concurrent `elaps retry`
/// invocations — same host or another process — serialize; the
/// exactly-once guard is durable: a `retried` fact in the ledger marks
/// the failed job as replaced, and a replaced (or dead-lettered) job
/// is never resubmitted again. A failure whose chain already has
/// `max_attempts` attempts is dead-lettered instead, also as a ledger
/// fact.
pub fn retry_errors(spool: &Spooler, tag: &str, max_attempts: u64) -> Result<RetryOutcome> {
    campaign::validate_tag(tag)?;
    if !has_ledger(&spool.dir, tag) {
        bail!(
            "campaign '{tag}' has no ledger in {} — `elaps retry` needs a \
             ledger-backed campaign (submitted without --no-ledger)",
            spool.dir.display()
        );
    }
    let max_attempts = max_attempts.max(1);
    let _lock = campaign::lock_tag(&spool.dir, tag)?;
    let mut idx = CampaignIndex::load(&spool.dir, tag)?;
    idx.refresh(&spool.dir)?;
    let tagged = spool.clone().with_campaign(tag);
    let mut out = RetryOutcome::default();
    for id in idx.order.clone() {
        let Some(e) = idx.jobs.get(&id) else { continue };
        if !e.done
            || e.outcome != Some(StampOutcome::Error)
            || e.retried_to.is_some()
            || e.dead
        {
            continue;
        }
        let attempt = e.attempt;
        if attempt >= max_attempts {
            let mut ev = fact(spool, tag, EventKind::DeadLettered, &id);
            ev.extra.insert("attempts".into(), attempt.into());
            append(&spool.dir, tag, &[ev.clone()])?;
            idx.apply(&ev);
            out.dead_lettered.push(id);
            continue;
        }
        let Some(exp_json) = e.experiment.clone() else {
            out.unrecoverable.push(id);
            continue;
        };
        let exp = io::experiment_from_json(&exp_json)
            .with_context(|| format!("experiment of failed job {id} in ledger"))?;
        let new_id = tagged.submit(&exp)?;
        let mut retried = fact(spool, tag, EventKind::Retried, &new_id);
        retried.extra.insert("of".into(), Json::Str(id.clone()));
        retried.extra.insert("attempt".into(), (attempt + 1).into());
        let mut submitted = fact(spool, tag, EventKind::Submitted, &new_id);
        submitted.extra.insert("attempt".into(), (attempt + 1).into());
        submitted.extra.insert("experiment".into(), exp_json);
        append(&spool.dir, tag, &[retried.clone(), submitted.clone()])?;
        idx.apply(&retried);
        idx.apply(&submitted);
        out.resubmitted.push((id, new_id));
    }
    idx.save(&spool.dir)?;
    Ok(out)
}

/// Compact a campaign: fold the ledger into the index snapshot and
/// persist it. With `archive`, a fully ingested ledger is additionally
/// moved to `<spool>/ledger/archive/<tag>.log` — refused (not an
/// error) while unread appends remain, so an active submitter cannot
/// lose facts. Returns whether the ledger was archived.
pub fn compact(spool: &Path, tag: &str, archive: bool) -> Result<bool> {
    campaign::validate_tag(tag)?;
    let _lock = campaign::lock_tag(spool, tag)?;
    let mut idx = CampaignIndex::load(spool, tag)?;
    idx.refresh(spool)?;
    idx.save(spool)?;
    if !archive {
        return Ok(false);
    }
    let path = ledger_path(spool, tag);
    let size = match std::fs::metadata(&path) {
        Ok(m) => m.len(),
        Err(_) => return Ok(false), // already archived
    };
    if size != idx.ledger_bytes {
        return Ok(false); // unread (possibly in-flight) appends remain
    }
    let dir = ledger_dir(spool).join("archive");
    std::fs::create_dir_all(&dir)?;
    std::fs::rename(&path, dir.join(format!("{tag}.log")))?;
    // The log is gone: bump the generation and reset the snapshot's
    // cursor so it is authoritative for any refresh that raced past
    // the archive, then publish the new generation in the sidecar.
    idx.generation += 1;
    idx.ledger_bytes = 0;
    idx.save(spool)?;
    let gen_path = generation_path(spool, tag);
    let tmp = unique_tmp(&gen_path);
    std::fs::write(&tmp, idx.generation.to_string())?;
    std::fs::rename(&tmp, &gen_path)?;
    Ok(true)
}

// ------------------------------------------------- spool-wide status

fn status_cache_path(spool: &Path) -> PathBuf {
    ledger_dir(spool).join("status-cache.json")
}

/// `elaps spool status` through the ledger machinery: the queue and
/// running scans are unchanged (those directories are small by
/// construction), but the done set — the part that grows to millions —
/// is folded incrementally: stamps are read only for reports not yet
/// in the cache snapshot, so a quiet spool costs one readdir and zero
/// stamp reads. Jobs whose stamp was missing when first seen are
/// re-probed (never cached as unknown), so the output converges to the
/// directory-scan path's — the differential bar both must meet.
pub fn spool_status_ledger(dir: &Path) -> Result<lease::SpoolStatus> {
    let mut st = lease::status_queue_and_running(dir)?;
    let cache_path = status_cache_path(dir);
    let mut cache: BTreeMap<String, (String, String)> = BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(&cache_path) {
        if let Ok(j) = Json::parse(&text) {
            if let Some(obj) = j.get("done").as_obj() {
                for (id, v) in obj {
                    if let (Some(h), Some(o)) = (v.get("host").as_str(), v.get("outcome").as_str())
                    {
                        cache.insert(id.clone(), (h.to_string(), o.to_string()));
                    }
                }
            }
        }
    }
    let mut grew = false;
    for entry in std::fs::read_dir(dir.join("done"))?.filter_map(|e| e.ok()) {
        let Some(job_id) = entry
            .file_name()
            .to_str()
            .and_then(|n| n.strip_suffix(".report.json"))
            .map(String::from)
        else {
            continue;
        };
        st.done += 1;
        let (host, outcome) = match cache.get(&job_id) {
            Some((h, o)) => (h.clone(), o.clone()),
            None => match campaign::read_stamp(dir, &job_id) {
                Some(s) => {
                    let pair = (s.host, s.outcome.as_str().to_string());
                    cache.insert(job_id.clone(), pair.clone());
                    grew = true;
                    pair
                }
                None => ("(unknown)".to_string(), "unknown".to_string()),
            },
        };
        if outcome == "error" {
            st.done_errors += 1;
        }
        *st.done_by_host.entry(host).or_insert(0) += 1;
    }
    if grew {
        let mut done = Json::obj();
        for (id, (h, o)) in &cache {
            let mut e = Json::obj();
            e.set("host", h.as_str()).set("outcome", o.as_str());
            done.set(id.as_str(), e);
        }
        let mut j = Json::obj();
        j.set("v", 1u64).set("done", done);
        std::fs::create_dir_all(ledger_dir(dir))?;
        let tmp = unique_tmp(&cache_path);
        if std::fs::write(&tmp, j.to_string_pretty()).is_ok() {
            let _ = std::fs::rename(&tmp, &cache_path);
        }
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("elaps_ledger_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_fact(kind: EventKind, job: &str, seq: u64) -> Event {
        Event {
            kind,
            job_id: job.to_string(),
            campaign: "camp".into(),
            host: "hostA".into(),
            worker: "hostA#1-0".into(),
            epoch: 0,
            t_unix_ns: 1_700_000_000_000_000_000,
            seq,
            extra: BTreeMap::new(),
        }
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // the classic IEEE 802.3 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let ev = sample_fact(EventKind::Submitted, "job-1", 0);
        let payload = ev.to_json().to_string_compact();
        let line = frame_record(&payload);
        assert!(line.ends_with('\n'));
        assert_eq!(parse_frame(line.trim_end()), Some(payload.as_str()));
        // flip one payload byte: the CRC catches it
        let mut corrupt = line.trim_end().to_string();
        let n = corrupt.len() - 1;
        corrupt.replace_range(n.., "!");
        assert_eq!(parse_frame(&corrupt), None);
        // splice two frames on one line: the length check catches it
        let spliced = format!("{}{}", line.trim_end(), payload);
        assert_eq!(parse_frame(&spliced), None);
        assert_eq!(parse_frame("nonsense"), None);
        assert_eq!(parse_frame(""), None);
    }

    #[test]
    fn ledger_scan_tolerates_torn_tail_and_counts_bad_lines() {
        let a = sample_fact(EventKind::Submitted, "a", 0);
        let b = sample_fact(EventKind::Submitted, "b", 1);
        let c = sample_fact(EventKind::Submitted, "c", 2);
        let mut text = frame_record(&a.to_json().to_string_compact());
        text.push_str("deadbeef 4 junk\n"); // framed but CRC-wrong
        text.push_str(&frame_record(&b.to_json().to_string_compact()));
        let cut = frame_record(&c.to_json().to_string_compact());
        let keep = text.len();
        text.push_str(&cut[..cut.len() / 2]); // torn in-flight append
        let scan = parse_ledger_text(&text);
        assert_eq!(scan.events, vec![a, b]);
        assert_eq!(scan.skipped, 1);
        assert_eq!(scan.bytes, keep as u64, "cursor stops before the torn tail");
    }

    #[test]
    fn append_and_incremental_read_roundtrip() {
        let dir = tmpdir("append");
        std::fs::create_dir_all(&dir).unwrap();
        let a = sample_fact(EventKind::Submitted, "a", 0);
        let b = sample_fact(EventKind::Submitted, "b", 1);
        append(&dir, "camp", &[a.clone()]).unwrap();
        let first = read_ledger_from(&ledger_path(&dir, "camp"), 0).unwrap();
        assert_eq!(first.events, vec![a.clone()]);
        append(&dir, "camp", &[b.clone()]).unwrap();
        // resuming from the cursor yields exactly the new fact
        let second = read_ledger_from(&ledger_path(&dir, "camp"), first.bytes).unwrap();
        assert_eq!(second.events, vec![b]);
        assert_eq!(second.skipped, 0);
        // a missing ledger scans as empty at the same cursor
        let none = read_ledger_from(&ledger_path(&dir, "nope"), 7).unwrap();
        assert!(none.events.is_empty());
        assert_eq!(none.bytes, 7);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn index_folds_submit_retry_dead_letter_facts() {
        let dir = tmpdir("fold");
        std::fs::create_dir_all(&dir).unwrap();
        let mut sub = sample_fact(EventKind::Submitted, "j1", 0);
        sub.extra.insert("attempt".into(), 1u64.into());
        let mut retried = sample_fact(EventKind::Retried, "j2", 1);
        retried.extra.insert("of".into(), Json::Str("j1".into()));
        retried.extra.insert("attempt".into(), 2u64.into());
        let mut sub2 = sample_fact(EventKind::Submitted, "j2", 2);
        sub2.extra.insert("attempt".into(), 2u64.into());
        let mut dead = sample_fact(EventKind::DeadLettered, "j2", 3);
        dead.extra.insert("attempts".into(), 2u64.into());
        append(&dir, "camp", &[sub, retried, sub2, dead]).unwrap();
        let mut idx = CampaignIndex::load(&dir, "camp").unwrap();
        idx.refresh(&dir).unwrap();
        assert_eq!(idx.job_ids(), vec!["j1".to_string(), "j2".to_string()]);
        assert_eq!(idx.jobs["j1"].retried_to.as_deref(), Some("j2"));
        assert_eq!(idx.jobs["j2"].retry_of.as_deref(), Some("j1"));
        assert_eq!(idx.jobs["j2"].attempt, 2);
        assert!(idx.jobs["j2"].dead);
        assert_eq!(idx.dead_letters().len(), 1);
        // snapshot roundtrip preserves the folded state and cursor
        idx.save(&dir).unwrap();
        let idx2 = CampaignIndex::load(&dir, "camp").unwrap();
        assert_eq!(idx2.ledger_bytes, idx.ledger_bytes);
        assert_eq!(idx2.order, idx.order);
        assert_eq!(idx2.jobs, idx.jobs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ledger_submit_wait_status_roundtrip() {
        let dir = tmpdir("roundtrip");
        let spool = Spooler::new(&dir).unwrap().with_events(false);
        let exps: Vec<_> = (0..3i64).map(|i| dgemm_experiment(8 + 4 * i)).collect();
        let ids = submit_experiments(&spool, "camp", &exps).unwrap();
        assert_eq!(ids.len(), 3);
        assert!(has_ledger(&dir, "camp"));
        assert_eq!(campaign_jobs_resolved(&dir, "camp", true).unwrap(), ids);
        // no record file was written: the ledger is the canonical store
        assert!(campaign::campaign_jobs(&dir, "camp").is_err());
        let mut idx = CampaignIndex::load(&dir, "camp").unwrap();
        idx.refresh(&dir).unwrap();
        let st = idx.status(&dir);
        assert_eq!((st.total, st.queued, st.done()), (3, 3, 0));
        spool.drain(2).unwrap();
        idx.refresh(&dir).unwrap();
        let st = idx.status(&dir);
        assert_eq!((st.total, st.done_ok), (3, 3));
        assert!(idx.pending_ids().is_empty());
        // done-ok entries drop their embedded experiment
        assert!(idx.jobs[&ids[0]].experiment.is_none());
        // compact + archive: the fully ingested ledger moves aside and
        // the snapshot alone still answers queries
        idx.save(&dir).unwrap();
        assert!(compact(&dir, "camp", true).unwrap());
        assert!(!ledger_path(&dir, "camp").exists());
        let mut idx2 = CampaignIndex::load(&dir, "camp").unwrap();
        idx2.refresh(&dir).unwrap();
        assert_eq!(idx2.job_ids(), ids);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_index_resyncs_across_archive_via_generation_marker() {
        let dir = tmpdir("genmark");
        std::fs::create_dir_all(&dir).unwrap();
        append(&dir, "camp", &[sample_fact(EventKind::Submitted, "a", 0)]).unwrap();
        // a long-lived reader folds the first fact and keeps its cursor
        let mut stale = CampaignIndex::load(&dir, "camp").unwrap();
        stale.refresh(&dir).unwrap();
        let old_cursor = stale.ledger_bytes;
        assert!(old_cursor > 0);
        // compaction archives the log behind the reader's back...
        assert!(compact(&dir, "camp", true).unwrap());
        // ...and enough new facts land that the recreated log grows
        // PAST the old cursor — the case a length check cannot detect
        let fresh: Vec<Event> = (0..8)
            .map(|i| sample_fact(EventKind::Submitted, &format!("post{i}"), 1 + i))
            .collect();
        append(&dir, "camp", &fresh).unwrap();
        assert!(std::fs::metadata(ledger_path(&dir, "camp")).unwrap().len() > old_cursor);
        stale.refresh(&dir).unwrap();
        let mut want = vec!["a".to_string()];
        want.extend((0..8).map(|i| format!("post{i}")));
        assert_eq!(stale.job_ids(), want, "every fact seen exactly once across the archive");
        assert_eq!(stale.generation, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_refuses_archive_with_unread_appends() {
        let dir = tmpdir("compactref");
        std::fs::create_dir_all(&dir).unwrap();
        append(&dir, "camp", &[sample_fact(EventKind::Submitted, "a", 0)]).unwrap();
        // compact folds everything → archivable
        let mut idx = CampaignIndex::load(&dir, "camp").unwrap();
        idx.refresh(&dir).unwrap();
        idx.save(&dir).unwrap();
        // a new append lands after the snapshot: archive must refuse
        append(&dir, "camp", &[sample_fact(EventKind::Submitted, "b", 1)]).unwrap();
        std::fs::create_dir_all(dir.join("queue")).unwrap();
        std::fs::create_dir_all(dir.join("running")).unwrap();
        std::fs::create_dir_all(dir.join("done")).unwrap();
        // (compact() itself re-refreshes, so it *will* ingest the new
        // fact and then archive; simulate a stale-snapshot archiver by
        // checking the guard directly)
        let size = std::fs::metadata(ledger_path(&dir, "camp")).unwrap().len();
        assert!(idx.ledger_bytes < size, "stale cursor must differ from file size");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
