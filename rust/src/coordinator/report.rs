//! Reports (§3.2.3) and metrics: structured access to raw measurements
//! — the hierarchy "parameter-range value → repetition → sum/OpenMP-
//! range value → kernel" — plus the reduced view that accumulates the
//! sum-/OpenMP-range and the kernels, converted to metrics and reduced
//! by statistics.

use super::experiment::Experiment;
use super::stats::{maybe_discard_first, Stat};
use crate::perfmodel::{scaling, MachineModel};
use crate::sampler::Record;
use anyhow::{bail, Result};

/// Performance metric (§3.2.3: "from execution time in seconds to
/// Gflops/s and efficiency").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    Cycles,
    TimeS,
    TimeMs,
    Gflops,
    FlopsPerCycle,
    /// Attained fraction of machine peak (in %), using the thread
    /// count of the measurement point.
    Efficiency,
    /// Simulated PAPI counter by index into `experiment.counters`.
    Counter(usize),
}

impl Metric {
    pub fn name(self) -> String {
        match self {
            Metric::Cycles => "cycles".into(),
            Metric::TimeS => "time [s]".into(),
            Metric::TimeMs => "time [ms]".into(),
            Metric::Gflops => "Gflops/s".into(),
            Metric::FlopsPerCycle => "flops/cycle".into(),
            Metric::Efficiency => "efficiency [%]".into(),
            Metric::Counter(i) => format!("counter[{i}]"),
        }
    }

    /// Whether smaller values of this metric are better — the
    /// comparison direction used by differential reports (winner per
    /// point, library ranking).
    pub fn lower_is_better(self) -> bool {
        match self {
            Metric::Cycles | Metric::TimeS | Metric::TimeMs | Metric::Counter(_) => true,
            Metric::Gflops | Metric::FlopsPerCycle | Metric::Efficiency => false,
        }
    }
}

/// Results of one parameter-range point.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub range_value: i64,
    pub nthreads: usize,
    pub sum_iters: usize,
    pub calls_per_iter: usize,
    /// Flat records: index = (rep × sum_iters + si) × calls_per_iter + c.
    pub records: Vec<Record>,
}

impl PointResult {
    pub fn nreps(&self) -> usize {
        self.records.len() / (self.sum_iters * self.calls_per_iter).max(1)
    }

    /// Raw record access (range → rep → sum iter → kernel).
    pub fn record(&self, rep: usize, si: usize, call: usize) -> &Record {
        &self.records[(rep * self.sum_iters + si) * self.calls_per_iter + call]
    }
}

/// The report: experiment + all measurement points.
#[derive(Debug, Clone)]
pub struct Report {
    pub experiment: Experiment,
    pub machine: MachineModel,
    pub points: Vec<PointResult>,
}

impl Report {
    /// Bundle records into a report, validating counts.
    pub fn assemble(
        experiment: Experiment,
        machine: MachineModel,
        points: Vec<PointResult>,
    ) -> Result<Report> {
        for p in &points {
            let per_rep = p.sum_iters * p.calls_per_iter;
            if per_rep == 0 || p.records.len() % per_rep != 0 {
                bail!(
                    "point {}: {} records not divisible by {} per rep",
                    p.range_value,
                    p.records.len(),
                    per_rep
                );
            }
        }
        Ok(Report { experiment, machine, points })
    }

    /// Reduced wall time of one repetition at one point, applying the
    /// thread-scaling model (DESIGN.md §Substitutions 4):
    /// * plain/sum-range: sum over all calls of the library-threaded
    ///   time,
    /// * OpenMP-range: the parallel-tasks model over the repetition's
    ///   task list.
    pub fn rep_seconds(&self, point: &PointResult, rep: usize) -> f64 {
        let per_rep = point.sum_iters * point.calls_per_iter;
        let recs = &point.records[rep * per_rep..(rep + 1) * per_rep];
        let lib = crate::libraries::by_name(&self.experiment.library);
        let pf = |kernel: &str| -> f64 {
            lib.as_ref().map(|l| l.parallel_fraction(kernel)).unwrap_or(0.9)
        };
        if self.experiment.omp {
            // tasks: every record in the repetition
            let total_serial: f64 = recs.iter().map(|r| r.seconds).sum();
            let ntasks = recs.len();
            let mean_task = total_serial / ntasks.max(1) as f64;
            let mean_pf =
                recs.iter().map(|r| pf(&r.kernel)).sum::<f64>() / ntasks.max(1) as f64;
            scaling::omp_tasks_time(
                mean_task,
                ntasks,
                self.machine.cores, // OpenMP uses all cores
                point.nthreads,
                mean_pf,
                &self.machine,
            )
        } else if point.nthreads <= 1 {
            recs.iter().map(|r| r.seconds).sum()
        } else {
            recs.iter()
                .map(|r| {
                    scaling::library_threads_time(
                        r.seconds,
                        pf(&r.kernel),
                        point.nthreads,
                        &self.machine,
                    )
                })
                .sum()
        }
    }

    /// Total flops of one repetition.
    pub fn rep_flops(&self, point: &PointResult, rep: usize) -> f64 {
        let per_rep = point.sum_iters * point.calls_per_iter;
        point.records[rep * per_rep..(rep + 1) * per_rep]
            .iter()
            .map(|r| r.flops)
            .sum()
    }

    /// Per-repetition values of a metric at one point.
    pub fn rep_values(&self, point: &PointResult, metric: Metric) -> Vec<f64> {
        (0..point.nreps())
            .map(|rep| {
                let secs = self.rep_seconds(point, rep);
                let flops = self.rep_flops(point, rep);
                match metric {
                    Metric::Cycles => self.machine.cycles(secs),
                    Metric::TimeS => secs,
                    Metric::TimeMs => secs * 1e3,
                    // a modeled repetition can reduce to exactly 0
                    // seconds (e.g. a degenerate call list); rate
                    // metrics report 0.0 then, never inf/NaN
                    Metric::Gflops => {
                        if secs > 0.0 {
                            flops / secs / 1e9
                        } else {
                            0.0
                        }
                    }
                    Metric::FlopsPerCycle => {
                        let cycles = self.machine.cycles(secs);
                        if cycles > 0.0 {
                            flops / cycles
                        } else {
                            0.0
                        }
                    }
                    Metric::Efficiency => {
                        // the scaling model clamps threads to physical
                        // cores (perfmodel/scaling.rs); the peak in the
                        // denominator must agree, or oversubscribed
                        // points are judged against capacity the
                        // machine does not have
                        let t = point.nthreads.min(self.machine.cores).max(1);
                        if secs > 0.0 {
                            100.0 * flops / secs / self.machine.peak_flops(t)
                        } else {
                            0.0
                        }
                    }
                    Metric::Counter(i) => {
                        let per_rep = point.sum_iters * point.calls_per_iter;
                        point.records[rep * per_rep..(rep + 1) * per_rep]
                            .iter()
                            .map(|r| r.counters.get(i).copied().unwrap_or(0) as f64)
                            .sum()
                    }
                }
            })
            .collect()
    }

    /// A metric/statistic series over the parameter range:
    /// (range value, stat over repetitions).
    pub fn series(&self, metric: Metric, stat: Stat) -> Vec<(i64, f64)> {
        self.points
            .iter()
            .map(|p| {
                let vals = self.rep_values(p, metric);
                let vals = maybe_discard_first(&vals, self.experiment.discard_first);
                (p.range_value, stat.apply(vals))
            })
            .collect()
    }

    /// Per-call time breakdown (§2.3 / Fig. 3): for each call of the
    /// experiment, the stat over repetitions of its summed (over the
    /// sum-range) time, per point.
    pub fn call_breakdown(&self, stat: Stat) -> Vec<Vec<(String, f64)>> {
        self.points
            .iter()
            .map(|p| {
                (0..p.calls_per_iter)
                    .map(|c| {
                        let label = format!("{}#{c}", self.experiment.calls[c].kernel);
                        let vals: Vec<f64> = (0..p.nreps())
                            .map(|rep| {
                                (0..p.sum_iters)
                                    .map(|si| p.record(rep, si, c).seconds)
                                    .sum()
                            })
                            .collect();
                        let vals =
                            maybe_discard_first(&vals, self.experiment.discard_first);
                        (label, stat.apply(vals))
                    })
                    .collect()
            })
            .collect()
    }

    /// The paper's §2 metrics table for single-point experiments.
    ///
    /// Errors on a report with no measurement points (possible via a
    /// malformed or empty range) instead of panicking on the missing
    /// first series entry.
    pub fn metrics_table(&self) -> Result<Vec<(String, f64)>> {
        if self.points.is_empty() {
            bail!("report '{}' has no measurement points", self.experiment.name);
        }
        let stat = Stat::Median;
        Ok([
            Metric::Cycles,
            Metric::TimeMs,
            Metric::Gflops,
            Metric::FlopsPerCycle,
            Metric::Efficiency,
        ]
        .iter()
        .map(|&m| (m.name(), self.series(m, stat)[0].1))
        .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;

    fn fake_record(kernel: &str, seconds: f64, flops: f64) -> Record {
        Record {
            kernel: kernel.into(),
            seconds,
            cycles: seconds * 2.6e9,
            counters: vec![],
            omp_group: None,
            flops,
        }
    }

    fn fake_report(nreps: usize, omp: bool) -> Report {
        let mut exp = dgemm_experiment(100);
        exp.nreps = nreps;
        exp.omp = omp;
        let machine = MachineModel::sandybridge();
        let records: Vec<Record> =
            (0..nreps).map(|r| fake_record("dgemm", 0.01 * (1 + r % 2) as f64, 2e6)).collect();
        Report::assemble(
            exp,
            machine,
            vec![PointResult {
                range_value: 0,
                nthreads: 1,
                sum_iters: 1,
                calls_per_iter: 1,
                records,
            }],
        )
        .unwrap()
    }

    #[test]
    fn series_and_stats() {
        let rep = fake_report(4, false);
        let s = rep.series(Metric::TimeMs, Stat::Min);
        assert_eq!(s.len(), 1);
        assert!((s[0].1 - 10.0).abs() < 1e-9);
        let g = rep.series(Metric::Gflops, Stat::Max);
        assert!((g[0].1 - 2e6 / 0.01 / 1e9).abs() < 1e-9);
    }

    #[test]
    fn efficiency_against_peak() {
        let rep = fake_report(1, false);
        let e = rep.series(Metric::Efficiency, Stat::Avg)[0].1;
        // 2e6 flops / 0.01 s = 0.2 Gflops/s on a 20.8 Gflops peak
        assert!((e - 100.0 * 0.2 / 20.8).abs() < 0.01, "{e}");
    }

    #[test]
    fn efficiency_clamps_oversubscribed_threads_to_cores() {
        // the scaling model clamps nthreads to machine.cores, so an
        // oversubscribed point runs exactly like a cores-wide one —
        // its efficiency must be judged against the same (physical)
        // peak, not a phantom nthreads× one
        let report_at = |nthreads: usize| {
            let exp = dgemm_experiment(100);
            let machine = MachineModel::sandybridge(); // 8 cores
            Report::assemble(
                exp,
                machine,
                vec![PointResult {
                    range_value: 0,
                    nthreads,
                    sum_iters: 1,
                    calls_per_iter: 1,
                    records: vec![fake_record("dgemm", 0.01, 2e6)],
                }],
            )
            .unwrap()
        };
        let at_cores = report_at(8).series(Metric::Efficiency, Stat::Avg)[0].1;
        let oversub = report_at(64).series(Metric::Efficiency, Stat::Avg)[0].1;
        assert!(
            (oversub - at_cores).abs() < 1e-12,
            "nthreads=64 efficiency {oversub} must equal nthreads=8 {at_cores}"
        );
        // and the old unclamped denominator would have been 8× off
        assert!(oversub > at_cores / 2.0, "{oversub} vs {at_cores}");
    }

    #[test]
    fn discard_first_respected() {
        let mut rep = fake_report(3, false);
        // values: 10ms, 20ms, 10ms
        rep.experiment.discard_first = true;
        let avg = rep.series(Metric::TimeMs, Stat::Avg)[0].1;
        assert!((avg - 15.0).abs() < 1e-9);
        rep.experiment.discard_first = false;
        let avg2 = rep.series(Metric::TimeMs, Stat::Avg)[0].1;
        assert!((avg2 - 40.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn omp_reduction_faster_than_sum() {
        // 4 identical tasks on 8 cores: parallel wall ≪ serial sum
        let mut exp = dgemm_experiment(100);
        exp.omp = true;
        let machine = MachineModel::sandybridge();
        let records: Vec<Record> = (0..4).map(|_| fake_record("dgemm", 0.01, 2e6)).collect();
        let rep = Report::assemble(
            exp,
            machine,
            vec![PointResult {
                range_value: 0,
                nthreads: 1,
                sum_iters: 4,
                calls_per_iter: 1,
                records,
            }],
        )
        .unwrap();
        let wall = rep.rep_seconds(&rep.points[0], 0);
        assert!(wall < 0.02, "parallel wall {wall} should be < serial 0.04");
    }

    #[test]
    fn record_count_validated() {
        let exp = dgemm_experiment(100);
        let machine = MachineModel::sandybridge();
        let bad = Report::assemble(
            exp,
            machine,
            vec![PointResult {
                range_value: 0,
                nthreads: 1,
                sum_iters: 2,
                calls_per_iter: 1,
                records: vec![fake_record("dgemm", 0.01, 1.0)],
            }],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn metrics_table_has_paper_rows() {
        let rep = fake_report(2, false);
        let table = rep.metrics_table().unwrap();
        let names: Vec<&str> = table.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["cycles", "time [ms]", "Gflops/s", "flops/cycle", "efficiency [%]"]
        );
    }

    #[test]
    fn metrics_table_on_empty_report_errors_instead_of_panicking() {
        let exp = dgemm_experiment(100);
        let machine = MachineModel::sandybridge();
        let rep = Report::assemble(exp, machine, vec![]).unwrap();
        let err = rep.metrics_table().unwrap_err();
        assert!(err.to_string().contains("no measurement points"), "{err}");
    }

    #[test]
    fn zero_second_repetition_yields_zero_rates_not_inf() {
        let exp = dgemm_experiment(100);
        let machine = MachineModel::sandybridge();
        let rep = Report::assemble(
            exp,
            machine,
            vec![PointResult {
                range_value: 0,
                nthreads: 1,
                sum_iters: 1,
                calls_per_iter: 1,
                records: vec![fake_record("dgemm", 0.0, 2e6)],
            }],
        )
        .unwrap();
        for metric in [Metric::Gflops, Metric::FlopsPerCycle, Metric::Efficiency] {
            let v = rep.series(metric, Stat::Median)[0].1;
            assert!(v.is_finite(), "{metric:?} must be finite, got {v}");
            assert_eq!(v, 0.0, "{metric:?} at 0 s must be 0.0");
        }
    }

    #[test]
    fn rate_direction_is_higher_is_better() {
        assert!(Metric::TimeS.lower_is_better());
        assert!(Metric::Cycles.lower_is_better());
        assert!(Metric::Counter(0).lower_is_better());
        assert!(!Metric::Gflops.lower_is_better());
        assert!(!Metric::Efficiency.lower_is_better());
    }
}
