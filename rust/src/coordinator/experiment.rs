//! The [`Experiment`] class — the paper's central concept (§2, §3.2.1):
//! one or more kernel calls, repeated `nreps` times, optionally swept
//! over a parameter range and/or a sum-/OpenMP-range, with per-operand
//! "vary" control (fresh memory per repetition / range iteration) —
//! and its translation into sampler command scripts (§3.2.2).

use super::symbolic::{Bindings, Expr};
use crate::kernels::{ArgRole, Signature};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// One argument of an experiment call.
#[derive(Debug, Clone, PartialEq)]
pub enum CallArg {
    /// Flag character (side/uplo/trans/diag/jobz).
    Flag(char),
    /// Integer expression (dims, leading dimensions, strides).
    Expr(Expr),
    /// Floating scalar (alpha, beta).
    Scalar(f64),
    /// Logical operand name.
    Data(String),
}

impl CallArg {
    pub fn n(v: i64) -> CallArg {
        CallArg::Expr(Expr::Const(v))
    }
    pub fn sym(s: &str) -> CallArg {
        CallArg::Expr(Expr::Sym(s.to_string()))
    }
    pub fn expr(s: &str) -> CallArg {
        CallArg::Expr(Expr::parse(s).expect("bad expression"))
    }
}

/// One kernel call of the experiment.
#[derive(Debug, Clone)]
pub struct Call {
    pub kernel: String,
    pub args: Vec<CallArg>,
}

impl Call {
    /// Build a call, checking arity against the signature.
    pub fn new(kernel: &str, args: Vec<CallArg>) -> Result<Call> {
        let sig = crate::kernels::lookup(kernel)
            .ok_or_else(|| anyhow!("unknown kernel '{kernel}'"))?;
        if sig.args.len() != args.len() {
            bail!(
                "{kernel}: expected {} args ({}), got {}",
                sig.args.len(),
                sig.args.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", "),
                args.len()
            );
        }
        Ok(Call { kernel: kernel.to_string(), args })
    }

    pub fn sig(&self) -> &'static Signature {
        crate::kernels::lookup(&self.kernel).expect("validated in new()")
    }
}

/// How an operand's contents are initialized.
#[derive(Debug, Clone, PartialEq)]
pub enum DataGen {
    /// Uniform random ]0,1[ (the sampler's dgerand) — the default.
    Rand,
    /// Random symmetric positive definite n×n (dporand).
    Spd(Expr),
    /// Random lower/upper triangular n×n (dtrrand).
    Tri(Expr, char),
    /// Zero-initialized.
    Zero,
}

/// Operand vary specification (§2.2): fresh memory per repetition
/// and/or per sum-/OpenMP-range iteration, with an optional pad between
/// consecutive instances (the paper's "arbitrary offset").
#[derive(Debug, Clone, Default)]
pub struct Vary {
    pub with_rep: bool,
    pub with_sumrange: bool,
    /// Extra elements between instances.
    pub pad_elems: usize,
}

/// A named range: symbol + values.
#[derive(Debug, Clone, PartialEq)]
pub struct RangeDef {
    pub sym: String,
    pub values: Vec<i64>,
}

impl RangeDef {
    pub fn new(sym: &str, values: Vec<i64>) -> RangeDef {
        RangeDef { sym: sym.to_string(), values }
    }

    /// `lo:step:hi` inclusive.
    pub fn span(sym: &str, lo: i64, step: i64, hi: i64) -> RangeDef {
        let mut values = Vec::new();
        let mut v = lo;
        while v <= hi {
            values.push(v);
            v += step;
        }
        RangeDef::new(sym, values)
    }
}

/// The experiment description (paper §3.2.1). Serializable to JSON for
/// file-based workflows ([`super::io`]).
#[derive(Debug, Clone)]
pub struct Experiment {
    pub name: String,
    /// Sampler backend (library) to run on: rustref / rustblocked /
    /// rustrecursive / xla.
    pub library: String,
    /// Machine model name to report metrics against.
    pub machine: String,
    /// Library-internal threads. On this 1-core host values > 1 mark
    /// the experiment for the thread-scaling model (DESIGN.md §Subst 4).
    pub nthreads: Expr,
    /// Repetitions (§2.1).
    pub nreps: usize,
    /// Whether statistics drop the first repetition (§2.1).
    pub discard_first: bool,
    /// Parameter range (§2.4) — outer sweep, one measurement series
    /// per value.
    pub range: Option<RangeDef>,
    /// Sum-range (§2.5) or OpenMP-range (§2.5.1) — inner loop within a
    /// repetition.
    pub sumrange: Option<RangeDef>,
    /// If true the sum-range iterations are parallel OpenMP tasks.
    pub omp: bool,
    /// The kernel calls (≥ 1; §2.3 sequences).
    pub calls: Vec<Call>,
    /// Operand initialization (operand name → generator).
    pub datagen: BTreeMap<String, DataGen>,
    /// Operand vary specs (§2.2).
    pub vary: BTreeMap<String, Vary>,
    /// PAPI counters to sample.
    pub counters: Vec<String>,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "experiment".into(),
            library: "rustblocked".into(),
            machine: "localhost".into(),
            nthreads: Expr::Const(1),
            nreps: 1,
            discard_first: false,
            range: None,
            sumrange: None,
            omp: false,
            calls: vec![],
            datagen: BTreeMap::new(),
            vary: BTreeMap::new(),
            counters: vec![],
        }
    }
}

/// The fully unrolled script for one parameter-range value, plus the
/// index structure needed to fold the sampler's flat record stream back
/// into (rep, sumrange-iteration, call).
#[derive(Debug, Clone)]
pub struct UnrolledPoint {
    /// Parameter-range value this script belongs to (0 if no range).
    pub range_value: i64,
    /// Library threads at this point.
    pub nthreads: usize,
    /// The sampler command script.
    pub script: String,
    /// Number of sum-range iterations per repetition (1 if none).
    pub sum_iters: usize,
    /// Calls per sum-range iteration.
    pub calls_per_iter: usize,
}

impl UnrolledPoint {
    /// Total records expected from the sampler.
    pub fn expected_records(&self, nreps: usize) -> usize {
        nreps * self.sum_iters * self.calls_per_iter
    }
}

impl Experiment {
    /// Validate and unroll into one sampler script per parameter-range
    /// value (§3.2.2).
    pub fn unroll(&self) -> Result<Vec<UnrolledPoint>> {
        if self.calls.is_empty() {
            bail!("experiment has no calls");
        }
        if self.nreps == 0 {
            bail!("nreps must be ≥ 1");
        }
        let range_values: Vec<i64> = match &self.range {
            Some(r) if r.values.is_empty() => bail!("empty parameter range"),
            Some(r) => r.values.clone(),
            None => vec![0],
        };
        let mut out = Vec::with_capacity(range_values.len());
        for &rv in &range_values {
            out.push(self.unroll_point(rv)?);
        }
        Ok(out)
    }

    fn base_bindings(&self, rv: i64) -> Bindings {
        let mut b = Bindings::new();
        if let Some(r) = &self.range {
            b.insert(r.sym.clone(), rv);
        }
        b
    }

    /// Operand element size: max over all calls and all loop bindings
    /// of the signature-derived size.
    fn operand_size(&self, op: &str, rv: i64) -> Result<usize> {
        let sum_values: Vec<i64> = match &self.sumrange {
            Some(s) => s.values.clone(),
            None => vec![0],
        };
        let mut worst = 0usize;
        for call in &self.calls {
            let sig = call.sig();
            for sv in &sum_values {
                let mut b = self.base_bindings(rv);
                if let Some(s) = &self.sumrange {
                    b.insert(s.sym.clone(), *sv);
                }
                let av = eval_call(call, sig, &b)?;
                let mut ord = 0;
                for (i, (_, role)) in sig.args.iter().enumerate() {
                    if let ArgRole::Data(_) = role {
                        if av.values[i].as_data() == Some(op) {
                            worst = worst.max(av.operand_elems(ord));
                        }
                        ord += 1;
                    }
                }
            }
        }
        Ok(worst)
    }

    /// All logical operand names, in first-appearance order.
    pub fn operands(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for call in &self.calls {
            let sig = call.sig();
            for (i, (_, role)) in sig.args.iter().enumerate() {
                if let ArgRole::Data(_) = role {
                    if let CallArg::Data(name) = &call.args[i] {
                        if !out.contains(name) {
                            out.push(name.clone());
                        }
                    }
                }
            }
        }
        out
    }

    fn unroll_point(&self, rv: i64) -> Result<UnrolledPoint> {
        let mut script = String::new();
        let b0 = self.base_bindings(rv);
        let nthreads = self.nthreads.eval_usize(&b0).map_err(|e| anyhow!(e))? .max(1);
        if !self.counters.is_empty() {
            script.push_str(&format!("set_counters {}\n", self.counters.join(" ")));
        }
        script.push_str(&format!("set_threads {nthreads}\n"));

        let sum_values: Vec<i64> = match &self.sumrange {
            Some(s) if s.values.is_empty() => bail!("empty sum-range"),
            Some(s) => s.values.clone(),
            None => vec![0],
        };
        let sum_iters = sum_values.len();

        // --- allocations (§3.2.2: varying operands are one large
        // block subdivided via offsets) ---
        for op in self.operands() {
            let size = self.operand_size(&op, rv)?;
            let vary = self.vary.get(&op).cloned().unwrap_or_default();
            let rep_inst = if vary.with_rep { self.nreps } else { 1 };
            let sum_inst = if vary.with_sumrange { sum_iters } else { 1 };
            let instances = rep_inst * sum_inst;
            let stride = size + vary.pad_elems;
            if instances == 1 {
                script.push_str(&format!("dmalloc {op} {size}\n"));
                self.emit_datagen(&mut script, &op, &op, &b0, &sum_values, None)?;
            } else {
                script.push_str(&format!("dmalloc {op}__blk {}\n", stride * instances));
                for r in 0..rep_inst {
                    for s in 0..sum_inst {
                        let inst = instance_name(&op, vary.with_rep.then_some(r), vary.with_sumrange.then_some(s));
                        let idx = r * sum_inst + s;
                        script.push_str(&format!("doffset {inst} {op}__blk {}\n", idx * stride));
                        self.emit_datagen(
                            &mut script, &inst, &op, &b0, &sum_values,
                            vary.with_sumrange.then_some(s),
                        )?;
                    }
                }
            }
        }

        // --- call loop nest ---
        for rep in 0..self.nreps {
            if self.omp {
                script.push_str("{omp\n");
            }
            for (si, sv) in sum_values.iter().enumerate() {
                let mut b = b0.clone();
                if let Some(s) = &self.sumrange {
                    b.insert(s.sym.clone(), *sv);
                }
                b.insert("rep".to_string(), rep as i64);
                for call in &self.calls {
                    script.push_str(&self.render_call(call, &b, rep, si)?);
                    script.push('\n');
                }
            }
            if self.omp {
                script.push_str("}\n");
            }
        }
        script.push_str("go\n");
        Ok(UnrolledPoint {
            range_value: rv,
            nthreads,
            script,
            sum_iters,
            calls_per_iter: self.calls.len(),
        })
    }

    /// Emit the data-generation command for one operand instance.
    /// Size expressions may reference the sum-range symbol: an instance
    /// tied to a specific iteration (`si`) binds that value; a shared
    /// operand is generated at the maximum size over the sum-range.
    fn emit_datagen(
        &self,
        script: &mut String,
        inst: &str,
        op: &str,
        b0: &Bindings,
        sum_values: &[i64],
        si: Option<usize>,
    ) -> Result<()> {
        let eval_dim = |e: &Expr| -> Result<usize> {
            let candidates: Vec<i64> = match si {
                Some(s) => vec![sum_values[s]],
                None => sum_values.to_vec(),
            };
            let mut best = None;
            for sv in candidates {
                let mut b = b0.clone();
                if let Some(s) = &self.sumrange {
                    b.insert(s.sym.clone(), sv);
                }
                let v = e.eval_usize(&b).map_err(|e| anyhow!(e))?;
                best = Some(best.map_or(v, |x: usize| x.max(v)));
            }
            best.ok_or_else(|| anyhow!("no bindings for datagen of '{op}'"))
        };
        match self.datagen.get(op).unwrap_or(&DataGen::Rand) {
            DataGen::Rand => script.push_str(&format!("dgerand {inst}\n")),
            DataGen::Zero => script.push_str(&format!("dmemset {inst} 0\n")),
            DataGen::Spd(e) => {
                let n = eval_dim(e)?;
                script.push_str(&format!("dporand {inst} {n}\n"));
            }
            DataGen::Tri(e, uplo) => {
                let n = eval_dim(e)?;
                script.push_str(&format!("dtrrand {inst} {n} {uplo}\n"));
            }
        }
        Ok(())
    }

    fn render_call(&self, call: &Call, b: &Bindings, rep: usize, si: usize) -> Result<String> {
        let sig = call.sig();
        let mut line = call.kernel.clone();
        for (arg, (name, role)) in call.args.iter().zip(sig.args) {
            line.push(' ');
            match (arg, role) {
                (CallArg::Flag(c), ArgRole::Flag(_)) => line.push(*c),
                (CallArg::Expr(e), ArgRole::Dim | ArgRole::Ld | ArgRole::Inc) => {
                    line.push_str(&e.eval_usize(b).map_err(|e| anyhow!("{}: {e}", call.kernel))?.to_string())
                }
                (CallArg::Scalar(v), ArgRole::Scalar) => line.push_str(&v.to_string()),
                (CallArg::Expr(e), ArgRole::Scalar) => {
                    line.push_str(&e.eval(b).map_err(|e| anyhow!(e))?.to_string())
                }
                (CallArg::Data(opname), ArgRole::Data(_)) => {
                    let vary = self.vary.get(opname).cloned().unwrap_or_default();
                    // must match the allocation logic: one instance ⇒
                    // plain name (even if marked varying)
                    let sum_iters = self.sumrange.as_ref().map_or(1, |s| s.values.len());
                    let rep_inst = if vary.with_rep { self.nreps } else { 1 };
                    let sum_inst = if vary.with_sumrange { sum_iters } else { 1 };
                    if rep_inst * sum_inst > 1 {
                        line.push_str(&instance_name(
                            opname,
                            vary.with_rep.then_some(rep),
                            vary.with_sumrange.then_some(si),
                        ));
                    } else {
                        line.push_str(opname);
                    }
                }
                (a, r) => bail!("{}: argument '{name}' role mismatch {a:?} vs {r:?}", call.kernel),
            }
        }
        Ok(line)
    }
}

fn instance_name(op: &str, rep: Option<usize>, si: Option<usize>) -> String {
    let mut s = op.to_string();
    if let Some(r) = rep {
        s.push_str(&format!("__r{r}"));
    }
    if let Some(i) = si {
        s.push_str(&format!("__s{i}"));
    }
    s
}

/// Evaluate a call's arguments under bindings into [`crate::kernels::ArgValues`]
/// (dims/lds/scalars only; data args keep logical names).
pub fn eval_call(
    call: &Call,
    sig: &'static Signature,
    b: &Bindings,
) -> Result<crate::kernels::ArgValues> {
    use crate::kernels::ArgValue;
    let mut values = Vec::with_capacity(call.args.len());
    for (arg, (name, role)) in call.args.iter().zip(sig.args) {
        let v = match (arg, role) {
            (CallArg::Flag(c), ArgRole::Flag(_)) => ArgValue::Char(*c),
            (CallArg::Expr(e), ArgRole::Dim | ArgRole::Ld | ArgRole::Inc) => {
                ArgValue::Size(e.eval_usize(b).map_err(|e| anyhow!("{}: {e}", call.kernel))?)
            }
            (CallArg::Scalar(v), ArgRole::Scalar) => ArgValue::Num(*v),
            (CallArg::Expr(e), ArgRole::Scalar) => {
                ArgValue::Num(e.eval(b).map_err(|e| anyhow!(e))? as f64)
            }
            (CallArg::Data(d), ArgRole::Data(_)) => ArgValue::Data(d.clone()),
            (a, r) => bail!("{}: arg '{name}' role mismatch {a:?} vs {r:?}", call.kernel),
        };
        values.push(v);
    }
    Ok(crate::kernels::ArgValues { sig, values })
}

/// Test helpers shared across coordinator modules.
#[cfg(test)]
pub mod tests_support {
    use super::*;

    /// A minimal single-call dgemm experiment of size n.
    pub fn dgemm_experiment(n: i64) -> Experiment {
        let ns = n.to_string();
        Experiment {
            name: format!("dgemm{n}"),
            calls: vec![Call::new(
                "dgemm",
                vec![
                    CallArg::Flag('N'),
                    CallArg::Flag('N'),
                    CallArg::expr(&ns),
                    CallArg::expr(&ns),
                    CallArg::expr(&ns),
                    CallArg::Scalar(1.0),
                    CallArg::Data("A".into()),
                    CallArg::expr(&ns),
                    CallArg::Data("B".into()),
                    CallArg::expr(&ns),
                    CallArg::Scalar(0.0),
                    CallArg::Data("C".into()),
                    CallArg::expr(&ns),
                ],
            )
            .unwrap()],
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dgemm_call(n: &str) -> Call {
        Call::new(
            "dgemm",
            vec![
                CallArg::Flag('N'),
                CallArg::Flag('N'),
                CallArg::expr(n),
                CallArg::expr(n),
                CallArg::expr(n),
                CallArg::Scalar(1.0),
                CallArg::Data("A".into()),
                CallArg::expr(n),
                CallArg::Data("B".into()),
                CallArg::expr(n),
                CallArg::Scalar(0.0),
                CallArg::Data("C".into()),
                CallArg::expr(n),
            ],
        )
        .unwrap()
    }

    #[test]
    fn simple_experiment_unrolls() {
        let exp = Experiment {
            name: "exp1".into(),
            nreps: 3,
            calls: vec![dgemm_call("100")],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert_eq!(p.expected_records(3), 3);
        assert!(p.script.contains("dmalloc A 10000"));
        assert_eq!(p.script.matches("dgemm N N 100 100 100").count(), 3);
        assert!(p.script.trim_end().ends_with("go"));
    }

    #[test]
    fn parameter_range_one_script_per_value() {
        let exp = Experiment {
            range: Some(RangeDef::span("n", 100, 100, 300)),
            calls: vec![dgemm_call("n")],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts[0].script.contains("dgemm N N 100 100 100"));
        assert!(pts[2].script.contains("dgemm N N 300 300 300"));
        assert!(pts[2].script.contains("dmalloc A 90000"));
    }

    #[test]
    fn vary_with_rep_allocates_block_and_offsets() {
        // the paper's Experiment 3: C varies per repetition
        let mut exp = Experiment {
            nreps: 2,
            calls: vec![dgemm_call("50")],
            ..Default::default()
        };
        exp.vary.insert("C".into(), Vary { with_rep: true, ..Default::default() });
        let pts = exp.unroll().unwrap();
        let s = &pts[0].script;
        assert!(s.contains("dmalloc C__blk 5000"), "{s}");
        assert!(s.contains("doffset C__r0 C__blk 0"));
        assert!(s.contains("doffset C__r1 C__blk 2500"));
        assert!(s.contains("dgemm N N 50 50 50 1 A 50 B 50 0 C__r0 50"));
        assert!(s.contains("C__r1 50"));
    }

    #[test]
    fn sumrange_unrolls_inner_loop() {
        // blocked triangular inversion sketch: calls with nb symbol
        let exp = Experiment {
            sumrange: Some(RangeDef::new("i", vec![0, 100, 200])),
            calls: vec![Call::new(
                "dtrti2",
                vec![
                    CallArg::Flag('L'),
                    CallArg::Flag('N'),
                    CallArg::n(100),
                    CallArg::Data("A".into()),
                    CallArg::n(100),
                ],
            )
            .unwrap()],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        assert_eq!(pts[0].sum_iters, 3);
        assert_eq!(pts[0].script.matches("dtrti2").count(), 3);
    }

    #[test]
    fn omp_range_emits_groups() {
        let exp = Experiment {
            nreps: 2,
            omp: true,
            sumrange: Some(RangeDef::new("j", vec![0, 1])),
            calls: vec![dgemm_call("30")],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        let s = &pts[0].script;
        assert_eq!(s.matches("{omp").count(), 2);
        assert_eq!(s.matches("\n}\n").count(), 2);
    }

    #[test]
    fn sumrange_symbol_usable_in_args() {
        let exp = Experiment {
            sumrange: Some(RangeDef::new("nb", vec![8, 16])),
            calls: vec![dgemm_call("nb")],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        assert!(pts[0].script.contains("dgemm N N 8 8 8"));
        assert!(pts[0].script.contains("dgemm N N 16 16 16"));
        // operand sized for the max
        assert!(pts[0].script.contains("dmalloc A 256"));
    }

    #[test]
    fn datagen_emitted() {
        let mut exp = Experiment {
            calls: vec![Call::new(
                "dpotrf",
                vec![CallArg::Flag('L'), CallArg::n(20), CallArg::Data("M".into()), CallArg::n(20)],
            )
            .unwrap()],
            ..Default::default()
        };
        exp.datagen.insert("M".into(), DataGen::Spd(Expr::Const(20)));
        let pts = exp.unroll().unwrap();
        assert!(pts[0].script.contains("dporand M 20"));
    }

    #[test]
    fn thread_expression_follows_range() {
        let exp = Experiment {
            range: Some(RangeDef::span("t", 1, 1, 4)),
            nthreads: Expr::sym("t"),
            calls: vec![dgemm_call("40")],
            ..Default::default()
        };
        let pts = exp.unroll().unwrap();
        assert_eq!(pts.iter().map(|p| p.nthreads).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert!(pts[3].script.contains("set_threads 4"));
    }

    #[test]
    fn errors_on_empty_calls_or_reps() {
        assert!(Experiment::default().unroll().is_err());
        let exp = Experiment { nreps: 0, calls: vec![dgemm_call("10")], ..Default::default() };
        assert!(exp.unroll().is_err());
    }

    #[test]
    fn call_arity_validated() {
        assert!(Call::new("dgemm", vec![CallArg::Flag('N')]).is_err());
        assert!(Call::new("nosuch", vec![]).is_err());
    }
}
