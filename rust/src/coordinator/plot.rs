//! Plotting (§3.2.4): line and bar charts from report series, rendered
//! as terminal ASCII and as standalone SVG files — the substitution for
//! the paper's matplotlib module and Viewer GUI (DESIGN.md §Subst 6).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

/// A figure: several series plus labels.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub series: Vec<Series>,
    /// Render bars (per-x grouped) instead of lines.
    pub bars: bool,
    /// Labeled vertical markers (e.g. series crossover points).
    pub vlines: Vec<(f64, String)>,
}

impl Figure {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Figure {
        Figure {
            title: title.into(),
            xlabel: xlabel.into(),
            ylabel: ylabel.into(),
            series: vec![],
            bars: false,
            vlines: vec![],
        }
    }

    pub fn add_series(&mut self, label: &str, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push(Series { label: label.into(), points });
        self
    }

    /// Mark a vertical line at `x` (rendered dashed in SVG, listed in
    /// the ASCII footer) — used for differential-report crossovers.
    pub fn add_vline(&mut self, x: f64, label: &str) -> &mut Self {
        self.vlines.push((x, label.into()));
        self
    }

    pub fn add_iseries(&mut self, label: &str, points: &[(i64, f64)]) -> &mut Self {
        self.add_series(label, points.iter().map(|&(x, y)| (x as f64, y)).collect())
    }

    fn bounds(&self) -> (f64, f64, f64, f64) {
        let (mut x0, mut x1, mut y0, mut y1) =
            (f64::INFINITY, f64::NEG_INFINITY, 0.0f64, f64::NEG_INFINITY);
        for s in &self.series {
            for &(x, y) in &s.points {
                if x.is_finite() {
                    x0 = x0.min(x);
                    x1 = x1.max(x);
                }
                if y.is_finite() {
                    y0 = y0.min(y);
                    y1 = y1.max(y);
                }
            }
        }
        if !x0.is_finite() {
            (x0, x1) = (0.0, 1.0);
        }
        if x1 <= x0 {
            x1 = x0 + 1.0;
        }
        if !y1.is_finite() || y1 <= y0 {
            y1 = y0 + 1.0;
        }
        (x0, x1, y0, y1)
    }

    /// Render an ASCII chart (width×height characters of plot area).
    pub fn to_ascii(&self, width: usize, height: usize) -> String {
        const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
        let (x0, x1, y0, y1) = self.bounds();
        let mut grid = vec![vec![' '; width]; height];
        for (si, s) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            // interpolate lines between consecutive points
            let proj = |x: f64, y: f64| -> (usize, usize) {
                let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
                (cx.min(width - 1), height - 1 - cy.min(height - 1))
            };
            if self.bars {
                for &(x, y) in &s.points {
                    if !(x.is_finite() && y.is_finite()) {
                        continue;
                    }
                    let (cx, cy) = proj(x, y);
                    let cx = (cx + si).min(width - 1); // offset grouped bars
                    for row in grid.iter_mut().skip(cy) {
                        row[cx] = mark;
                    }
                }
            } else {
                let mut prev: Option<(usize, usize)> = None;
                for &(x, y) in &s.points {
                    if !(x.is_finite() && y.is_finite()) {
                        prev = None;
                        continue;
                    }
                    let (cx, cy) = proj(x, y);
                    if let Some((px, py)) = prev {
                        // simple line interpolation
                        let steps = (cx.abs_diff(px)).max(cy.abs_diff(py)).max(1);
                        for t in 0..=steps {
                            let ix = px as f64 + (cx as f64 - px as f64) * t as f64 / steps as f64;
                            let iy = py as f64 + (cy as f64 - py as f64) * t as f64 / steps as f64;
                            grid[iy.round() as usize][ix.round() as usize] = mark;
                        }
                    } else {
                        grid[cy][cx] = mark;
                    }
                    prev = Some((cx, cy));
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{} ({})\n", self.title, self.ylabel));
        for (i, row) in grid.iter().enumerate() {
            let yv = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
            out.push_str(&format!("{yv:>10.3} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
        out.push_str(&format!(
            "{:>10}  {:<width$}\n",
            "",
            format!("{} ∈ [{x0:.0}, {x1:.0}]", self.xlabel),
            width = width
        ));
        for (si, s) in self.series.iter().enumerate() {
            out.push_str(&format!(
                "{:>10}  {} {}\n",
                "",
                ['*', 'o', '+', 'x', '#', '@', '%', '&'][si % 8],
                s.label
            ));
        }
        for (x, label) in &self.vlines {
            out.push_str(&format!("{:>10}  | {} at {} = {}\n", "", label, self.xlabel, x));
        }
        out
    }

    /// Render as a standalone SVG document.
    pub fn to_svg(&self, width: usize, height: usize) -> String {
        const COLORS: &[&str] =
            &["#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b"];
        let (x0, x1, y0, y1) = self.bounds();
        let (ml, mr, mt, mb) = (70.0, 20.0, 35.0, 50.0);
        let (w, h) = (width as f64, height as f64);
        let (pw, ph) = (w - ml - mr, h - mt - mb);
        let px = |x: f64| ml + (x - x0) / (x1 - x0) * pw;
        let py = |y: f64| mt + ph - (y - y0) / (y1 - y0) * ph;
        let mut s = format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" viewBox="0 0 {width} {height}">"#
        );
        s.push_str(&format!(
            r#"<rect width="{width}" height="{height}" fill="white"/><text x="{}" y="20" text-anchor="middle" font-size="14" font-family="sans-serif">{}</text>"#,
            w / 2.0,
            xml_escape(&self.title)
        ));
        // axes
        s.push_str(&format!(
            r#"<line x1="{ml}" y1="{}" x2="{}" y2="{}" stroke="black"/><line x1="{ml}" y1="{mt}" x2="{ml}" y2="{}" stroke="black"/>"#,
            mt + ph,
            ml + pw,
            mt + ph,
            mt + ph
        ));
        // y ticks
        for t in 0..=4 {
            let yv = y0 + (y1 - y0) * t as f64 / 4.0;
            let yy = py(yv);
            s.push_str(&format!(
                r#"<line x1="{}" y1="{yy}" x2="{ml}" y2="{yy}" stroke="black"/><text x="{}" y="{}" text-anchor="end" font-size="10" font-family="sans-serif">{}</text>"#,
                ml - 4.0,
                ml - 6.0,
                yy + 3.0,
                format_tick(yv)
            ));
        }
        // x ticks
        for t in 0..=4 {
            let xv = x0 + (x1 - x0) * t as f64 / 4.0;
            let xx = px(xv);
            s.push_str(&format!(
                r#"<line x1="{xx}" y1="{}" x2="{xx}" y2="{}" stroke="black"/><text x="{xx}" y="{}" text-anchor="middle" font-size="10" font-family="sans-serif">{}</text>"#,
                mt + ph,
                mt + ph + 4.0,
                mt + ph + 16.0,
                format_tick(xv)
            ));
        }
        // axis labels
        s.push_str(&format!(
            r#"<text x="{}" y="{}" text-anchor="middle" font-size="11" font-family="sans-serif">{}</text>"#,
            ml + pw / 2.0,
            h - 12.0,
            xml_escape(&self.xlabel)
        ));
        s.push_str(&format!(
            r#"<text x="14" y="{}" text-anchor="middle" font-size="11" font-family="sans-serif" transform="rotate(-90 14 {})">{}</text>"#,
            mt + ph / 2.0,
            mt + ph / 2.0,
            xml_escape(&self.ylabel)
        ));
        // vertical markers (crossovers) behind the data series
        for (x, label) in &self.vlines {
            if !x.is_finite() || *x < x0 || *x > x1 {
                continue;
            }
            let xx = px(*x);
            s.push_str(&format!(
                r##"<line x1="{xx}" y1="{mt}" x2="{xx}" y2="{}" stroke="#888" stroke-dasharray="4 3"/><text x="{}" y="{}" font-size="9" font-family="sans-serif" fill="#555">{}</text>"##,
                mt + ph,
                xx + 3.0,
                mt + 10.0,
                xml_escape(label)
            ));
        }
        let nseries = self.series.len().max(1) as f64;
        for (si, ser) in self.series.iter().enumerate() {
            let color = COLORS[si % COLORS.len()];
            if self.bars {
                let bw = (pw / (ser.points.len().max(1) as f64) / (nseries + 1.0)).max(2.0);
                for &(x, y) in &ser.points {
                    let xx = px(x) + si as f64 * bw;
                    let yy = py(y);
                    s.push_str(&format!(
                        r#"<rect x="{}" y="{yy}" width="{bw}" height="{}" fill="{color}"/>"#,
                        xx - bw * nseries / 2.0,
                        (mt + ph - yy).max(0.0)
                    ));
                }
            } else {
                let pts: Vec<String> = ser
                    .points
                    .iter()
                    .filter(|(x, y)| x.is_finite() && y.is_finite())
                    .map(|&(x, y)| format!("{:.1},{:.1}", px(x), py(y)))
                    .collect();
                s.push_str(&format!(
                    r#"<polyline points="{}" fill="none" stroke="{color}" stroke-width="1.5"/>"#,
                    pts.join(" ")
                ));
                for p in &pts {
                    let (cx, cy) = p.split_once(',').unwrap();
                    s.push_str(&format!(r#"<circle cx="{cx}" cy="{cy}" r="2.5" fill="{color}"/>"#));
                }
            }
            // legend
            let ly = mt + 14.0 * si as f64;
            s.push_str(&format!(
                r#"<rect x="{}" y="{}" width="10" height="10" fill="{color}"/><text x="{}" y="{}" font-size="10" font-family="sans-serif">{}</text>"#,
                ml + pw - 120.0,
                ly,
                ml + pw - 106.0,
                ly + 9.0,
                xml_escape(&ser.label)
            ));
        }
        s.push_str("</svg>");
        s
    }
}

fn format_tick(v: f64) -> String {
    if v.abs() >= 1e6 || (v.abs() < 1e-2 && v != 0.0) {
        format!("{v:.1e}")
    } else if v.fract() == 0.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Figure {
        let mut f = Figure::new("perf", "n", "Gflops/s");
        f.add_series("rustblocked", vec![(100.0, 1.0), (200.0, 2.0), (300.0, 2.5)]);
        f.add_series("rustref", vec![(100.0, 0.5), (200.0, 0.6), (300.0, 0.6)]);
        f
    }

    #[test]
    fn ascii_renders_marks_and_legend() {
        let a = fig().to_ascii(60, 16);
        assert!(a.contains('*'));
        assert!(a.contains('o'));
        assert!(a.contains("rustblocked"));
        assert!(a.lines().count() > 16);
    }

    #[test]
    fn svg_is_wellformed_ish() {
        let s = fig().to_svg(640, 400);
        assert!(s.starts_with("<svg"));
        assert!(s.ends_with("</svg>"));
        assert!(s.contains("polyline"));
        assert_eq!(s.matches("<svg").count(), 1);
    }

    #[test]
    fn bars_mode() {
        let mut f = fig();
        f.bars = true;
        let s = f.to_svg(640, 400);
        assert!(s.contains("<rect"));
        let a = f.to_ascii(40, 10);
        assert!(a.contains('*'));
    }

    #[test]
    fn degenerate_single_point() {
        let mut f = Figure::new("t", "x", "y");
        f.add_series("s", vec![(5.0, 3.0)]);
        let a = f.to_ascii(20, 5);
        assert!(a.contains('*'));
        let _ = f.to_svg(200, 100);
    }

    #[test]
    fn vlines_render_in_both_outputs() {
        let mut f = fig();
        f.add_vline(200.0, "crossover rustref→rustblocked");
        let s = f.to_svg(640, 400);
        assert!(s.contains("stroke-dasharray"));
        assert!(s.contains("crossover"));
        let a = f.to_ascii(40, 10);
        assert!(a.contains("crossover"));
        // out-of-range markers are skipped in SVG, listed in ASCII
        let mut g = fig();
        g.add_vline(9999.0, "far");
        assert!(!g.to_svg(640, 400).contains("stroke-dasharray"));
    }

    #[test]
    fn escape_in_labels() {
        let mut f = Figure::new("a<b", "x&y", "z");
        f.add_series("s<&>", vec![(0.0, 1.0)]);
        let s = f.to_svg(100, 100);
        assert!(s.contains("a&lt;b"));
        assert!(!s.contains("s<&>"));
    }
}
