//! Job leases: the spooler's multi-host claim protocol.
//!
//! The original spooler guessed whether a claimed job was abandoned by
//! looking at the claim file's mtime — a heuristic that misfires under
//! clock skew and NFS attribute caching, exactly the shared-filesystem
//! setting remote workers live in. This module replaces the guess with
//! an explicit contract:
//!
//! * **Lease.** A claim is a JSON lease
//!   `{job_id, worker_id, host, epoch, expires_unix}` stored in
//!   `<spool>/leases/`, written atomically (temp + rename). Only the
//!   worker that won the queue→running rename writes it.
//! * **Heartbeat.** The holder renews the lease (extends
//!   `expires_unix`) while the job runs. A worker that stops renewing —
//!   crashed, paused, partitioned — lets the lease expire.
//! * **Expiry reclaim.** Anyone may move a job whose lease has expired
//!   back into the queue ([`crate::coordinator::Spooler::recover_stale`]).
//!   The lease file stays behind: it carries the epoch.
//! * **Epoch fencing.** Every acquisition bumps the lease's `epoch`
//!   (read old epoch, write `epoch + 1`). A publish is only valid while
//!   the on-disk lease still names the publisher's `(worker_id, epoch)`
//!   *and* is unexpired — so a zombie worker (one that kept running
//!   past its expiry) finds either a bumped epoch or an expired lease
//!   and its late publish is rejected ([`FenceReason`]).
//!
//! Timestamps are absolute Unix seconds (fractional, so sub-second
//! TTLs work), which makes the protocol independent of file mtimes —
//! the usual lease assumption of loosely synchronized clocks replaces
//! the unfounded assumption of consistent NFS mtimes. Pick the TTL a
//! comfortable multiple of both the heartbeat interval and the
//! worst-case clock skew.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One job lease: who holds which job, under which fencing epoch,
/// until when.
#[derive(Debug, Clone, PartialEq)]
pub struct Lease {
    /// The claimed job.
    pub job_id: String,
    /// Holder identity, unique per worker thread
    /// ([`crate::util::hostid::new_worker_id`]).
    pub worker_id: String,
    /// Hostname of the holder (provenance; also the `spool status`
    /// grouping key).
    pub host: String,
    /// Fencing epoch: bumped on every acquisition of this job. A
    /// publish carrying a stale epoch is rejected.
    pub epoch: u64,
    /// Absolute expiry, fractional Unix seconds.
    pub expires_unix: f64,
}

impl Lease {
    /// Whether the lease is expired at `now` (Unix seconds).
    pub fn expired_at(&self, now: f64) -> bool {
        now >= self.expires_unix
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job_id", self.job_id.as_str())
            .set("worker_id", self.worker_id.as_str())
            .set("host", self.host.as_str())
            .set("epoch", self.epoch)
            .set("expires_unix", self.expires_unix);
        j
    }

    /// Parse a lease; corrupt or incomplete JSON yields `None` (a
    /// missing lease, never an error — the claim then counts as
    /// legacy).
    pub fn from_json(j: &Json) -> Option<Lease> {
        Some(Lease {
            job_id: j.get("job_id").as_str()?.to_string(),
            worker_id: j.get("worker_id").as_str()?.to_string(),
            host: j.get("host").as_str()?.to_string(),
            epoch: j.get("epoch").as_u64()?,
            expires_unix: j.get("expires_unix").as_f64()?,
        })
    }
}

/// Current time as fractional Unix seconds (the lease clock).
pub fn now_unix() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Why a publish (or renewal) was refused by the fence.
#[derive(Debug, Clone, PartialEq)]
pub enum FenceReason {
    /// The lease still names the publisher but has expired: the job is
    /// up for reclaim, and a reclaimer may already be re-running it.
    Expired { expires_unix: f64 },
    /// The job was reclaimed and re-acquired: the on-disk lease carries
    /// a newer epoch (and usually another worker). The publisher is a
    /// zombie.
    Superseded { current_epoch: u64, current_worker: String },
    /// No lease exists for the job any more — typically another worker
    /// already published it (publishing releases the lease).
    LeaseGone,
}

/// Outcome of a fenced publish attempt.
#[derive(Debug, Clone, PartialEq)]
pub enum PublishOutcome {
    /// The report landed in `<spool>/done/`.
    Published,
    /// The publish was rejected by the lease fence; nothing was
    /// written.
    Fenced(FenceReason),
}

impl PublishOutcome {
    pub fn published(&self) -> bool {
        matches!(self, PublishOutcome::Published)
    }
}

// ------------------------------------------------------- lease store

fn leases_dir(spool: &Path) -> PathBuf {
    spool.join("leases")
}

pub(crate) fn lease_path(spool: &Path, job_id: &str) -> PathBuf {
    leases_dir(spool).join(format!("{job_id}.json"))
}

/// Read the current lease of a job; `None` if absent or unreadable.
pub fn read(spool: &Path, job_id: &str) -> Option<Lease> {
    let text = std::fs::read_to_string(lease_path(spool, job_id)).ok()?;
    Lease::from_json(&Json::parse(&text).ok()?)
}

/// Atomically write (create or replace) a job's lease.
pub fn write(spool: &Path, lease: &Lease) -> Result<()> {
    let path = lease_path(spool, &lease.job_id);
    let tmp = crate::coordinator::submit::unique_tmp(&path);
    std::fs::write(&tmp, lease.to_json().to_string_pretty())
        .with_context(|| format!("writing lease for {}", lease.job_id))?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

/// Remove a job's lease (publish-time release). A missing lease is
/// fine — a racing publish already released it.
pub fn remove(spool: &Path, job_id: &str) -> Result<()> {
    match std::fs::remove_file(lease_path(spool, job_id)) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(e.into()),
    }
}

/// A held advisory `flock(2)` on a sidecar lock file (a job's lease
/// lock, a campaign tag lock, a host lease-cap lock). Dropping the
/// guard releases the lock — `flock(2)` locks die with the last
/// descriptor on their open file description.
#[derive(Debug)]
pub struct JobLock {
    _file: Option<std::fs::File>,
}

/// Take an advisory `flock(2)` on `path` — exclusive by default,
/// shared (many concurrent readers) with `shared`. The lock file is a
/// sidecar, never the data file it guards: data files are replaced by
/// atomic renames, which would leave later lockers holding a lock on a
/// dead inode. Used for per-job lease locks, per-campaign tag locks
/// and the per-host lease-cap lock.
#[cfg(unix)]
pub(crate) fn flock_path(path: &Path, shared: bool) -> Result<JobLock> {
    use std::os::unix::io::AsRawFd;
    extern "C" {
        fn flock(fd: i32, operation: i32) -> i32;
    }
    const LOCK_SH: i32 = 1;
    const LOCK_EX: i32 = 2;
    const EINTR: i32 = 4;
    let file = std::fs::OpenOptions::new()
        .create(true)
        .truncate(false)
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("opening lock {}", path.display()))?;
    let op = if shared { LOCK_SH } else { LOCK_EX };
    loop {
        if unsafe { flock(file.as_raw_fd(), op) } == 0 {
            return Ok(JobLock { _file: Some(file) });
        }
        let err = std::io::Error::last_os_error();
        if err.raw_os_error() != Some(EINTR) {
            return Err(err).with_context(|| format!("locking {}", path.display()));
        }
    }
}

/// Non-unix fallback: no advisory locking — concurrent writers keep
/// the historical read-modify-write race.
#[cfg(not(unix))]
pub(crate) fn flock_path(_path: &Path, _shared: bool) -> Result<JobLock> {
    Ok(JobLock { _file: None })
}

/// Serialize lease writes for one job across threads *and* processes
/// with an advisory `flock(2)` on a sidecar lock file. Every
/// read-verify-write of a lease (claim acquisition, heartbeat renewal,
/// stale-claim reclaim) runs under this lock, so the on-disk epoch can
/// never regress: a stale renewal is forced to re-read *after* any
/// concurrent acquisition's epoch bump and fences itself out. The
/// `.lock` sidecar is invisible to every lease scan (they all filter
/// on the `.json` extension).
pub(crate) fn lock_job(spool: &Path, job_id: &str) -> Result<JobLock> {
    flock_path(&leases_dir(spool).join(format!("{job_id}.lock")), false)
}

/// Count the live (unexpired) leases currently held by `host` — the
/// observable quantity the `--max-leases` backpressure caps. Corrupt
/// lease files count as missing, exactly as [`read`] treats them.
pub fn live_leases_for_host(spool: &Path, host: &str) -> Result<usize> {
    let now = now_unix();
    let mut live = 0;
    for entry in std::fs::read_dir(leases_dir(spool))?.filter_map(|e| e.ok()) {
        let path = entry.path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        let lease = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| Lease::from_json(&j));
        if lease.is_some_and(|l| l.host == host && !l.expired_at(now)) {
            live += 1;
        }
    }
    Ok(live)
}

// ------------------------------------------------------ spool status

/// One currently leased (or legacy-claimed) job, for `spool status`.
#[derive(Debug, Clone, PartialEq)]
pub struct LeasedJob {
    pub job_id: String,
    /// `None` marks a legacy claim: a file in `running/` without a
    /// lease, recoverable only by the mtime heuristic.
    pub lease: Option<Lease>,
}

/// A snapshot of a spool directory: queued/leased/done totals plus the
/// per-host breakdown behind `elaps spool status`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpoolStatus {
    pub queued: usize,
    pub leased: Vec<LeasedJob>,
    pub done: usize,
    /// Leased jobs per host; legacy claims count under `"(legacy)"`.
    pub leased_by_host: BTreeMap<String, usize>,
    /// Finished reports per serving host, read from the stamp sidecars
    /// ([`crate::coordinator::campaign::Stamp`]) — never from the
    /// report bodies, so the grouping is O(#jobs) regardless of report
    /// size. Reports without a readable stamp (pre-stamp workers, or a
    /// corrupt sidecar) count under `"(unknown)"`.
    pub done_by_host: BTreeMap<String, usize>,
    /// Done reports whose stamp records an error outcome.
    pub done_errors: usize,
}

impl SpoolStatus {
    /// Multi-line human-readable rendering (the `spool status` output).
    pub fn render(&self) -> String {
        let now = now_unix();
        let mut s = String::new();
        s += &format!("  queued: {}\n", self.queued);
        s += &format!("  leased: {}\n", self.leased.len());
        for job in &self.leased {
            match &job.lease {
                Some(l) => {
                    let left = l.expires_unix - now;
                    let state = if left <= 0.0 {
                        format!("expired {:.1}s ago", -left)
                    } else {
                        format!("expires in {left:.1}s")
                    };
                    s += &format!(
                        "    {}  worker {} (host {}, epoch {}, {state})\n",
                        job.job_id, l.worker_id, l.host, l.epoch
                    );
                }
                None => {
                    s += &format!("    {}  (legacy claim, no lease)\n", job.job_id);
                }
            }
        }
        s += &format!("  done: {}\n", self.done);
        if self.done_errors > 0 {
            s += &format!("  done with errors: {}\n", self.done_errors);
        }
        if !self.done_by_host.is_empty() {
            s += "  done per host:\n";
            for (host, n) in &self.done_by_host {
                s += &format!("    {host:<16} {n}\n");
            }
        }
        s
    }

    /// Machine-readable twin of [`SpoolStatus::render`] (the
    /// `spool status --json` output): counts as numbers, per-host
    /// breakdowns as object maps, and each leased job with its full
    /// lease — `null` for a legacy claim.
    pub fn to_json(&self) -> Json {
        let leased: Vec<Json> = self
            .leased
            .iter()
            .map(|job| {
                let lease_json = match &job.lease {
                    Some(l) => l.to_json(),
                    None => Json::Null,
                };
                let mut lj = Json::obj();
                lj.set("job_id", job.job_id.as_str()).set("lease", lease_json);
                lj
            })
            .collect();
        let mut j = Json::obj();
        j.set("queued", self.queued)
            .set("done", self.done)
            .set("done_errors", self.done_errors)
            .set("leased", Json::Arr(leased))
            .set("leased_by_host", count_map(&self.leased_by_host))
            .set("done_by_host", count_map(&self.done_by_host));
        j
    }
}

/// A `{key: count}` JSON object from a counting map.
fn count_map(counts: &BTreeMap<String, usize>) -> Json {
    let mut j = Json::obj();
    for (k, n) in counts {
        j.set(k.as_str(), *n);
    }
    j
}

/// Count the `.json` files under `<spool>/<sub>`.
fn count_json(spool: &Path, sub: &str) -> Result<usize> {
    Ok(std::fs::read_dir(spool.join(sub))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
        .count())
}

/// The queue/running half of a [`SpoolStatus`]: queued count plus the
/// leased jobs with their per-host breakdown. Shared by the
/// directory-scan status path below and the incremental ledger path
/// ([`crate::coordinator::ledger::spool_status_ledger`]) — these
/// directories hold only in-flight work, so both paths scan them.
pub(crate) fn status_queue_and_running(dir: &Path) -> Result<SpoolStatus> {
    if !dir.join("queue").is_dir() {
        return Err(anyhow!("no spool directory at {}", dir.display()));
    }
    let mut st = SpoolStatus { queued: count_json(dir, "queue")?, ..Default::default() };
    // leased: every claim in running/, with its lease where one exists
    let mut leased = Vec::new();
    for entry in std::fs::read_dir(dir.join("running"))?.filter_map(|e| e.ok()) {
        let path = entry.path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        let job_id = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        let lease = read(dir, &job_id);
        let host = lease
            .as_ref()
            .map(|l| l.host.clone())
            .unwrap_or_else(|| "(legacy)".to_string());
        *st.leased_by_host.entry(host).or_insert(0) += 1;
        leased.push(LeasedJob { job_id, lease });
    }
    leased.sort_by(|a, b| a.job_id.cmp(&b.job_id));
    st.leased = leased;
    Ok(st)
}

/// Gather a [`SpoolStatus`] snapshot for the spool at `dir`.
pub fn spool_status(dir: &Path) -> Result<SpoolStatus> {
    let mut st = status_queue_and_running(dir)?;
    // done: group by the stamp sidecar the publisher wrote — report
    // bodies are deliberately never opened (a corrupt or huge report
    // cannot slow or break the status view; the sidecars keep this
    // pass O(#jobs))
    let scan = crate::coordinator::campaign::read_stamps(dir);
    for entry in std::fs::read_dir(dir.join("done"))?.filter_map(|e| e.ok()) {
        let Some(job_id) = entry
            .file_name()
            .to_str()
            .and_then(|n| n.strip_suffix(".report.json"))
            .map(String::from)
        else {
            continue;
        };
        st.done += 1;
        let host = match scan.stamps.get(&job_id) {
            Some(stamp) => {
                if stamp.outcome == crate::coordinator::campaign::StampOutcome::Error {
                    st.done_errors += 1;
                }
                stamp.host.clone()
            }
            None => "(unknown)".to_string(),
        };
        *st.done_by_host.entry(host).or_insert(0) += 1;
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("elaps_lease_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for sub in ["queue", "running", "done", "leases"] {
            std::fs::create_dir_all(dir.join(sub)).unwrap();
        }
        dir
    }

    fn lease(job: &str, epoch: u64, expires_unix: f64) -> Lease {
        Lease {
            job_id: job.to_string(),
            worker_id: format!("hostA#1-{epoch}"),
            host: "hostA".to_string(),
            epoch,
            expires_unix,
        }
    }

    #[test]
    fn lease_json_roundtrip() {
        let l = lease("job-1", 3, 1_700_000_000.25);
        let j = l.to_json();
        let l2 = Lease::from_json(&j).unwrap();
        assert_eq!(l, l2);
        // fractional expiry survives (sub-second TTLs)
        assert!((l2.expires_unix - 1_700_000_000.25).abs() < 1e-6);
        // incomplete JSON is a missing lease, not a panic
        assert!(Lease::from_json(&Json::parse(r#"{"job_id":"x"}"#).unwrap()).is_none());
        assert!(Lease::from_json(&Json::parse("[]").unwrap()).is_none());
    }

    #[test]
    fn expiry_is_absolute_time() {
        let l = lease("j", 1, 100.0);
        assert!(!l.expired_at(99.9));
        assert!(l.expired_at(100.0));
        assert!(l.expired_at(200.0));
    }

    #[test]
    fn store_roundtrip_and_release() {
        let dir = tmpdir("store");
        assert!(read(&dir, "j1").is_none());
        let l = lease("j1", 1, now_unix() + 60.0);
        write(&dir, &l).unwrap();
        assert_eq!(read(&dir, "j1").unwrap().epoch, 1);
        // replace bumps in place (atomic rename)
        let l2 = lease("j1", 2, now_unix() + 60.0);
        write(&dir, &l2).unwrap();
        assert_eq!(read(&dir, "j1").unwrap().epoch, 2);
        remove(&dir, "j1").unwrap();
        assert!(read(&dir, "j1").is_none());
        // double release is fine
        remove(&dir, "j1").unwrap();
        // corrupt lease file reads as missing
        std::fs::write(lease_path(&dir, "bad"), "{not json").unwrap();
        assert!(read(&dir, "bad").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn status_counts_and_groups_by_host() {
        let dir = tmpdir("status");
        std::fs::write(dir.join("queue").join("q1.json"), "{}").unwrap();
        std::fs::write(dir.join("running").join("r1.json"), "{}").unwrap();
        std::fs::write(dir.join("running").join("r2.json"), "{}").unwrap();
        write(&dir, &lease("r1", 2, now_unix() + 30.0)).unwrap();
        // r2 has no lease: a legacy claim
        //
        // d1 is a *deliberately corrupt* report body with a valid
        // stamp sidecar: status must group it by the stamp's host,
        // proving it never opens report bodies. d2 has no stamp (a
        // pre-stamp worker published it) and counts as unknown.
        std::fs::write(dir.join("done").join("d1.report.json"), "{CORRUPT not json")
            .unwrap();
        crate::coordinator::campaign::write_stamp(
            &dir,
            &crate::coordinator::campaign::Stamp {
                job_id: "d1".into(),
                host: "hostB".into(),
                worker: "hostB#9-0".into(),
                epoch: 1,
                outcome: crate::coordinator::campaign::StampOutcome::Error,
            },
        )
        .unwrap();
        std::fs::write(dir.join("done").join("d2.report.json"), "{}").unwrap();
        let st = spool_status(&dir).unwrap();
        assert_eq!(st.queued, 1);
        assert_eq!(st.leased.len(), 2);
        assert_eq!(st.done, 2);
        assert_eq!(st.done_errors, 1);
        assert_eq!(st.leased_by_host.get("hostA"), Some(&1));
        assert_eq!(st.leased_by_host.get("(legacy)"), Some(&1));
        assert_eq!(st.done_by_host.get("hostB"), Some(&1));
        assert_eq!(st.done_by_host.get("(unknown)"), Some(&1));
        let text = st.render();
        assert!(text.contains("queued: 1"), "{text}");
        assert!(text.contains("leased: 2"), "{text}");
        assert!(text.contains("epoch 2"), "{text}");
        assert!(text.contains("legacy claim"), "{text}");
        assert!(text.contains("hostB"), "{text}");
        assert!(text.contains("done with errors: 1"), "{text}");
        // the JSON twin mirrors every count; a legacy lease is null
        let j = st.to_json();
        assert_eq!(j.get("queued").as_u64(), Some(1));
        assert_eq!(j.get("done").as_u64(), Some(2));
        assert_eq!(j.get("done_errors").as_u64(), Some(1));
        let leased = j.get("leased").as_arr().unwrap();
        assert_eq!(leased.len(), 2);
        assert_eq!(leased[0].get("job_id").as_str(), Some("r1"));
        assert_eq!(leased[0].get("lease").get("epoch").as_u64(), Some(2));
        assert!(leased[1].get("lease").is_null(), "legacy claim must be null");
        assert_eq!(j.get("leased_by_host").get("(legacy)").as_u64(), Some(1));
        assert_eq!(j.get("done_by_host").get("hostB").as_u64(), Some(1));
        assert!(Json::parse(&j.to_string_pretty()).is_ok());
        // a directory that is not a spool is an error
        assert!(spool_status(&dir.join("nope")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_lock_serializes_concurrent_lease_writers() {
        let dir = tmpdir("lock");
        // four threads each run a read-bump-write of the same job's
        // lease under the lock; without serialization two writers
        // could read the same epoch and lose an update
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let _guard = lock_job(&dir, "j").unwrap();
                    let epoch = read(&dir, "j").map(|l| l.epoch).unwrap_or(0) + 1;
                    // widen the race window: a lost update would show
                    // up as a duplicate epoch
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    write(&dir, &lease("j", epoch, now_unix() + 60.0)).unwrap();
                });
            }
        });
        assert_eq!(read(&dir, "j").unwrap().epoch, 4, "no lost lease update");
        // the sidecar lock file is invisible to the lease scans
        assert!(leases_dir(&dir).join("j.lock").exists());
        assert_eq!(live_leases_for_host(&dir, "hostA").unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_lease_count_ignores_expired_and_foreign_hosts() {
        let dir = tmpdir("live");
        let now = now_unix();
        write(&dir, &lease("a", 1, now + 60.0)).unwrap();
        write(&dir, &lease("b", 1, now + 60.0)).unwrap();
        write(&dir, &lease("c", 1, now - 1.0)).unwrap(); // expired
        let mut foreign = lease("d", 1, now + 60.0);
        foreign.host = "hostZ".into();
        write(&dir, &foreign).unwrap();
        std::fs::write(lease_path(&dir, "junk"), "{not json").unwrap();
        assert_eq!(live_leases_for_host(&dir, "hostA").unwrap(), 2);
        assert_eq!(live_leases_for_host(&dir, "hostZ").unwrap(), 1);
        assert_eq!(live_leases_for_host(&dir, "nobody").unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
