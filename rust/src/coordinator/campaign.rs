//! Campaigns: the asynchronous client side of the batch spooler
//! (§3.2.2 — experiments are composed on a laptop and submitted to
//! "the whole spectrum of architectures" via batch jobs).
//!
//! Three pieces live here:
//!
//! * **Campaign manifests** ([`CampaignManifest`]): a JSON file naming
//!   a campaign tag plus the experiments it comprises (by path or
//!   inline), the input of `elaps submit`.
//! * **Campaign records**: `<spool>/campaigns/<tag>.json` maps a tag
//!   to the job ids submitted under it, so `elaps wait --campaign` and
//!   `elaps fetch --campaign` can address a whole campaign without the
//!   client remembering individual ids.
//! * **Stamp sidecars** ([`Stamp`]): one small JSON per *done* job
//!   (`<spool>/stamps/<job>.stamp.json`) recording `{job_id, host,
//!   worker, epoch, outcome}`, written atomically at publish time.
//!   Campaign status and `elaps spool status` read stamps instead of
//!   parsing report bodies, making both O(#jobs) instead of
//!   O(report bytes) — a multi-thousand-job spool on NFS is summarized
//!   with one readdir and #jobs tiny reads.
//!
//! Malformed or truncated stamps are never an error: a stamp exists
//! purely as an index over the (atomically published) reports, so a
//! corrupt one degrades the affected job to "(unknown)" provenance
//! with a warning, and the report itself stays untouched.

use super::experiment::Experiment;
use super::io;
use super::submit::{unique_tmp, Spooler};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// ------------------------------------------------------------- stamps

/// How a published job ended: a real report, or an error report (the
/// worker publishes a job's failure as a report too, so poison jobs
/// cannot crash-loop the queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StampOutcome {
    Ok,
    Error,
}

impl StampOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            StampOutcome::Ok => "ok",
            StampOutcome::Error => "error",
        }
    }

    /// Inverse of [`StampOutcome::as_str`] (named like
    /// [`crate::coordinator::Stat::by_name`] — an inherent `from_str`
    /// would shadow the `FromStr` convention).
    pub fn by_name(s: &str) -> Option<StampOutcome> {
        match s {
            "ok" => Some(StampOutcome::Ok),
            "error" => Some(StampOutcome::Error),
            _ => None,
        }
    }
}

/// The per-job publish stamp: which host/worker produced the done
/// report, under which lease epoch, and whether the job succeeded.
/// Everything `spool status` and campaign-level `wait` need, without
/// opening the report body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stamp {
    pub job_id: String,
    pub host: String,
    pub worker: String,
    pub epoch: u64,
    pub outcome: StampOutcome,
}

impl Stamp {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job_id", self.job_id.as_str())
            .set("host", self.host.as_str())
            .set("worker", self.worker.as_str())
            .set("epoch", self.epoch)
            .set("outcome", self.outcome.as_str());
        j
    }

    /// Parse a stamp; incomplete or mistyped JSON yields `None`, never
    /// a panic — readers skip it with a warning.
    pub fn from_json(j: &Json) -> Option<Stamp> {
        Some(Stamp {
            job_id: j.get("job_id").as_str()?.to_string(),
            host: j.get("host").as_str()?.to_string(),
            worker: j.get("worker").as_str()?.to_string(),
            epoch: j.get("epoch").as_u64()?,
            outcome: StampOutcome::by_name(j.get("outcome").as_str()?)?,
        })
    }
}

fn stamps_dir(spool: &Path) -> PathBuf {
    spool.join("stamps")
}

pub fn stamp_path(spool: &Path, job_id: &str) -> PathBuf {
    stamps_dir(spool).join(format!("{job_id}.stamp.json"))
}

/// Atomically write (create or replace) a job's publish stamp. A
/// republish after an expiry reclaim overwrites the previous stamp,
/// exactly as it overwrites the report.
pub fn write_stamp(spool: &Path, stamp: &Stamp) -> Result<()> {
    std::fs::create_dir_all(stamps_dir(spool))?;
    let path = stamp_path(spool, &stamp.job_id);
    let tmp = unique_tmp(&path);
    std::fs::write(&tmp, stamp.to_json().to_string_pretty())
        .with_context(|| format!("writing stamp for {}", stamp.job_id))?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

/// Read one job's stamp; `None` if absent or unreadable.
pub fn read_stamp(spool: &Path, job_id: &str) -> Option<Stamp> {
    let text = std::fs::read_to_string(stamp_path(spool, job_id)).ok()?;
    Stamp::from_json(&Json::parse(&text).ok()?)
}

/// The result of scanning a spool's stamp directory: every readable
/// stamp by job id, plus how many files were skipped as malformed.
#[derive(Debug, Clone, Default)]
pub struct StampScan {
    pub stamps: BTreeMap<String, Stamp>,
    pub skipped: usize,
}

/// Scan every stamp in the spool. Malformed or truncated stamp files
/// are skipped with a warning on stderr (the report they index is
/// still intact — the job merely loses its cheap provenance), never an
/// error or a panic. A spool without a stamps directory (pre-stamp
/// era) scans as empty.
pub fn read_stamps(spool: &Path) -> StampScan {
    let mut scan = StampScan::default();
    let Ok(rd) = std::fs::read_dir(stamps_dir(spool)) else {
        return scan;
    };
    for entry in rd.filter_map(|e| e.ok()) {
        let path = entry.path();
        let Some(job_id) = path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_suffix(".stamp.json"))
        else {
            continue; // tmp files from in-flight atomic writes
        };
        let parsed = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| Stamp::from_json(&j));
        match parsed {
            Some(stamp) => {
                scan.stamps.insert(job_id.to_string(), stamp);
            }
            None => {
                scan.skipped += 1;
                eprintln!(
                    "warning: skipping malformed stamp {} (report unaffected)",
                    path.display()
                );
            }
        }
    }
    scan
}

// ---------------------------------------------------------- manifests

/// One experiment in a campaign manifest: a path to an experiment file
/// (resolved relative to the manifest's directory) or an inline
/// experiment object.
#[derive(Debug, Clone)]
pub enum ManifestEntry {
    Path(String),
    Inline(Experiment),
}

/// A campaign manifest: the `elaps submit` input for a multi-experiment
/// campaign. JSON form:
///
/// ```json
/// {
///   "campaign": "sweep-2026-08",
///   "experiments": ["gemm_small.json", "gemm_large.json", { ...inline... }]
/// }
/// ```
#[derive(Debug, Clone)]
pub struct CampaignManifest {
    pub campaign: String,
    pub experiments: Vec<ManifestEntry>,
}

impl CampaignManifest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("campaign", self.campaign.as_str()).set(
            "experiments",
            Json::Arr(
                self.experiments
                    .iter()
                    .map(|e| match e {
                        ManifestEntry::Path(p) => Json::Str(p.clone()),
                        ManifestEntry::Inline(exp) => io::experiment_to_json(exp),
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Parse a manifest. Strict where it matters: a missing/empty tag
    /// or an empty experiment list is an error (an empty campaign is
    /// always a composition mistake), and every entry must be a path
    /// string or a parsable experiment object.
    pub fn from_json(j: &Json) -> Result<CampaignManifest> {
        let campaign = j
            .get("campaign")
            .as_str()
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .ok_or_else(|| anyhow!("manifest needs a non-empty 'campaign' tag"))?
            .to_string();
        validate_tag(&campaign)?;
        let entries = j
            .get("experiments")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest needs an 'experiments' array"))?;
        if entries.is_empty() {
            bail!("campaign '{campaign}' lists no experiments");
        }
        let mut experiments = Vec::new();
        for (i, e) in entries.iter().enumerate() {
            experiments.push(match e {
                Json::Str(p) => ManifestEntry::Path(p.clone()),
                obj if obj.as_obj().is_some() => ManifestEntry::Inline(
                    io::experiment_from_json(obj)
                        .with_context(|| format!("experiments[{i}]"))?,
                ),
                other => bail!(
                    "experiments[{i}] must be a path string or an experiment \
                     object, not {other}"
                ),
            });
        }
        Ok(CampaignManifest { campaign, experiments })
    }

    /// Is this JSON a campaign manifest (as opposed to a bare
    /// experiment file)? The discriminator `elaps submit` uses.
    pub fn is_manifest(j: &Json) -> bool {
        !j.get("experiments").is_null()
    }

    /// Load the experiments the manifest names, resolving path entries
    /// relative to `base_dir` (the manifest file's directory).
    pub fn resolve(&self, base_dir: &Path) -> Result<Vec<Experiment>> {
        self.experiments
            .iter()
            .map(|e| match e {
                ManifestEntry::Path(p) => {
                    let path = if Path::new(p).is_absolute() {
                        PathBuf::from(p)
                    } else {
                        base_dir.join(p)
                    };
                    io::load_experiment_file(&path)
                }
                ManifestEntry::Inline(exp) => Ok(exp.clone()),
            })
            .collect()
    }
}

// ----------------------------------------------------- campaign record

/// Campaign tags become file names, so they are *validated*, not
/// sanitized: replacing characters would silently map distinct tags
/// (`sweep/1`, `sweep_1`) onto one record file and merge their job
/// lists. Only `[A-Za-z0-9._-]` is allowed, and a tag may not consist
/// purely of dots (`.`/`..` are directory names, not files).
pub fn validate_tag(tag: &str) -> Result<()> {
    if tag.is_empty() {
        bail!("campaign tag must not be empty");
    }
    if let Some(c) = tag.chars().find(|&c| !(c.is_ascii_alphanumeric() || ".-_".contains(c)))
    {
        bail!("campaign tag '{tag}' contains '{c}': only [A-Za-z0-9._-] is allowed");
    }
    if tag.chars().all(|c| c == '.') {
        bail!("campaign tag '{tag}' is not a valid file name");
    }
    Ok(())
}

fn campaign_path(spool: &Path, tag: &str) -> PathBuf {
    spool.join("campaigns").join(format!("{tag}.json"))
}

/// A held lock on a campaign tag's record
/// (`<spool>/campaigns/<tag>.lock`). Dropping the guard releases the
/// lock — `flock(2)` locks die with the last descriptor on their open
/// file description.
#[derive(Debug)]
pub struct TagLock {
    _lock: super::lease::JobLock,
}

/// Take the exclusive per-tag lock: serializes [`record_jobs`] merges,
/// whole-campaign submissions, and ledger retries on one tag with an
/// advisory `flock(2)` on a sidecar lock file — not on the record
/// itself, whose inode is replaced by every atomic rename, which would
/// leave later lockers holding a lock on a dead file. Each caller
/// opens its own descriptor, so the lock serializes threads within one
/// process as well as distinct processes on a shared (local)
/// filesystem.
pub(crate) fn lock_tag(spool: &Path, tag: &str) -> Result<TagLock> {
    std::fs::create_dir_all(spool.join("campaigns"))?;
    let path = spool.join("campaigns").join(format!("{tag}.lock"));
    Ok(TagLock { _lock: super::lease::flock_path(&path, false)? })
}

/// Take the per-tag lock shared: campaign *readers* hold this, so many
/// concurrent `wait`/`fetch`/`analyze` calls proceed in parallel while
/// any one writer (a `record_jobs` merge, a whole-campaign submit)
/// excludes them all — a reader can never act on a pre-merge job list.
pub(crate) fn lock_tag_shared(spool: &Path, tag: &str) -> Result<TagLock> {
    std::fs::create_dir_all(spool.join("campaigns"))?;
    let path = spool.join("campaigns").join(format!("{tag}.lock"));
    Ok(TagLock { _lock: super::lease::flock_path(&path, true)? })
}

/// Register job ids under a campaign tag (creating or extending the
/// record). The load-merge-store runs under an exclusive per-tag
/// [`TagLock`], so concurrent submitters to the *same tag* merge their
/// job lists instead of silently dropping each other's updates; the
/// final store is still an atomic replace, so readers never observe a
/// torn record.
pub fn record_jobs(spool: &Path, tag: &str, job_ids: &[String]) -> Result<()> {
    validate_tag(tag)?;
    let _lock = lock_tag(spool, tag)?;
    record_jobs_locked(spool, tag, job_ids)
}

/// The merge body of [`record_jobs`], for callers already holding the
/// tag's exclusive lock (taking it again on a fresh descriptor would
/// deadlock against ourselves).
fn record_jobs_locked(spool: &Path, tag: &str, job_ids: &[String]) -> Result<()> {
    let path = campaign_path(spool, tag);
    let mut jobs = campaign_jobs_unlocked(spool, tag).unwrap_or_default();
    for id in job_ids {
        if !jobs.contains(id) {
            jobs.push(id.clone());
        }
    }
    let mut j = Json::obj();
    j.set("campaign", tag)
        .set("jobs", Json::Arr(jobs.iter().map(|s| Json::Str(s.clone())).collect()));
    let tmp = unique_tmp(&path);
    std::fs::write(&tmp, j.to_string_pretty())?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

/// The job ids registered under a campaign tag, in submission order.
/// Reads under the shared per-tag lock, so a concurrent submission in
/// progress (which holds the lock exclusively across its whole
/// enqueue+record span) is either observed completely or not at all.
pub fn campaign_jobs(spool: &Path, tag: &str) -> Result<Vec<String>> {
    validate_tag(tag)?;
    if !campaign_path(spool, tag).exists() {
        // bail before locking: reading a campaign that was never
        // submitted must not create the campaigns/ directory
        bail!("no campaign '{tag}' in {}", spool.display());
    }
    let _lock = lock_tag_shared(spool, tag)?;
    campaign_jobs_unlocked(spool, tag)
}

fn campaign_jobs_unlocked(spool: &Path, tag: &str) -> Result<Vec<String>> {
    let path = campaign_path(spool, tag);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no campaign '{tag}' in {}", spool.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("campaign '{tag}': {e}"))?;
    Ok(j.get("jobs")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_str().map(String::from))
        .collect())
}

/// Submit experiments through a spooler, optionally registering the
/// job ids under a campaign tag. Returns the ids in submission order.
/// Purely client-side: nothing blocks on workers.
pub fn submit_experiments(
    spool: &Spooler,
    tag: Option<&str>,
    exps: &[Experiment],
) -> Result<Vec<String>> {
    // validate the tag BEFORE enqueueing: a bad tag must not leave
    // already-queued jobs behind with their ids never reported
    if let Some(tag) = tag {
        validate_tag(tag)?;
    }
    // hold the tag's exclusive lock across the whole enqueue+record
    // span: a concurrent campaign reader (wait/fetch, which locks
    // shared) blocks until the record merge lands, so it can never act
    // on a job list missing jobs that were already enqueued
    let _lock = match tag {
        Some(t) => Some(lock_tag(&spool.dir, t)?),
        None => None,
    };
    // submit through a campaign-tagged clone so the `submitted`
    // lifecycle events carry the tag; worker-side events stay untagged
    // and `elaps analyze` attributes them via the campaign record
    let tagged = tag.map(|t| spool.clone().with_campaign(t));
    let submitter = tagged.as_ref().unwrap_or(spool);
    let ids: Vec<String> =
        exps.iter().map(|e| submitter.submit(e)).collect::<Result<_>>()?;
    if let Some(tag) = tag {
        record_jobs_locked(&spool.dir, tag, &ids)?;
    }
    Ok(ids)
}

// ------------------------------------------------------------- status

/// Campaign-level progress, computed in O(#jobs): existence checks in
/// queue/running/done plus the stamp sidecars — no report body is ever
/// opened.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CampaignStatus {
    pub total: usize,
    pub queued: usize,
    pub leased: usize,
    pub done_ok: usize,
    pub done_error: usize,
    /// Done reports whose stamp is missing or unreadable (pre-stamp
    /// workers, or a corrupt sidecar): finished, outcome unknown.
    pub done_unknown: usize,
    /// Jobs registered in the campaign but visible nowhere in the
    /// spool (e.g. a queue file deleted by hand).
    pub missing: usize,
}

impl CampaignStatus {
    pub fn done(&self) -> usize {
        self.done_ok + self.done_error + self.done_unknown
    }

    pub fn render(&self, tag: &str) -> String {
        format!(
            "campaign '{tag}': {} job(s) — {} queued, {} leased, {} done \
             ({} ok, {} error, {} unknown){}\n",
            self.total,
            self.queued,
            self.leased,
            self.done(),
            self.done_ok,
            self.done_error,
            self.done_unknown,
            if self.missing > 0 { format!(", {} missing", self.missing) } else { String::new() },
        )
    }
}

/// Compute [`CampaignStatus`] for a set of job ids.
///
/// Probes are ordered so a job moving *forward* (queue → running →
/// done) between checks is never misreported as missing: `done` is
/// terminal and checked first, then queue before running (the claim
/// direction), and `done` once more at the end to catch a publish that
/// landed mid-probe. Only a job caught mid-*reclaim* (running → queue,
/// a sub-TTL window) can transiently count as missing.
pub fn status_of_jobs(spool: &Path, job_ids: &[String]) -> CampaignStatus {
    let mut st = CampaignStatus { total: job_ids.len(), ..Default::default() };
    let done_outcome = |st: &mut CampaignStatus, id: &str| match read_stamp(spool, id) {
        Some(s) if s.outcome == StampOutcome::Ok => st.done_ok += 1,
        Some(_) => st.done_error += 1,
        None => st.done_unknown += 1,
    };
    for id in job_ids {
        let done = spool.join("done").join(format!("{id}.report.json"));
        if done.exists() {
            done_outcome(&mut st, id);
        } else if spool.join("queue").join(format!("{id}.json")).exists() {
            st.queued += 1;
        } else if spool.join("running").join(format!("{id}.json")).exists() {
            st.leased += 1;
        } else if done.exists() {
            // claimed and published while we probed
            done_outcome(&mut st, id);
        } else {
            st.missing += 1;
        }
    }
    st
}

/// [`status_of_jobs`] for a recorded campaign tag.
pub fn campaign_status(spool: &Path, tag: &str) -> Result<CampaignStatus> {
    Ok(status_of_jobs(spool, &campaign_jobs(spool, tag)?))
}

// -------------------------------------------------------------- fetch

/// Copy the published reports of `job_ids` to `out_dir` as
/// `<job>.report.json`, byte-for-byte (the `served_by` provenance
/// stamp inside each report is preserved). Every job must be done;
/// wait first ([`Spooler::wait_many`]).
pub fn fetch_jobs(spool: &Spooler, job_ids: &[String], out_dir: &Path) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(out_dir)?;
    let mut fetched = Vec::new();
    for id in job_ids {
        let src = spool.dir.join("done").join(format!("{id}.report.json"));
        if !src.exists() {
            bail!("job {id} has no published report (wait for the campaign first)");
        }
        let dest = out_dir.join(format!("{id}.report.json"));
        let tmp = unique_tmp(&dest);
        std::fs::copy(&src, &tmp).with_context(|| format!("fetching {id}"))?;
        std::fs::rename(&tmp, &dest)?;
        fetched.push(dest);
    }
    Ok(fetched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use std::time::Duration;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("elaps_campaign_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn stamp_roundtrip_and_corruption() {
        let dir = tmpdir("stamp");
        std::fs::create_dir_all(&dir).unwrap();
        let s = Stamp {
            job_id: "job-1".into(),
            host: "hostA".into(),
            worker: "hostA#7-0".into(),
            epoch: 3,
            outcome: StampOutcome::Error,
        };
        write_stamp(&dir, &s).unwrap();
        assert_eq!(read_stamp(&dir, "job-1"), Some(s.clone()));
        // replace is atomic and overwrites
        let s2 = Stamp { epoch: 4, outcome: StampOutcome::Ok, ..s.clone() };
        write_stamp(&dir, &s2).unwrap();
        assert_eq!(read_stamp(&dir, "job-1"), Some(s2.clone()));
        // truncated and malformed stamps are skipped, never a panic
        std::fs::write(stamp_path(&dir, "trunc"), r#"{"job_id":"tru"#).unwrap();
        std::fs::write(stamp_path(&dir, "badout"), r#"{"job_id":"b","host":"h","worker":"w","epoch":1,"outcome":"maybe"}"#).unwrap();
        let scan = read_stamps(&dir);
        assert_eq!(scan.stamps.len(), 1);
        assert_eq!(scan.stamps.get("job-1"), Some(&s2));
        assert_eq!(scan.skipped, 2);
        assert_eq!(read_stamp(&dir, "trunc"), None);
        // a spool with no stamps directory scans as empty
        assert!(read_stamps(&dir.join("nope")).stamps.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_roundtrip_and_validation() {
        let m = CampaignManifest {
            campaign: "sweep".into(),
            experiments: vec![
                ManifestEntry::Path("a.json".into()),
                ManifestEntry::Inline(dgemm_experiment(24)),
            ],
        };
        let j = m.to_json();
        assert!(CampaignManifest::is_manifest(&j));
        let m2 = CampaignManifest::from_json(&j).unwrap();
        assert_eq!(m2.campaign, "sweep");
        assert_eq!(m2.experiments.len(), 2);
        // parse ∘ serialize is the identity on the JSON form
        assert_eq!(j.to_string_compact(), m2.to_json().to_string_compact());
        // a bare experiment is not a manifest
        assert!(!CampaignManifest::is_manifest(&io::experiment_to_json(
            &dgemm_experiment(8)
        )));
        // validation: tag and experiment list are mandatory
        for bad in [
            r#"{"experiments":["a.json"]}"#,
            r#"{"campaign":"  ","experiments":["a.json"]}"#,
            r#"{"campaign":"a/b","experiments":["a.json"]}"#,
            r#"{"campaign":"x"}"#,
            r#"{"campaign":"x","experiments":[]}"#,
            r#"{"campaign":"x","experiments":[42]}"#,
        ] {
            assert!(
                CampaignManifest::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn manifest_resolves_paths_relative_to_base() {
        let dir = tmpdir("resolve");
        std::fs::create_dir_all(&dir).unwrap();
        let exp = dgemm_experiment(16);
        std::fs::write(
            dir.join("e.json"),
            io::experiment_to_json(&exp).to_string_pretty(),
        )
        .unwrap();
        let m = CampaignManifest {
            campaign: "c".into(),
            experiments: vec![
                ManifestEntry::Path("e.json".into()),
                ManifestEntry::Inline(dgemm_experiment(8)),
            ],
        };
        let exps = m.resolve(&dir).unwrap();
        assert_eq!(exps.len(), 2);
        assert_eq!(exps[0].name, exp.name);
        // a dangling path is an error
        let bad = CampaignManifest {
            campaign: "c".into(),
            experiments: vec![ManifestEntry::Path("missing.json".into())],
        };
        assert!(bad.resolve(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_jobs_concurrent_submitters_merge() {
        // the regression this locks down: two clients registering jobs
        // under one tag used to race the read-modify-write and lose
        // whole submissions (last write wins)
        let dir = tmpdir("lock");
        std::fs::create_dir_all(&dir).unwrap();
        const THREADS: usize = 4;
        const PER: usize = 25;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let dir = &dir;
                s.spawn(move || {
                    for i in 0..PER {
                        record_jobs(dir, "camp", &[format!("job-{t}-{i}")]).unwrap();
                    }
                });
            }
        });
        let jobs = campaign_jobs(&dir, "camp").unwrap();
        assert_eq!(jobs.len(), THREADS * PER, "a lost update dropped job ids");
        // every submitter's ids survive, each in its submission order
        for t in 0..THREADS {
            let prefix = format!("job-{t}-");
            let mine: Vec<String> =
                jobs.iter().filter(|j| j.starts_with(&prefix)).cloned().collect();
            let expect: Vec<String> = (0..PER).map(|i| format!("job-{t}-{i}")).collect();
            assert_eq!(mine, expect, "thread {t} must keep submission order");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_record_submit_status_fetch_roundtrip() {
        let dir = tmpdir("record");
        let spool = Spooler::new(&dir).unwrap();
        let exps: Vec<_> = (0..3).map(|i| dgemm_experiment(8 + 4 * i)).collect();
        let ids = submit_experiments(&spool, Some("camp"), &exps).unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(campaign_jobs(&dir, "camp").unwrap(), ids);
        // incremental submission extends the record without duplicates
        let more = submit_experiments(&spool, Some("camp"), &exps[..1]).unwrap();
        record_jobs(&dir, "camp", &ids[..1]).unwrap();
        let all = campaign_jobs(&dir, "camp").unwrap();
        assert_eq!(all.len(), 4);
        assert_eq!(&all[..3], &ids[..]);
        assert_eq!(all[3], more[0]);
        // tags that could collide or escape the directory are
        // rejected outright, never sanitized into someone else's file
        for bad in ["../evil", "evil tag", "a/b", "", ".", ".."] {
            assert!(record_jobs(&dir, bad, &ids[..1]).is_err(), "{bad:?}");
            assert!(campaign_jobs(&dir, bad).is_err(), "{bad:?}");
        }
        // status: everything queued, then drained to done-ok
        let st = status_of_jobs(&dir, &all);
        assert_eq!(st.total, 4);
        assert_eq!(st.queued, 4);
        assert_eq!(st.done(), 0);
        spool.drain(2).unwrap();
        let st = campaign_status(&dir, "camp").unwrap();
        assert_eq!(st.done_ok, 4);
        assert_eq!(st.done_error + st.done_unknown + st.missing, 0);
        // an unknown tag is an error
        assert!(campaign_status(&dir, "nope").is_err());
        // wait returns immediately, fetch copies the raw reports
        spool.wait_many(&all, Duration::from_secs(5)).unwrap();
        let out = dir.join("fetched");
        let files = fetch_jobs(&spool, &all, &out).unwrap();
        assert_eq!(files.len(), 4);
        for (id, f) in all.iter().zip(&files) {
            let fetched = std::fs::read(f).unwrap();
            let original =
                std::fs::read(dir.join("done").join(format!("{id}.report.json"))).unwrap();
            assert_eq!(fetched, original, "fetch must be byte-for-byte");
        }
        // fetching a job that was never published is an error
        assert!(fetch_jobs(&spool, &["ghost".into()], &out).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
