//! Symbolic integer expressions over range variables.
//!
//! Experiment calls may use expressions like `n`, `4*m`, `n*(n+1)/2`
//! or `i*nb` for dimension arguments and operand sizes; ranges bind the
//! symbols at unroll time (§3.2.2: "all ranges and repetitions are
//! completely unrolled, thereby evaluating any symbolic variable").

use std::collections::BTreeMap;
use std::fmt;

/// A symbolic integer expression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    Const(i64),
    Sym(String),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    /// Integer division (floor).
    Div(Box<Expr>, Box<Expr>),
    /// Ceiling division.
    CeilDiv(Box<Expr>, Box<Expr>),
    /// min / max
    Min(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
}

/// Bindings of symbols to values.
pub type Bindings = BTreeMap<String, i64>;

impl Expr {
    pub fn c(v: i64) -> Expr {
        Expr::Const(v)
    }

    pub fn sym(s: &str) -> Expr {
        Expr::Sym(s.to_string())
    }

    /// Evaluate under bindings; errors on unbound symbols, division by
    /// zero, or i64 overflow (`n*n*n` at large n must not wrap silently
    /// in release builds).
    pub fn eval(&self, b: &Bindings) -> Result<i64, String> {
        let overflow = || format!("overflow in '{self}'");
        Ok(match self {
            Expr::Const(v) => *v,
            Expr::Sym(s) => {
                *b.get(s).ok_or_else(|| format!("unbound symbol '{s}'"))?
            }
            Expr::Add(l, r) => {
                l.eval(b)?.checked_add(r.eval(b)?).ok_or_else(overflow)?
            }
            Expr::Sub(l, r) => {
                l.eval(b)?.checked_sub(r.eval(b)?).ok_or_else(overflow)?
            }
            Expr::Mul(l, r) => {
                l.eval(b)?.checked_mul(r.eval(b)?).ok_or_else(overflow)?
            }
            Expr::Div(l, r) => {
                let d = r.eval(b)?;
                if d == 0 {
                    return Err("division by zero".into());
                }
                l.eval(b)?.div_euclid(d)
            }
            Expr::CeilDiv(l, r) => {
                let d = r.eval(b)?;
                if d == 0 {
                    return Err("division by zero".into());
                }
                let n = l.eval(b)?;
                n.checked_add(d - 1).ok_or_else(overflow)?.div_euclid(d)
            }
            Expr::Min(l, r) => l.eval(b)?.min(r.eval(b)?),
            Expr::Max(l, r) => l.eval(b)?.max(r.eval(b)?),
        })
    }

    /// Evaluate to usize (errors on negative results).
    pub fn eval_usize(&self, b: &Bindings) -> Result<usize, String> {
        let v = self.eval(b)?;
        usize::try_from(v).map_err(|_| format!("expression '{self}' evaluated to {v} < 0"))
    }

    /// Symbols appearing in the expression.
    pub fn symbols(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_symbols(&mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_symbols(&self, out: &mut Vec<String>) {
        match self {
            Expr::Const(_) => {}
            Expr::Sym(s) => out.push(s.clone()),
            Expr::Add(l, r)
            | Expr::Sub(l, r)
            | Expr::Mul(l, r)
            | Expr::Div(l, r)
            | Expr::CeilDiv(l, r)
            | Expr::Min(l, r)
            | Expr::Max(l, r) => {
                l.collect_symbols(out);
                r.collect_symbols(out);
            }
        }
    }

    /// Parse from text. Grammar: `expr := term (('+'|'-') term)*`,
    /// `term := atom (('*'|'/') atom)*`, `atom := int | ident |
    /// '(' expr ')' | ('min'|'max'|'ceildiv') '(' expr ',' expr ')'`.
    pub fn parse(text: &str) -> Result<Expr, String> {
        let toks = tokenize(text)?;
        let mut p = P { toks, pos: 0 };
        let e = p.expr()?;
        if p.pos != p.toks.len() {
            return Err(format!("trailing input at token {}", p.pos));
        }
        Ok(e)
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(v) => write!(f, "{v}"),
            Expr::Sym(s) => write!(f, "{s}"),
            Expr::Add(l, r) => write!(f, "({l} + {r})"),
            Expr::Sub(l, r) => write!(f, "({l} - {r})"),
            Expr::Mul(l, r) => write!(f, "({l} * {r})"),
            Expr::Div(l, r) => write!(f, "({l} / {r})"),
            Expr::CeilDiv(l, r) => write!(f, "ceildiv({l}, {r})"),
            Expr::Min(l, r) => write!(f, "min({l}, {r})"),
            Expr::Max(l, r) => write!(f, "max({l}, {r})"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Int(i64),
    Ident(String),
    Op(char),
}

fn tokenize(s: &str) -> Result<Vec<Tok>, String> {
    let mut toks = Vec::new();
    let b: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c.is_whitespace() {
            i += 1;
        } else if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
            let txt: String = b[start..i].iter().collect();
            toks.push(Tok::Int(txt.parse().map_err(|_| "bad integer")?));
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok::Ident(b[start..i].iter().collect()));
        } else if "+-*/(),".contains(c) {
            toks.push(Tok::Op(c));
            i += 1;
        } else {
            return Err(format!("unexpected character '{c}'"));
        }
    }
    Ok(toks)
}

struct P {
    toks: Vec<Tok>,
    pos: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.term()?;
        while let Some(Tok::Op(c @ ('+' | '-'))) = self.peek() {
            let c = *c;
            self.pos += 1;
            let rhs = self.term()?;
            lhs = if c == '+' {
                Expr::Add(Box::new(lhs), Box::new(rhs))
            } else {
                Expr::Sub(Box::new(lhs), Box::new(rhs))
            };
        }
        Ok(lhs)
    }

    fn term(&mut self) -> Result<Expr, String> {
        let mut lhs = self.atom()?;
        while let Some(Tok::Op(c @ ('*' | '/'))) = self.peek() {
            let c = *c;
            self.pos += 1;
            let rhs = self.atom()?;
            lhs = if c == '*' {
                Expr::Mul(Box::new(lhs), Box::new(rhs))
            } else {
                Expr::Div(Box::new(lhs), Box::new(rhs))
            };
        }
        Ok(lhs)
    }

    fn atom(&mut self) -> Result<Expr, String> {
        match self.peek().cloned() {
            Some(Tok::Int(v)) => {
                self.pos += 1;
                Ok(Expr::Const(v))
            }
            Some(Tok::Ident(name)) => {
                self.pos += 1;
                if matches!((name.as_str(), self.peek()), ("min" | "max" | "ceildiv", Some(Tok::Op('(')))) {
                    self.pos += 1; // '('
                    let a = self.expr()?;
                    match self.peek() {
                        Some(Tok::Op(',')) => self.pos += 1,
                        _ => return Err("expected ','".into()),
                    }
                    let b2 = self.expr()?;
                    match self.peek() {
                        Some(Tok::Op(')')) => self.pos += 1,
                        _ => return Err("expected ')'".into()),
                    }
                    Ok(match name.as_str() {
                        "min" => Expr::Min(Box::new(a), Box::new(b2)),
                        "max" => Expr::Max(Box::new(a), Box::new(b2)),
                        _ => Expr::CeilDiv(Box::new(a), Box::new(b2)),
                    })
                } else {
                    Ok(Expr::Sym(name))
                }
            }
            Some(Tok::Op('(')) => {
                self.pos += 1;
                let e = self.expr()?;
                match self.peek() {
                    Some(Tok::Op(')')) => {
                        self.pos += 1;
                        Ok(e)
                    }
                    _ => Err("expected ')'".into()),
                }
            }
            Some(Tok::Op('-')) => {
                self.pos += 1;
                // fold a negated literal into a negative constant, so
                // `Display` output like "-5" reparses to Const(-5)
                // instead of Sub(0, 5) (parse ∘ Display = id)
                let e = self.atom()?;
                Ok(match e {
                    Expr::Const(v) => Expr::Const(-v),
                    other => Expr::Sub(Box::new(Expr::Const(0)), Box::new(other)),
                })
            }
            other => Err(format!("unexpected token {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bind(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn parse_and_eval() {
        let e = Expr::parse("n*(n+1)/2").unwrap();
        assert_eq!(e.eval(&bind(&[("n", 10)])).unwrap(), 55);
    }

    #[test]
    fn precedence() {
        let e = Expr::parse("2+3*4").unwrap();
        assert_eq!(e.eval(&bind(&[])).unwrap(), 14);
        let e = Expr::parse("(2+3)*4").unwrap();
        assert_eq!(e.eval(&bind(&[])).unwrap(), 20);
    }

    #[test]
    fn functions() {
        let e = Expr::parse("min(n, 100) + max(m, 2) + ceildiv(n, 3)").unwrap();
        assert_eq!(e.eval(&bind(&[("n", 10), ("m", 1)])).unwrap(), 10 + 2 + 4);
    }

    #[test]
    fn unary_minus() {
        let e = Expr::parse("-n + 5").unwrap();
        assert_eq!(e.eval(&bind(&[("n", 3)])).unwrap(), 2);
    }

    #[test]
    fn unbound_symbol_errors() {
        let e = Expr::parse("n*m").unwrap();
        assert!(e.eval(&bind(&[("n", 3)])).is_err());
    }

    #[test]
    fn negative_to_usize_errors() {
        let e = Expr::parse("n - 10").unwrap();
        assert!(e.eval_usize(&bind(&[("n", 3)])).is_err());
        assert_eq!(e.eval_usize(&bind(&[("n", 13)])).unwrap(), 3);
    }

    #[test]
    fn symbols_collected() {
        let e = Expr::parse("a*b + b*c").unwrap();
        assert_eq!(e.symbols(), vec!["a", "b", "c"]);
    }

    #[test]
    fn div_by_zero() {
        let e = Expr::parse("10/n").unwrap();
        assert!(e.eval(&bind(&[("n", 0)])).is_err());
    }

    #[test]
    fn min_ident_not_function_without_paren() {
        let e = Expr::parse("min + 1").unwrap();
        assert_eq!(e.eval(&bind(&[("min", 4)])).unwrap(), 5);
    }

    #[test]
    fn negated_literal_parses_to_negative_const() {
        assert_eq!(Expr::parse("-5").unwrap(), Expr::Const(-5));
        // negated non-literals keep the 0 - e desugaring
        assert_eq!(
            Expr::parse("-n").unwrap(),
            Expr::Sub(Box::new(Expr::Const(0)), Box::new(Expr::sym("n")))
        );
    }

    #[test]
    fn eval_overflow_is_an_error_not_a_wrap() {
        // add at the top of the range
        let e = Expr::parse("a + b").unwrap();
        let err = e.eval(&bind(&[("a", i64::MAX), ("b", 1)])).unwrap_err();
        assert!(err.contains("overflow in '(a + b)'"), "{err}");
        // sub at the bottom of the range
        let e = Expr::parse("a - b").unwrap();
        let err = e.eval(&bind(&[("a", i64::MIN), ("b", 1)])).unwrap_err();
        assert!(err.contains("overflow in '(a - b)'"), "{err}");
        // the motivating case: n*n*n wraps silently in release pre-fix
        let e = Expr::parse("n*n*n").unwrap();
        let err = e.eval(&bind(&[("n", 3_000_000)])).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        // ceildiv's internal n + d - 1 must also be checked
        let e = Expr::parse("ceildiv(a, b)").unwrap();
        assert!(e.eval(&bind(&[("a", i64::MAX), ("b", 2)])).is_err());
        // boundary values that do NOT overflow still evaluate
        let e = Expr::parse("a + 0").unwrap();
        assert_eq!(e.eval(&bind(&[("a", i64::MAX)])).unwrap(), i64::MAX);
        let e = Expr::parse("a - 0").unwrap();
        assert_eq!(e.eval(&bind(&[("a", i64::MIN)])).unwrap(), i64::MIN);
    }
}
