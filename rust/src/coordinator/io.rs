//! Experiment and report (de)serialization — "easily stored to and
//! loaded from strings and files for portability" (§3.2.1).

use super::experiment::{Call, CallArg, DataGen, Experiment, RangeDef, Vary};
use super::report::{PointResult, Report};
use super::symbolic::Expr;
use crate::kernels::ArgRole;
use crate::perfmodel::MachineModel;
use crate::sampler::Record;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

// ---------------------------------------------------------------- exp

/// Load an experiment from a JSON file (the CLI's and the campaign
/// manifest's shared path → [`Experiment`] step).
pub fn load_experiment_file(path: impl AsRef<std::path::Path>) -> Result<Experiment> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    experiment_from_json(&j)
}

pub fn experiment_to_json(e: &Experiment) -> Json {
    let mut j = Json::obj();
    j.set("name", e.name.as_str())
        .set("library", e.library.as_str())
        .set("machine", e.machine.as_str())
        .set("nthreads", e.nthreads.to_string())
        .set("nreps", e.nreps)
        .set("discard_first", e.discard_first)
        .set("omp", e.omp);
    if let Some(r) = &e.range {
        j.set("range", range_to_json(r));
    }
    if let Some(r) = &e.sumrange {
        j.set("sumrange", range_to_json(r));
    }
    j.set(
        "calls",
        Json::Arr(e.calls.iter().map(call_to_json).collect()),
    );
    let mut dg = Json::obj();
    for (k, v) in &e.datagen {
        dg.set(
            k,
            match v {
                DataGen::Rand => Json::Str("rand".into()),
                DataGen::Zero => Json::Str("zero".into()),
                DataGen::Spd(ex) => Json::Str(format!("spd:{ex}")),
                DataGen::Tri(ex, u) => Json::Str(format!("tri{u}:{ex}")),
            },
        );
    }
    j.set("datagen", dg);
    let mut vy = Json::obj();
    for (k, v) in &e.vary {
        let mut o = Json::obj();
        o.set("rep", v.with_rep).set("sumrange", v.with_sumrange).set("pad", v.pad_elems);
        vy.set(k, o);
    }
    j.set("vary", vy);
    j.set("counters", e.counters.clone());
    j
}

fn range_to_json(r: &RangeDef) -> Json {
    let mut o = Json::obj();
    o.set("sym", r.sym.as_str())
        .set("values", Json::Arr(r.values.iter().map(|&v| Json::Num(v as f64)).collect()));
    o
}

fn call_to_json(c: &Call) -> Json {
    let mut args = vec![Json::Str(c.kernel.clone())];
    let sig = c.sig();
    for (arg, (_, role)) in c.args.iter().zip(sig.args) {
        args.push(match (arg, role) {
            (CallArg::Flag(ch), _) => Json::Str(ch.to_string()),
            (CallArg::Scalar(v), _) => Json::Num(*v),
            (CallArg::Expr(e), _) => match e {
                Expr::Const(v) => Json::Num(*v as f64),
                other => Json::Str(other.to_string()),
            },
            (CallArg::Data(d), ArgRole::Data(_)) => Json::Str(format!("${d}")),
            (CallArg::Data(d), _) => Json::Str(format!("${d}")),
        });
    }
    Json::Arr(args)
}

pub fn experiment_from_json(j: &Json) -> Result<Experiment> {
    let name = j.get("name").as_str().unwrap_or("experiment").to_string();
    let library = j.get("library").as_str().unwrap_or("rustblocked").to_string();
    let machine = j.get("machine").as_str().unwrap_or("localhost").to_string();
    let nthreads = match j.get("nthreads") {
        Json::Num(v) => Expr::Const(*v as i64),
        Json::Str(s) => Expr::parse(s).map_err(|e| anyhow!("nthreads: {e}"))?,
        _ => Expr::Const(1),
    };
    let nreps = j.get("nreps").as_u64().unwrap_or(1) as usize;
    let discard_first = j.get("discard_first").as_bool().unwrap_or(false);
    let omp = j.get("omp").as_bool().unwrap_or(false);
    let range = range_from_json(j.get("range"))?;
    let sumrange = range_from_json(j.get("sumrange"))?;
    let mut calls = Vec::new();
    for cj in j.get("calls").as_arr().unwrap_or(&[]) {
        calls.push(call_from_json(cj)?);
    }
    let mut datagen = std::collections::BTreeMap::new();
    if let Some(obj) = j.get("datagen").as_obj() {
        for (k, v) in obj {
            let s = v.as_str().unwrap_or("rand");
            let g = if s == "rand" {
                DataGen::Rand
            } else if s == "zero" {
                DataGen::Zero
            } else if let Some(e) = s.strip_prefix("spd:") {
                DataGen::Spd(Expr::parse(e).map_err(|e| anyhow!("datagen {k}: {e}"))?)
            } else if let Some(e) = s.strip_prefix("triL:") {
                DataGen::Tri(Expr::parse(e).map_err(|e| anyhow!("datagen {k}: {e}"))?, 'L')
            } else if let Some(e) = s.strip_prefix("triU:") {
                DataGen::Tri(Expr::parse(e).map_err(|e| anyhow!("datagen {k}: {e}"))?, 'U')
            } else {
                bail!("bad datagen spec '{s}' for operand {k}");
            };
            datagen.insert(k.clone(), g);
        }
    }
    let mut vary = std::collections::BTreeMap::new();
    if let Some(obj) = j.get("vary").as_obj() {
        for (k, v) in obj {
            vary.insert(
                k.clone(),
                Vary {
                    with_rep: v.get("rep").as_bool().unwrap_or(false),
                    with_sumrange: v.get("sumrange").as_bool().unwrap_or(false),
                    pad_elems: v.get("pad").as_u64().unwrap_or(0) as usize,
                },
            );
        }
    }
    let counters = j
        .get("counters")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|c| c.as_str().map(String::from))
        .collect();
    Ok(Experiment {
        name,
        library,
        machine,
        nthreads,
        nreps,
        discard_first,
        range,
        sumrange,
        omp,
        calls,
        datagen,
        vary,
        counters,
    })
}

fn range_from_json(j: &Json) -> Result<Option<RangeDef>> {
    if j.is_null() {
        return Ok(None);
    }
    let sym = j.get("sym").as_str().ok_or_else(|| anyhow!("range needs 'sym'"))?;
    let values: Vec<i64> = j
        .get("values")
        .as_arr()
        .ok_or_else(|| anyhow!("range needs 'values'"))?
        .iter()
        .filter_map(Json::as_i64)
        .collect();
    Ok(Some(RangeDef::new(sym, values)))
}

fn call_from_json(j: &Json) -> Result<Call> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("call must be an array"))?;
    let kernel = arr
        .first()
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("call needs a kernel name"))?;
    let sig = crate::kernels::lookup(kernel).ok_or_else(|| anyhow!("unknown kernel {kernel}"))?;
    if arr.len() != sig.args.len() + 1 {
        bail!("{kernel}: expected {} args, got {}", sig.args.len(), arr.len() - 1);
    }
    let mut args = Vec::new();
    for (v, (name, role)) in arr[1..].iter().zip(sig.args) {
        let arg = match role {
            ArgRole::Flag(_) => CallArg::Flag(
                v.as_str()
                    .and_then(|s| s.chars().next())
                    .ok_or_else(|| anyhow!("{kernel}: flag '{name}'"))?,
            ),
            ArgRole::Scalar => match v {
                Json::Num(x) => CallArg::Scalar(*x),
                Json::Str(s) => CallArg::Expr(Expr::parse(s).map_err(|e| anyhow!("{e}"))?),
                _ => bail!("{kernel}: scalar '{name}'"),
            },
            ArgRole::Dim | ArgRole::Ld | ArgRole::Inc => match v {
                Json::Num(x) => CallArg::Expr(Expr::Const(*x as i64)),
                Json::Str(s) => CallArg::Expr(Expr::parse(s).map_err(|e| anyhow!("{e}"))?),
                _ => bail!("{kernel}: dim '{name}'"),
            },
            ArgRole::Data(_) => {
                let s = v.as_str().ok_or_else(|| anyhow!("{kernel}: data '{name}'"))?;
                CallArg::Data(s.strip_prefix('$').unwrap_or(s).to_string())
            }
        };
        args.push(arg);
    }
    Call::new(kernel, args)
}

// ------------------------------------------------------------- report

/// Serialize one measurement point (also the engine's result-cache
/// entry format, [`crate::engine::cache`]).
pub fn point_result_to_json(p: &PointResult) -> Json {
    let mut pj = Json::obj();
    pj.set("range_value", p.range_value)
        .set("nthreads", p.nthreads)
        .set("sum_iters", p.sum_iters)
        .set("calls_per_iter", p.calls_per_iter);
    let recs: Vec<Json> = p
        .records
        .iter()
        .map(|rec| {
            let mut o = Json::obj();
            o.set("kernel", rec.kernel.as_str())
                .set("seconds", rec.seconds)
                .set("cycles", rec.cycles)
                .set("flops", rec.flops)
                .set(
                    "counters",
                    Json::Arr(rec.counters.iter().map(|&c| Json::Num(c as f64)).collect()),
                );
            if let Some(g) = rec.omp_group {
                o.set("omp_group", g);
            }
            o
        })
        .collect();
    pj.set("records", Json::Arr(recs));
    pj
}

/// Deserialize one measurement point (lenient: missing fields fall back
/// to defaults, matching the rest of the report loader).
pub fn point_result_from_json(pj: &Json) -> PointResult {
    let records = pj
        .get("records")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|o| Record {
            kernel: o.get("kernel").as_str().unwrap_or("?").to_string(),
            seconds: o.get("seconds").as_f64().unwrap_or(0.0),
            cycles: o.get("cycles").as_f64().unwrap_or(0.0),
            flops: o.get("flops").as_f64().unwrap_or(0.0),
            counters: o
                .get("counters")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|c| c.as_f64().map(|v| v as u64))
                .collect(),
            omp_group: o.get("omp_group").as_u64().map(|v| v as usize),
        })
        .collect();
    PointResult {
        range_value: pj.get("range_value").as_i64().unwrap_or(0),
        nthreads: pj.get("nthreads").as_u64().unwrap_or(1) as usize,
        sum_iters: pj.get("sum_iters").as_u64().unwrap_or(1) as usize,
        calls_per_iter: pj.get("calls_per_iter").as_u64().unwrap_or(1) as usize,
        records,
    }
}

// ---------------------------------------------------- cache envelope

/// Schema version of the engine's result-cache entry envelope
/// ([`crate::engine::cache`]). Bump on incompatible layout changes;
/// readers treat unknown schemas as cache misses, never as errors.
///
/// History: schema 1 added `{jobs, created_unix}` provenance over the
/// legacy bare point object; schema 2 added the `warm` flag (whether
/// the measuring sampler carried simulated cache state from previous
/// points); schema 3 added `{host, worker}` — which machine and which
/// worker process measured the entry, the provenance multi-host
/// campaigns over one shared cache need. Schema-1 entries still parse,
/// as `warm: false` (a cold measurement is exactly what a schema-1 run
/// produced); schema-1/2 entries parse with unknown host/worker.
pub const CACHE_ENTRY_SCHEMA: u64 = 3;

/// A parsed result-cache entry: the stored [`PointResult`] plus the
/// provenance the storing run recorded. `schema == 0` (with `jobs` and
/// `created_unix` both `None`) marks a legacy pre-envelope entry — a
/// bare point object, still readable but of unknown provenance.
#[derive(Debug, Clone)]
pub struct CacheEnvelope {
    /// Envelope schema version (0 = legacy bare entry).
    pub schema: u64,
    /// Worker-pool width of the run that measured this entry; `None`
    /// means unknown (legacy entry).
    pub jobs: Option<usize>,
    /// Unix seconds when the entry was stored; `None` means unknown.
    pub created_unix: Option<u64>,
    /// Whether the measuring sampler carried simulated cache state from
    /// previous points (the engine's warm execution mode). Legacy and
    /// schema-1 entries are cold by construction.
    pub warm: bool,
    /// Hostname of the measuring machine; `None` means unknown
    /// (pre-schema-3 entry).
    pub host: Option<String>,
    /// Worker identity of the measuring process
    /// ([`crate::util::hostid::new_worker_id`]); `None` means unknown.
    pub worker: Option<String>,
    /// The cached measurement.
    pub result: PointResult,
}

impl CacheEnvelope {
    /// The timing-provenance rule: only entries measured without worker
    /// contention (`jobs ≤ 1`) are trustworthy for publication timings.
    /// Legacy entries cannot prove it, so they are untrusted.
    pub fn trusted(&self) -> bool {
        matches!(self.jobs, Some(j) if j <= 1)
    }
}

/// Serialize a result-cache entry as the versioned envelope
/// `{schema, jobs, warm, host, worker, created_unix, result}`.
pub fn cache_envelope_to_json(
    p: &PointResult,
    jobs: usize,
    created_unix: Option<u64>,
    warm: bool,
    host: Option<&str>,
    worker: Option<&str>,
) -> Json {
    let mut j = Json::obj();
    j.set("schema", CACHE_ENTRY_SCHEMA)
        .set("jobs", jobs)
        .set("warm", warm)
        .set("result", point_result_to_json(p));
    if let Some(t) = created_unix {
        j.set("created_unix", t);
    }
    if let Some(h) = host {
        j.set("host", h);
    }
    if let Some(w) = worker {
        j.set("worker", w);
    }
    j
}

/// Parse a result-cache entry. Envelopes with an unknown `schema` are
/// rejected (`None` — a miss, not an error); schema-1/2 envelopes parse
/// with the provenance fields they predate defaulted (cold, unknown
/// host/worker); a bare point object (the pre-envelope format) parses
/// as a legacy entry with unknown provenance.
pub fn cache_envelope_from_json(j: &Json) -> Option<CacheEnvelope> {
    if j.get("schema").is_null() {
        // legacy bare entry: require at least a records array so that
        // arbitrary JSON is not misread as an empty measurement
        j.get("records").as_arr()?;
        return Some(CacheEnvelope {
            schema: 0,
            jobs: None,
            created_unix: None,
            warm: false,
            host: None,
            worker: None,
            result: point_result_from_json(j),
        });
    }
    let schema = j.get("schema").as_u64()?;
    if !(1..=CACHE_ENTRY_SCHEMA).contains(&schema) {
        return None;
    }
    // same guard as the legacy branch: a payload without a records
    // array is junk, not an empty measurement
    j.get("result").get("records").as_arr()?;
    Some(CacheEnvelope {
        schema,
        jobs: j.get("jobs").as_u64().map(|v| v as usize),
        created_unix: j.get("created_unix").as_u64(),
        // schema 1 predates warm execution: those entries are cold
        warm: schema >= 2 && j.get("warm").as_bool().unwrap_or(false),
        // schema 3 added host/worker provenance; a stray field on an
        // older envelope is ignored, like the warm flag above
        host: (schema >= 3).then(|| j.get("host").as_str().map(String::from)).flatten(),
        worker: (schema >= 3).then(|| j.get("worker").as_str().map(String::from)).flatten(),
        result: point_result_from_json(j.get("result")),
    })
}

pub fn report_to_json(r: &Report) -> Json {
    let mut j = Json::obj();
    j.set("experiment", experiment_to_json(&r.experiment));
    j.set("machine", r.machine.name.as_str());
    j.set(
        "points",
        Json::Arr(r.points.iter().map(point_result_to_json).collect()),
    );
    j
}

pub fn report_from_json(j: &Json) -> Result<Report> {
    let experiment = experiment_from_json(j.get("experiment"))?;
    let machine_name = j.get("machine").as_str().unwrap_or("localhost");
    // accept machine specs (registry names, profile:PATH, a
    // profile-shadowed localhost) and model display names; reports
    // must stay loadable even when a profile file has moved, so
    // resolution failures fall back to the built-in localhost
    let machine = crate::perfmodel::resolve_machine(&experiment.machine)
        .ok()
        .or_else(|| MachineModel::by_name(machine_name))
        .unwrap_or_else(MachineModel::localhost);
    let points = j
        .get("points")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(point_result_from_json)
        .collect();
    Report::assemble(experiment, machine, points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::tests_support::dgemm_experiment;
    use crate::coordinator::submit::run_local;

    #[test]
    fn experiment_roundtrip() {
        let mut e = dgemm_experiment(128);
        e.nreps = 5;
        e.discard_first = true;
        e.range = Some(RangeDef::span("n", 100, 50, 200));
        e.sumrange = Some(RangeDef::new("i", vec![0, 1, 2]));
        e.omp = true;
        e.counters = vec!["PAPI_L1_TCM".into()];
        e.vary.insert("C".into(), Vary { with_rep: true, with_sumrange: false, pad_elems: 64 });
        e.datagen.insert("A".into(), DataGen::Spd(Expr::parse("n").unwrap()));
        let j = experiment_to_json(&e);
        let e2 = experiment_from_json(&j).unwrap();
        assert_eq!(e2.name, e.name);
        assert_eq!(e2.nreps, 5);
        assert!(e2.discard_first);
        assert!(e2.omp);
        assert_eq!(e2.range, e.range);
        assert_eq!(e2.sumrange, e.sumrange);
        assert_eq!(e2.counters, e.counters);
        assert_eq!(e2.vary["C"].with_rep, true);
        assert_eq!(e2.vary["C"].pad_elems, 64);
        assert_eq!(e2.datagen["A"], e.datagen["A"]);
        // and round again: stable
        let j2 = experiment_to_json(&e2);
        assert_eq!(j.to_string_compact(), j2.to_string_compact());
    }

    #[test]
    fn symbolic_args_survive() {
        let mut e = dgemm_experiment(0);
        e.range = Some(RangeDef::span("n", 10, 10, 30));
        // replace dims with symbolic n
        let j = Json::parse(
            r#"{"name":"x","calls":[["dgemm","N","N","n","n","n",1,"$A","n","$B","n",0,"$C","n"]],
               "range":{"sym":"n","values":[10,20]},"nreps":2}"#,
        )
        .unwrap();
        let e2 = experiment_from_json(&j).unwrap();
        let pts = e2.unroll().unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[1].script.contains("dgemm N N 20 20 20"));
    }

    #[test]
    fn report_roundtrip() {
        let mut e = dgemm_experiment(40);
        e.nreps = 2;
        let r = run_local(&e).unwrap();
        let j = report_to_json(&r);
        let r2 = report_from_json(&j).unwrap();
        assert_eq!(r2.points.len(), r.points.len());
        assert_eq!(r2.points[0].records.len(), r.points[0].records.len());
        let s1 = r.series(crate::coordinator::report::Metric::TimeS, crate::coordinator::stats::Stat::Avg);
        let s2 = r2.series(crate::coordinator::report::Metric::TimeS, crate::coordinator::stats::Stat::Avg);
        assert!((s1[0].1 - s2[0].1).abs() < 1e-12);
    }

    #[test]
    fn cache_envelope_roundtrip_and_legacy() {
        let p = PointResult {
            range_value: 7,
            nthreads: 2,
            sum_iters: 1,
            calls_per_iter: 1,
            records: vec![Record {
                kernel: "dgemm".into(),
                seconds: 0.5,
                cycles: 1.3e9,
                flops: 2e9,
                counters: vec![3, 4],
                omp_group: None,
            }],
        };
        let j = cache_envelope_to_json(
            &p,
            8,
            Some(1_700_000_000),
            true,
            Some("nodeA"),
            Some("nodeA#7-0"),
        );
        let env = cache_envelope_from_json(&j).unwrap();
        assert_eq!(env.schema, CACHE_ENTRY_SCHEMA);
        assert_eq!(env.jobs, Some(8));
        assert_eq!(env.created_unix, Some(1_700_000_000));
        assert!(env.warm);
        assert!(!env.trusted());
        assert_eq!(env.host.as_deref(), Some("nodeA"));
        assert_eq!(env.worker.as_deref(), Some("nodeA#7-0"));
        assert_eq!(env.result.records.len(), 1);
        assert_eq!(env.result.records[0].counters, vec![3, 4]);
        // jobs ≤ 1 is trusted; absent host/worker stay unknown
        let env1 =
            cache_envelope_from_json(&cache_envelope_to_json(&p, 1, None, false, None, None))
                .unwrap();
        assert!(env1.trusted());
        assert!(!env1.warm);
        assert_eq!(env1.host, None);
        assert_eq!(env1.worker, None);
        // a schema-1 envelope (pre-warm, pre-host) still parses, as cold
        let mut v1 = cache_envelope_to_json(&p, 1, Some(1_700_000_000), false, None, None);
        v1.set("schema", 1u64);
        let env_v1 = cache_envelope_from_json(&v1).unwrap();
        assert_eq!(env_v1.schema, 1);
        assert_eq!(env_v1.jobs, Some(1));
        assert!(!env_v1.warm);
        assert!(env_v1.trusted());
        // ...even if some (corrupt) writer put a warm flag on it
        v1.set("warm", true);
        assert!(!cache_envelope_from_json(&v1).unwrap().warm);
        // a schema-2 envelope (pre-host) parses with unknown host
        let mut v2 = cache_envelope_to_json(&p, 1, None, true, None, None);
        v2.set("schema", 2u64);
        let env_v2 = cache_envelope_from_json(&v2).unwrap();
        assert_eq!(env_v2.schema, 2);
        assert!(env_v2.warm);
        assert_eq!(env_v2.host, None);
        // ...even if some (corrupt) writer put host/worker fields on it
        v2.set("host", "bogus").set("worker", "bogus#0-0");
        let env_v2b = cache_envelope_from_json(&v2).unwrap();
        assert_eq!(env_v2b.host, None);
        assert_eq!(env_v2b.worker, None);
        // legacy bare point: readable, provenance unknown, untrusted
        let legacy = cache_envelope_from_json(&point_result_to_json(&p)).unwrap();
        assert_eq!(legacy.schema, 0);
        assert_eq!(legacy.jobs, None);
        assert!(!legacy.warm);
        assert!(!legacy.trusted());
        assert_eq!(legacy.result.records.len(), 1);
        // unknown schema and non-entry JSON are rejected, not errors
        let mut wrong = cache_envelope_to_json(&p, 1, None, false, None, None);
        wrong.set("schema", CACHE_ENTRY_SCHEMA + 1);
        assert!(cache_envelope_from_json(&wrong).is_none());
        assert!(cache_envelope_from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(cache_envelope_from_json(&Json::parse("[1,2]").unwrap()).is_none());
        // a right-schema envelope missing its result payload is junk
        // too, never a trusted empty measurement
        let hollow = Json::parse(r#"{"schema":2,"jobs":1}"#).unwrap();
        assert!(cache_envelope_from_json(&hollow).is_none());
        let hollow2 = Json::parse(r#"{"schema":1,"jobs":1,"result":{}}"#).unwrap();
        assert!(cache_envelope_from_json(&hollow2).is_none());
    }

    #[test]
    fn malformed_call_rejected() {
        let j = Json::parse(r#"{"calls":[["dgemm","N","N"]]}"#).unwrap();
        assert!(experiment_from_json(&j).is_err());
        let j = Json::parse(r#"{"calls":[["zgemm"]]}"#).unwrap();
        assert!(experiment_from_json(&j).is_err());
    }
}
