//! Statistics over repetitions (§2.1, §3.2.3): minimum, maximum,
//! average, median, standard deviation — with the paper's
//! "discard the first repetition" option.

/// A statistic reducing the per-repetition values of a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stat {
    Min,
    Max,
    Avg,
    Median,
    Std,
}

pub const ALL_STATS: &[Stat] = &[Stat::Min, Stat::Max, Stat::Avg, Stat::Median, Stat::Std];

impl Stat {
    pub fn name(self) -> &'static str {
        match self {
            Stat::Min => "min",
            Stat::Max => "max",
            Stat::Avg => "avg",
            Stat::Median => "med",
            Stat::Std => "std",
        }
    }

    pub fn by_name(name: &str) -> Option<Stat> {
        Some(match name {
            "min" => Stat::Min,
            "max" => Stat::Max,
            "avg" | "mean" => Stat::Avg,
            "med" | "median" => Stat::Median,
            "std" => Stat::Std,
            _ => return None,
        })
    }

    /// Apply to a sample; returns NaN for an empty sample.
    pub fn apply(self, values: &[f64]) -> f64 {
        if values.is_empty() {
            return f64::NAN;
        }
        match self {
            Stat::Min => values.iter().copied().fold(f64::INFINITY, f64::min),
            Stat::Max => values.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            Stat::Avg => values.iter().sum::<f64>() / values.len() as f64,
            Stat::Median => {
                // A NaN sample poisons the median, exactly as it does
                // avg and std — anything else would rank the NaN at an
                // end (where depends on its sign bit) and silently
                // shift the reported median of the finite samples.
                if values.iter().any(|v| v.is_nan()) {
                    return f64::NAN;
                }
                let mut v = values.to_vec();
                // total_cmp instead of partial_cmp(..).unwrap(): the
                // sort must never be able to panic a report reduction
                v.sort_by(f64::total_cmp);
                let n = v.len();
                if n % 2 == 1 {
                    v[n / 2]
                } else {
                    0.5 * (v[n / 2 - 1] + v[n / 2])
                }
            }
            Stat::Std => {
                let n = values.len() as f64;
                let mean = values.iter().sum::<f64>() / n;
                (values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n).sqrt()
            }
        }
    }
}

/// Drop the first repetition (§2.1: "the first one almost inevitably
/// represents an outlier") unless that would empty the sample.
pub fn maybe_discard_first(values: &[f64], discard: bool) -> &[f64] {
    if discard && values.len() > 1 {
        &values[1..]
    } else {
        values
    }
}

/// NaN-safe percentile with linear interpolation between closest
/// ranks: `percentile(v, 0.5)` is the median of an odd sample,
/// `percentile(v, 0.0)`/`(v, 1.0)` the min/max. Empty or NaN-poisoned
/// samples yield NaN (the same poisoning rule as [`Stat::Median`]);
/// `q` is clamped to `[0, 1]`. Deliberately a free function, not a
/// [`Stat`] variant: report reductions stay the paper's five
/// statistics, while `elaps analyze` layers percentiles on top.
pub fn percentile(values: &[f64], q: f64) -> f64 {
    if values.is_empty() || values.iter().any(|v| v.is_nan()) {
        return f64::NAN;
    }
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_of_sorted(&v, q)
}

/// The rank-interpolation core of [`percentile`], for callers that read
/// several percentiles from one sample: sort once (`f64::total_cmp`,
/// after screening NaNs), then call this per rank — instead of paying
/// [`percentile`]'s clone + sort every time. An empty sample yields
/// NaN; NaN *elements* are the caller's job to screen, since a sort
/// order over them is already caller-defined.
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: &[f64] = &[10.0, 2.0, 4.0, 4.0];

    #[test]
    fn basic_stats() {
        assert_eq!(Stat::Min.apply(V), 2.0);
        assert_eq!(Stat::Max.apply(V), 10.0);
        assert_eq!(Stat::Avg.apply(V), 5.0);
        assert_eq!(Stat::Median.apply(V), 4.0);
        let std = Stat::Std.apply(V);
        assert!((std - 3.0).abs() < 1e-12, "{std}"); // var = (25+9+1+1)/4 = 9
    }

    #[test]
    fn median_odd() {
        assert_eq!(Stat::Median.apply(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(Stat::Median.apply(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(Stat::Median.apply(&[1.0, 2.0]), 1.5);
    }

    #[test]
    fn empty_is_nan() {
        for &s in ALL_STATS {
            assert!(s.apply(&[]).is_nan(), "{}", s.name());
        }
    }

    #[test]
    fn nan_samples_never_panic() {
        // the regression: Median used to sort with
        // partial_cmp(..).unwrap(), which panics on NaN samples
        let with_nan = &[2.0, f64::NAN, 1.0];
        // a poisoned sample yields NaN — consistently with avg/std,
        // and independent of the NaN's sign bit (total_cmp would rank
        // -NaN first but +NaN last)
        assert!(Stat::Median.apply(with_nan).is_nan());
        assert!(Stat::Median.apply(&[1.0, f64::NAN]).is_nan());
        assert!(Stat::Median.apply(&[-f64::NAN, 5.0, 6.0]).is_nan());
        // the other stats handle NaN without panicking
        for &s in ALL_STATS {
            let _ = s.apply(with_nan);
        }
        assert!(Stat::Std.apply(with_nan).is_nan());
        assert!(Stat::Avg.apply(with_nan).is_nan());
    }

    #[test]
    fn discard_first_changes_stats() {
        // the paper's Fig. 1 point: the first-rep outlier dominates
        // min/avg/std
        let with = Stat::Avg.apply(maybe_discard_first(V, false));
        let without = Stat::Avg.apply(maybe_discard_first(V, true));
        assert_eq!(with, 5.0);
        assert!((without - 10.0 / 3.0).abs() < 1e-12);
        // never empties the sample
        assert_eq!(maybe_discard_first(&[1.0], true), &[1.0]);
    }

    #[test]
    fn names_roundtrip() {
        for &s in ALL_STATS {
            assert_eq!(Stat::by_name(s.name()), Some(s));
        }
        assert_eq!(Stat::by_name("p99"), None);
    }

    #[test]
    fn percentile_interpolates_and_brackets() {
        let v = &[1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(v, 0.0), 1.0);
        assert_eq!(percentile(v, 1.0), 4.0);
        assert_eq!(percentile(v, 0.5), 2.5, "matches the even-sample median");
        assert!((percentile(v, 0.9) - 3.7).abs() < 1e-12);
        // order-independent and monotone in q
        let shuffled = &[4.0, 1.0, 3.0, 2.0];
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(percentile(v, q), percentile(shuffled, q));
        }
        assert!(percentile(v, 0.5) <= percentile(v, 0.9));
        assert!(percentile(v, 0.9) <= percentile(v, 0.99));
        // a single sample is every percentile
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        // out-of-range q clamps instead of indexing out of bounds
        assert_eq!(percentile(v, -1.0), 1.0);
        assert_eq!(percentile(v, 2.0), 4.0);
    }

    #[test]
    fn percentile_is_nan_safe() {
        assert!(percentile(&[], 0.5).is_nan());
        assert!(percentile(&[1.0, f64::NAN], 0.5).is_nan(), "poisoned like Median");
        assert!(percentile(&[-f64::NAN, 5.0], 0.9).is_nan());
    }
}
