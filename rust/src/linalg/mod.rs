//! From-scratch dense linear algebra substrate (f64, column-major,
//! BLAS/LAPACK calling conventions with leading dimensions).
//!
//! The paper's experiments exercise vendor BLAS/LAPACK libraries
//! (OpenBLAS, MKL, ESSL, Accelerate, RECSY, libFLAME). None are
//! available here, so this module implements the needed kernel set from
//! scratch, in several algorithmic variants (naive/unblocked, blocked
//! with packed microkernel, recursive) — the variants *are* the
//! "libraries" being compared in the library-selection experiments
//! (DESIGN.md §Substitutions 1).
//!
//! Conventions: matrices are column-major slices; element (i,j) of an
//! m×n matrix with leading dimension `ld >= m` is `a[i + j*ld]`.

pub mod matrix;
pub mod blas1;
pub mod blas2;
pub mod blas3;
pub mod lapack;

pub use matrix::Matrix;

/// Transpose flag, mirroring the BLAS `trans` character argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    /// 'N' — operate on A
    No,
    /// 'T' — operate on Aᵀ
    Yes,
}

impl Trans {
    pub fn from_char(c: char) -> Option<Trans> {
        match c.to_ascii_uppercase() {
            'N' => Some(Trans::No),
            'T' | 'C' => Some(Trans::Yes),
            _ => None,
        }
    }
    pub fn as_char(self) -> char {
        match self {
            Trans::No => 'N',
            Trans::Yes => 'T',
        }
    }
}

/// Upper/lower triangular flag (`uplo`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Uplo {
    Upper,
    Lower,
}

impl Uplo {
    pub fn from_char(c: char) -> Option<Uplo> {
        match c.to_ascii_uppercase() {
            'U' => Some(Uplo::Upper),
            'L' => Some(Uplo::Lower),
            _ => None,
        }
    }
    pub fn as_char(self) -> char {
        match self {
            Uplo::Upper => 'U',
            Uplo::Lower => 'L',
        }
    }
}

/// Left/right multiplication side (`side`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Left,
    Right,
}

impl Side {
    pub fn from_char(c: char) -> Option<Side> {
        match c.to_ascii_uppercase() {
            'L' => Some(Side::Left),
            'R' => Some(Side::Right),
            _ => None,
        }
    }
    pub fn as_char(self) -> char {
        match self {
            Side::Left => 'L',
            Side::Right => 'R',
        }
    }
}

/// Unit-diagonal flag (`diag`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Diag {
    NonUnit,
    Unit,
}

impl Diag {
    pub fn from_char(c: char) -> Option<Diag> {
        match c.to_ascii_uppercase() {
            'N' => Some(Diag::NonUnit),
            'U' => Some(Diag::Unit),
            _ => None,
        }
    }
    pub fn as_char(self) -> char {
        match self {
            Diag::NonUnit => 'N',
            Diag::Unit => 'U',
        }
    }
}

/// Errors reported by the LAPACK-level routines (mirrors `info`).
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum LinalgError {
    #[error("matrix is singular at pivot {0}")]
    Singular(usize),
    #[error("matrix is not positive definite at column {0}")]
    NotPositiveDefinite(usize),
    #[error("eigensolver failed to converge for eigenvalue {0}")]
    NoConvergence(usize),
    #[error("sylvester equation has common eigenvalues (perturbed at {0})")]
    CommonEigenvalues(usize),
    #[error("invalid argument {0}: {1}")]
    BadArg(usize, &'static str),
}

pub type Result<T> = std::result::Result<T, LinalgError>;
