//! Level-3 BLAS: matrix-matrix operations.
//!
//! `dgemm` comes in three algorithmic variants, which back the three
//! rust "libraries" the experiments compare (DESIGN.md §Substitutions 1):
//!
//! * [`dgemm_naive`] — textbook triple loop (the "unblocked reference
//!   library" / netlib analog),
//! * [`dgemm_blocked`] — BLIS-style cache-blocked loop nest with packed
//!   A/B panels and an `MR×NR` register microkernel (the optimized
//!   library analog; this is the L3 performance hot path, see
//!   EXPERIMENTS.md §Perf),
//! * [`dgemm_recursive`] — recursive splitting down to a blocked base
//!   case (the RECSY-style analog).
//!
//! `dtrsm`/`dtrmm`/`dsyrk` have unblocked and blocked (gemm-rich)
//! variants.

use super::{Diag, Side, Trans, Uplo};

/// Microkernel tile: MR×NR accumulators held in registers.
pub const MR: usize = 8;
pub const NR: usize = 4;
/// Cache blocking: A panel MC×KC (~L2), B panel KC×NC (~L3/L2).
pub const MC: usize = 256;
pub const KC: usize = 256;
pub const NC: usize = 2048;

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

/// Scale C by beta (shared prologue of the gemm variants).
fn scale_c(m: usize, n: usize, beta: f64, c: &mut [f64], ldc: usize) {
    if beta == 1.0 {
        return;
    }
    for j in 0..n {
        let col = &mut c[j * ldc..j * ldc + m];
        if beta == 0.0 {
            col.fill(0.0);
        } else {
            for v in col {
                *v *= beta;
            }
        }
    }
}

#[inline(always)]
fn a_elem(a: &[f64], lda: usize, trans: Trans, i: usize, k: usize) -> f64 {
    match trans {
        Trans::No => a[idx(i, k, lda)],
        Trans::Yes => a[idx(k, i, lda)],
    }
}

/// C := alpha·op(A)·op(B) + beta·C, textbook loops. op(A): m×k, op(B): k×n.
pub fn dgemm_naive(
    transa: Trans,
    transb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    scale_c(m, n, beta, c, ldc);
    if alpha == 0.0 || k == 0 {
        return;
    }
    for j in 0..n {
        for p in 0..k {
            let bpj = alpha * a_elem(b, ldb, transb, p, j);
            if bpj == 0.0 {
                continue;
            }
            match transa {
                Trans::No => {
                    let acol = &a[p * lda..p * lda + m];
                    let ccol = &mut c[j * ldc..j * ldc + m];
                    for i in 0..m {
                        ccol[i] += bpj * acol[i];
                    }
                }
                Trans::Yes => {
                    for i in 0..m {
                        c[idx(i, j, ldc)] += bpj * a[idx(p, i, lda)];
                    }
                }
            }
        }
    }
}

/// Pack an MC×KC block of op(A) into row-major MR-panels:
/// buf[panel][k][r] with panel = i/MR.
fn pack_a(
    buf: &mut [f64],
    a: &[f64],
    lda: usize,
    trans: Trans,
    i0: usize,
    k0: usize,
    mc: usize,
    kc: usize,
) {
    let mut dst = 0;
    let mut i = 0;
    while i < mc {
        let mr = MR.min(mc - i);
        for p in 0..kc {
            for r in 0..MR {
                buf[dst] = if r < mr {
                    a_elem(a, lda, trans, i0 + i + r, k0 + p)
                } else {
                    0.0
                };
                dst += 1;
            }
        }
        i += MR;
    }
}

/// Pack a KC×NC block of op(B) into column-major NR-panels:
/// buf[panel][k][c] with panel = j/NR.
fn pack_b(
    buf: &mut [f64],
    b: &[f64],
    ldb: usize,
    trans: Trans,
    k0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
) {
    let mut dst = 0;
    let mut j = 0;
    while j < nc {
        let nr = NR.min(nc - j);
        for p in 0..kc {
            for cidx in 0..NR {
                buf[dst] = if cidx < nr {
                    a_elem(b, ldb, trans, k0 + p, j0 + j + cidx)
                } else {
                    0.0
                };
                dst += 1;
            }
        }
        j += NR;
    }
}

/// MR×NR microkernel over a length-`kc` rank-1 chain. `pa` is an
/// MR-panel (MR consecutive per k), `pb` an NR-panel. Accumulates
/// `alpha * pa * pb` into C (C already beta-scaled).
#[inline(always)]
fn microkernel(
    kc: usize,
    alpha: f64,
    pa: &[f64],
    pb: &[f64],
    c: &mut [f64],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    // Accumulators: NR columns of MR values — kept in a flat array the
    // optimizer promotes to vector registers. The k-loop is unrolled by
    // two to hide the panel loads (EXPERIMENTS.md §Perf iteration 4).
    let mut acc = [[0.0f64; MR]; NR];
    let mut p = 0;
    while p + 2 <= kc {
        let av0 = &pa[p * MR..p * MR + MR];
        let bv0 = &pb[p * NR..p * NR + NR];
        let av1 = &pa[(p + 1) * MR..(p + 1) * MR + MR];
        let bv1 = &pb[(p + 1) * NR..(p + 1) * NR + NR];
        for cidx in 0..NR {
            let (b0, b1) = (bv0[cidx], bv1[cidx]);
            let accc = &mut acc[cidx];
            for r in 0..MR {
                accc[r] += av0[r] * b0 + av1[r] * b1;
            }
        }
        p += 2;
    }
    if p < kc {
        let av = &pa[p * MR..p * MR + MR];
        let bv = &pb[p * NR..p * NR + NR];
        for cidx in 0..NR {
            let bb = bv[cidx];
            let accc = &mut acc[cidx];
            for r in 0..MR {
                accc[r] += av[r] * bb;
            }
        }
    }
    for cidx in 0..nr {
        let ccol = &mut c[cidx * ldc..cidx * ldc + mr];
        for r in 0..mr {
            ccol[r] += alpha * acc[cidx][r];
        }
    }
}

/// C := alpha·op(A)·op(B) + beta·C — cache-blocked, packed, with the
/// register microkernel. The optimized-library gemm.
pub fn dgemm_blocked(
    transa: Trans,
    transb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    scale_c(m, n, beta, c, ldc);
    if alpha == 0.0 || k == 0 || m == 0 || n == 0 {
        return;
    }
    // Packing buffers are reused across calls (thread-local): per-call
    // allocation of the ~1.5 MiB panels dominated small/recursive gemms
    // (EXPERIMENTS.md §Perf iteration 1).
    thread_local! {
        static PACK_A: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
        static PACK_B: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
    }
    PACK_A.with(|pa| PACK_B.with(|pb| {
    let mut pa = pa.borrow_mut();
    let mut pb = pb.borrow_mut();
    let need_a = MC.div_ceil(MR) * MR * KC;
    let need_b = KC * NC.div_ceil(NR) * NR;
    if pa.len() < need_a {
        pa.resize(need_a, 0.0);
    }
    if pb.len() < need_b {
        pb.resize(need_b, 0.0);
    }
    let packed_a: &mut [f64] = &mut pa;
    let packed_b: &mut [f64] = &mut pb;

    let mut j0 = 0;
    while j0 < n {
        let nc = NC.min(n - j0);
        let mut k0 = 0;
        while k0 < k {
            let kc = KC.min(k - k0);
            pack_b(packed_b, b, ldb, transb, k0, j0, kc, nc);
            let mut i0 = 0;
            while i0 < m {
                let mc = MC.min(m - i0);
                pack_a(packed_a, a, lda, transa, i0, k0, mc, kc);
                // macrokernel: sweep microtiles
                let mut jj = 0;
                while jj < nc {
                    let nr = NR.min(nc - jj);
                    let pb = &packed_b[(jj / NR) * kc * NR..][..kc * NR];
                    let mut ii = 0;
                    while ii < mc {
                        let mr = MR.min(mc - ii);
                        let pa = &packed_a[(ii / MR) * kc * MR..][..kc * MR];
                        let coff = idx(i0 + ii, j0 + jj, ldc);
                        microkernel(kc, alpha, pa, pb, &mut c[coff..], ldc, mr, nr);
                        ii += MR;
                    }
                    jj += NR;
                }
                i0 += MC;
            }
            k0 += KC;
        }
        j0 += NC;
    }
    }));
}

/// Recursion cutoff for [`dgemm_recursive`].
const REC_CUTOFF: usize = 128;

/// C := alpha·op(A)·op(B) + beta·C via recursive splitting of the
/// largest dimension (RECSY-style), blocked base case.
pub fn dgemm_recursive(
    transa: Trans,
    transb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    if m.max(n).max(k) <= REC_CUTOFF {
        dgemm_blocked(transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc);
        return;
    }
    if m >= n && m >= k {
        let m1 = m / 2;
        dgemm_recursive(transa, transb, m1, n, k, alpha, a, lda, b, ldb, beta, c, ldc);
        let a_lo = match transa {
            Trans::No => &a[m1..],        // row split of A
            Trans::Yes => &a[m1 * lda..], // column split of Aᵀ storage
        };
        dgemm_recursive(
            transa, transb, m - m1, n, k, alpha, a_lo, lda, b, ldb, beta,
            &mut c[m1..], ldc,
        );
    } else if n >= k {
        let n1 = n / 2;
        dgemm_recursive(transa, transb, m, n1, k, alpha, a, lda, b, ldb, beta, c, ldc);
        let b_hi = match transb {
            Trans::No => &b[n1 * ldb..],
            Trans::Yes => &b[n1..],
        };
        dgemm_recursive(
            transa, transb, m, n - n1, k, alpha, a, lda, b_hi, ldb, beta,
            &mut c[n1 * ldc..], ldc,
        );
    } else {
        let k1 = k / 2;
        dgemm_recursive(transa, transb, m, n, k1, alpha, a, lda, b, ldb, beta, c, ldc);
        let a_hi = match transa {
            Trans::No => &a[k1 * lda..],
            Trans::Yes => &a[k1..],
        };
        let b_lo = match transb {
            Trans::No => &b[k1..],
            Trans::Yes => &b[k1 * ldb..],
        };
        dgemm_recursive(transa, transb, m, n, k - k1, alpha, a_hi, lda, b_lo, ldb, 1.0, c, ldc);
    }
}

/// Default gemm used by higher-level routines (blocked).
pub fn dgemm(
    transa: Trans,
    transb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    dgemm_blocked(transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Unblocked triangular solve with multiple right-hand sides:
/// op(A)·X = alpha·B (Left) or X·op(A) = alpha·B (Right), X overwrites B.
pub fn dtrsm_unblocked(
    side: Side,
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
) {
    if alpha != 1.0 {
        for j in 0..n {
            for v in &mut b[j * ldb..j * ldb + m] {
                *v *= alpha;
            }
        }
    }
    match side {
        Side::Left => {
            // solve op(A) X = B column by column
            for j in 0..n {
                super::blas2::dtrsv(uplo, trans, diag, m, a, lda, &mut b[j * ldb..], 1);
            }
        }
        Side::Right => {
            // X op(A) = B  ⇔  op(A)ᵀ Xᵀ = Bᵀ: solve row systems.
            // Row i of B has stride ldb; dtrsv supports strides.
            let flip = match trans {
                Trans::No => Trans::Yes,
                Trans::Yes => Trans::No,
            };
            for i in 0..m {
                super::blas2::dtrsv(uplo, flip, diag, n, a, lda, &mut b[i..], ldb);
            }
        }
    }
}

/// Blocked triangular solve: diagonal-block unblocked solves plus gemm
/// updates (the optimized-library trsm).
pub fn dtrsm_blocked(
    side: Side,
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
    nb: usize,
) {
    let nb = nb.max(1);
    if alpha != 1.0 {
        for j in 0..n {
            for v in &mut b[j * ldb..j * ldb + m] {
                *v *= alpha;
            }
        }
    }
    match side {
        Side::Left => {
            // Traversal order depends on (uplo, trans).
            let forward = matches!(
                (uplo, trans),
                (Uplo::Lower, Trans::No) | (Uplo::Upper, Trans::Yes)
            );
            let starts: Vec<usize> = (0..m).step_by(nb).collect();
            let order: Vec<usize> =
                if forward { starts.clone() } else { starts.iter().rev().copied().collect() };
            for &i0 in &order {
                let ib = nb.min(m - i0);
                // solve diagonal block
                dtrsm_unblocked(
                    side, uplo, trans, diag, ib, n, 1.0,
                    &a[idx(i0, i0, lda)..], lda, &mut b[i0..], ldb,
                );
                // Update the remaining rows. The solved row panel
                // B1 = B[i0..i0+ib, :] is interleaved (column-major)
                // with the rows being updated, so copy it into a packed
                // temp first to satisfy Rust aliasing (LAPACK would
                // alias; a pack is what optimized BLAS do anyway).
                let mut panel = vec![0.0f64; ib * n];
                for j in 0..n {
                    panel[j * ib..(j + 1) * ib]
                        .copy_from_slice(&b[i0 + j * ldb..i0 + j * ldb + ib]);
                }
                if forward {
                    let rem = m - i0 - ib;
                    if rem > 0 {
                        // B2 -= op(A21) * B1
                        let (a_off, ta) = match (uplo, trans) {
                            (Uplo::Lower, Trans::No) => (idx(i0 + ib, i0, lda), Trans::No),
                            (Uplo::Upper, Trans::Yes) => (idx(i0, i0 + ib, lda), Trans::Yes),
                            _ => unreachable!(),
                        };
                        dgemm(
                            ta, Trans::No, rem, n, ib, -1.0,
                            &a[a_off..], lda, &panel, ib, 1.0, &mut b[i0 + ib..], ldb,
                        );
                    }
                } else if i0 > 0 {
                    // B1' -= op(A12) * B1 (rows above the solved block)
                    let (a_off, ta) = match (uplo, trans) {
                        (Uplo::Upper, Trans::No) => (idx(0, i0, lda), Trans::No),
                        (Uplo::Lower, Trans::Yes) => (idx(i0, 0, lda), Trans::Yes),
                        _ => unreachable!(),
                    };
                    dgemm(
                        ta, Trans::No, i0, n, ib, -1.0,
                        &a[a_off..], lda, &panel, ib, 1.0, b, ldb,
                    );
                }
            }
        }
        Side::Right => {
            // Column-block traversal of B. X op(A) = B.
            let forward = matches!(
                (uplo, trans),
                (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes)
            );
            let starts: Vec<usize> = (0..n).step_by(nb).collect();
            let order: Vec<usize> =
                if forward { starts.clone() } else { starts.iter().rev().copied().collect() };
            for &j0 in &order {
                let jb = nb.min(n - j0);
                dtrsm_unblocked(
                    side, uplo, trans, diag, m, jb, 1.0,
                    &a[idx(j0, j0, lda)..], lda, &mut b[j0 * ldb..], ldb,
                );
                if forward {
                    let rem = n - j0 - jb;
                    if rem > 0 {
                        // B2 -= B1 * op(A12)
                        let (a_off, ta) = match (uplo, trans) {
                            (Uplo::Upper, Trans::No) => (idx(j0, j0 + jb, lda), Trans::No),
                            (Uplo::Lower, Trans::Yes) => (idx(j0 + jb, j0, lda), Trans::Yes),
                            _ => unreachable!(),
                        };
                        let (b1, b2) = b.split_at_mut((j0 + jb) * ldb);
                        dgemm(
                            Trans::No, ta, m, rem, jb, -1.0,
                            &b1[j0 * ldb..], ldb, &a[a_off..], lda, 1.0, b2, ldb,
                        );
                    }
                } else if j0 > 0 {
                    // B1 -= B2 * op(A21)
                    let (a_off, ta) = match (uplo, trans) {
                        (Uplo::Lower, Trans::No) => (idx(j0, 0, lda), Trans::No),
                        (Uplo::Upper, Trans::Yes) => (idx(0, j0, lda), Trans::Yes),
                        _ => unreachable!(),
                    };
                    let (b1, b2) = b.split_at_mut(j0 * ldb);
                    dgemm(
                        Trans::No, ta, m, j0, jb, -1.0,
                        b2, ldb, &a[a_off..], lda, 1.0, b1, ldb,
                    );
                }
            }
        }
    }
}

/// Default trsm (blocked with nb=64).
pub fn dtrsm(
    side: Side,
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
) {
    dtrsm_blocked(side, uplo, trans, diag, m, n, alpha, a, lda, b, ldb, 64)
}

/// B := alpha·op(A)·B (Left) or alpha·B·op(A) (Right), A triangular.
pub fn dtrmm(
    side: Side,
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
) {
    match side {
        Side::Left => {
            for j in 0..n {
                super::blas2::dtrmv(uplo, trans, diag, m, a, lda, &mut b[j * ldb..], 1);
                if alpha != 1.0 {
                    for v in &mut b[j * ldb..j * ldb + m] {
                        *v *= alpha;
                    }
                }
            }
        }
        Side::Right => {
            let flip = match trans {
                Trans::No => Trans::Yes,
                Trans::Yes => Trans::No,
            };
            for i in 0..m {
                super::blas2::dtrmv(uplo, flip, diag, n, a, lda, &mut b[i..], ldb);
            }
            if alpha != 1.0 {
                for j in 0..n {
                    for v in &mut b[j * ldb..j * ldb + m] {
                        *v *= alpha;
                    }
                }
            }
        }
    }
}

/// C := alpha·A·Aᵀ + beta·C (trans=No) or alpha·Aᵀ·A + beta·C
/// (trans=Yes), C symmetric n×n, only `uplo` triangle updated.
pub fn dsyrk(
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    for j in 0..n {
        let (i_lo, i_hi) = match uplo {
            Uplo::Lower => (j, n),
            Uplo::Upper => (0, j + 1),
        };
        for i in i_lo..i_hi {
            let mut s = 0.0;
            for p in 0..k {
                let aip = a_elem(a, lda, trans, i, p);
                let ajp = a_elem(a, lda, trans, j, p);
                s += aip * ajp;
            }
            let v = &mut c[idx(i, j, ldc)];
            *v = alpha * s + if beta == 0.0 { 0.0 } else { beta * *v };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    fn ref_gemm(
        transa: Trans,
        transb: Trans,
        alpha: f64,
        a: &Matrix,
        b: &Matrix,
        beta: f64,
        c: &Matrix,
    ) -> Matrix {
        let ae = if transa == Trans::Yes { a.transpose() } else { a.clone() };
        let be = if transb == Trans::Yes { b.transpose() } else { b.clone() };
        let mut out = ae.matmul(&be);
        for j in 0..out.n {
            for i in 0..out.m {
                out[(i, j)] = alpha * out[(i, j)] + beta * c[(i, j)];
            }
        }
        out
    }

    fn check_gemm_variant(
        gemm: fn(
            Trans, Trans, usize, usize, usize, f64, &[f64], usize, &[f64], usize, f64,
            &mut [f64], usize,
        ),
        m: usize,
        n: usize,
        k: usize,
        seed: u64,
    ) {
        let mut rng = Xoshiro256::seeded(seed);
        for &transa in &[Trans::No, Trans::Yes] {
            for &transb in &[Trans::No, Trans::Yes] {
                let a = if transa == Trans::No {
                    Matrix::random(m, k, &mut rng)
                } else {
                    Matrix::random(k, m, &mut rng)
                };
                let b = if transb == Trans::No {
                    Matrix::random(k, n, &mut rng)
                } else {
                    Matrix::random(n, k, &mut rng)
                };
                let c0 = Matrix::random(m, n, &mut rng);
                let expect = ref_gemm(transa, transb, 1.5, &a, &b, -0.5, &c0);
                let mut c = c0.clone();
                let ldc = c.ld();
                gemm(
                    transa, transb, m, n, k, 1.5, &a.data, a.ld(), &b.data, b.ld(), -0.5,
                    &mut c.data, ldc,
                );
                let diff = c.max_abs_diff(&expect);
                assert!(diff < 1e-10 * k as f64, "{transa:?}{transb:?} m{m} n{n} k{k}: {diff}");
            }
        }
    }

    #[test]
    fn gemm_naive_matches_ref() {
        check_gemm_variant(dgemm_naive, 13, 7, 9, 10);
    }

    #[test]
    fn gemm_blocked_matches_ref_small() {
        check_gemm_variant(dgemm_blocked, 13, 7, 9, 11);
    }

    #[test]
    fn gemm_blocked_matches_ref_microtile_edges() {
        // Exercise all mr/nr edge combinations around MR=8, NR=4.
        for &m in &[1usize, 7, 8, 9, 16, 17] {
            for &n in &[1usize, 3, 4, 5, 8, 9] {
                check_gemm_variant(dgemm_blocked, m, n, 5, 100 + (m * 31 + n) as u64);
            }
        }
    }

    #[test]
    fn gemm_blocked_matches_ref_crossing_cache_blocks() {
        check_gemm_variant(dgemm_blocked, MC + 9, NR * 3 + 2, KC + 5, 12);
    }

    #[test]
    fn gemm_recursive_matches_ref() {
        check_gemm_variant(dgemm_recursive, 150, 140, 130, 13);
        check_gemm_variant(dgemm_recursive, 260, 40, 300, 14);
    }

    #[test]
    fn gemm_beta_zero_ignores_nan_c() {
        let a = [1.0, 1.0];
        let b = [1.0];
        let mut c = [f64::NAN, f64::NAN];
        dgemm_blocked(Trans::No, Trans::No, 2, 1, 1, 1.0, &a, 2, &b, 1, 0.0, &mut c, 2);
        assert_eq!(c, [1.0, 1.0]);
    }

    #[test]
    fn gemm_with_ld_gt_m() {
        // 2×2 matrices stored with ld=4.
        let mut rng = Xoshiro256::seeded(15);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        let mut c = vec![0.0; 8];
        for j in 0..2 {
            for i in 0..2 {
                a[i + j * 4] = rng.next_open01();
                b[i + j * 4] = rng.next_open01();
            }
        }
        dgemm_blocked(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 4, &b, 4, 0.0, &mut c, 4);
        for j in 0..2 {
            for i in 0..2 {
                let expect = a[i] * b[j * 4] + a[i + 4] * b[1 + j * 4];
                assert!((c[i + j * 4] - expect).abs() < 1e-14);
            }
        }
        // padding untouched
        assert_eq!(c[2], 0.0);
        assert_eq!(c[3], 0.0);
    }

    fn check_trsm_all_variants(blocked: bool, n_rhs: usize, n: usize, seed: u64) {
        let mut rng = Xoshiro256::seeded(seed);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Lower, Uplo::Upper] {
                for &trans in &[Trans::No, Trans::Yes] {
                    for &diag in &[Diag::NonUnit, Diag::Unit] {
                        let (m_b, n_b) = match side {
                            Side::Left => (n, n_rhs),
                            Side::Right => (n_rhs, n),
                        };
                        let a = Matrix::random_triangular(n, uplo, &mut rng);
                        let x = Matrix::random(m_b, n_b, &mut rng);
                        // b := op(A)·x (left) or x·op(A) (right)
                        let mut b = x.clone();
                        dtrmm(side, uplo, trans, diag, m_b, n_b, 1.0, &a.data, n, &mut b.data, m_b);
                        let mut solved = b.clone();
                        if blocked {
                            dtrsm_blocked(
                                side, uplo, trans, diag, m_b, n_b, 1.0, &a.data, n,
                                &mut solved.data, m_b, 3,
                            );
                        } else {
                            dtrsm_unblocked(
                                side, uplo, trans, diag, m_b, n_b, 1.0, &a.data, n,
                                &mut solved.data, m_b,
                            );
                        }
                        let diff = solved.max_abs_diff(&x);
                        assert!(
                            diff < 1e-9,
                            "{side:?} {uplo:?} {trans:?} {diag:?} blocked={blocked}: {diff}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trsm_unblocked_inverts_trmm() {
        check_trsm_all_variants(false, 5, 8, 20);
    }

    #[test]
    fn trsm_blocked_inverts_trmm() {
        check_trsm_all_variants(true, 5, 8, 21);
        check_trsm_all_variants(true, 4, 17, 22); // n not multiple of nb
    }

    #[test]
    fn trsm_alpha_scaling() {
        let a = [2.0]; // 1×1 lower
        let mut b = [8.0, 6.0];
        dtrsm(
            Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, 1, 2, 0.5, &a, 1, &mut b, 1,
        );
        assert_eq!(b, [2.0, 1.5]);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Xoshiro256::seeded(23);
        let n = 9;
        let k = 5;
        for &trans in &[Trans::No, Trans::Yes] {
            let a = if trans == Trans::No {
                Matrix::random(n, k, &mut rng)
            } else {
                Matrix::random(k, n, &mut rng)
            };
            let full = if trans == Trans::No {
                a.matmul(&a.transpose())
            } else {
                a.transpose().matmul(&a)
            };
            for &uplo in &[Uplo::Lower, Uplo::Upper] {
                let mut c = Matrix::zeros(n, n);
                dsyrk(uplo, trans, n, k, 1.0, &a.data, a.ld(), 0.0, &mut c.data, n);
                for j in 0..n {
                    for i in 0..n {
                        let in_tri = match uplo {
                            Uplo::Lower => i >= j,
                            Uplo::Upper => i <= j,
                        };
                        if in_tri {
                            assert!((c[(i, j)] - full[(i, j)]).abs() < 1e-12);
                        } else {
                            assert_eq!(c[(i, j)], 0.0);
                        }
                    }
                }
            }
        }
    }
}
